//! `cargo bench --bench fig9a` — regenerates the paper's fig9a (DESIGN.md §3).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("fig9a", &scale) {
        Ok(out) => {
            println!("==== fig9a (scale={scale}) ====");
            println!("{out}");
            println!("[fig9a completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig9a failed: {e:#}");
            std::process::exit(1);
        }
    }
}
