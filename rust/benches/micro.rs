//! Microbenchmarks of the hot paths (the §Perf profiling harness):
//! scheduler cycles/s, simulator cycles/s, full compile, and the numeric
//! level-executor dispatch (native always; PJRT when available).

use mgd_sptrsv::compiler::{compile, schedule_only, CompilerConfig};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::runtime::{LevelSolver, NativeBackend, NativeConfig, SolverBackend};
use mgd_sptrsv::sim::Accelerator;
use mgd_sptrsv::util::timing::fmt_duration;
use std::time::Instant;

fn main() {
    let m = gen::circuit(20_000, 6, 0.8, GenSeed(3));
    let cfg = CompilerConfig::default();
    println!("workload: n={} nnz={}", m.n, m.nnz());

    // Scheduler throughput.
    let t0 = Instant::now();
    let s = schedule_only(&m, &cfg).expect("schedule");
    let dt = t0.elapsed();
    let cu_cycles = s.stats.cycles * 64;
    println!(
        "schedule_only: {} ({} cycles, {:.1} M CU-cycles/s)",
        fmt_duration(dt),
        s.stats.cycles,
        cu_cycles as f64 / dt.as_secs_f64() / 1e6
    );

    // Full compile (both passes + coloring + emission).
    let t0 = Instant::now();
    let prog = compile(&m, &cfg).expect("compile");
    let dt = t0.elapsed();
    println!(
        "compile: {} ({:.2} ns/nnz)",
        fmt_duration(dt),
        dt.as_nanos() as f64 / m.nnz() as f64
    );

    // Simulator throughput.
    let b = vec![1.0f32; m.n];
    let mut acc = Accelerator::new(cfg.arch);
    let t0 = Instant::now();
    let run = acc.run(&prog, &b).expect("sim");
    let dt = t0.elapsed();
    run.stats
        .verify_against(&prog.predicted)
        .expect("double entry");
    println!(
        "simulate: {} ({:.1} M CU-cycles/s)",
        fmt_duration(dt),
        (run.stats.cycles * 64) as f64 / dt.as_secs_f64() / 1e6
    );

    // Native numeric path (the default serve backend).
    let solver = LevelSolver::new(&m);
    let native = NativeBackend::new(NativeConfig::default());
    let t0 = Instant::now();
    let x = native.solve(&solver, &b).expect("native solve");
    let dt = t0.elapsed();
    std::hint::black_box(&x);
    println!(
        "native solve ({} threads): {} ({} levels, {:.1} us/level)",
        native.threads(),
        fmt_duration(dt),
        solver.num_levels(),
        dt.as_micros() as f64 / solver.num_levels() as f64
    );
    let bs: Vec<Vec<f32>> = (0..8).map(|_| b.clone()).collect();
    let t0 = Instant::now();
    let xs = native.solve_multi(&solver, &bs).expect("native multi");
    let dt = t0.elapsed();
    std::hint::black_box(&xs);
    println!(
        "native solve_multi x8: {} ({:.2} ms/rhs)",
        fmt_duration(dt),
        dt.as_secs_f64() * 1e3 / 8.0
    );

    // PJRT numeric path (feature `pjrt` + built artifacts only).
    #[cfg(feature = "pjrt")]
    {
        let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match mgd_sptrsv::runtime::PjrtBackend::load(&artifacts) {
            Ok(backend) => {
                let t0 = Instant::now();
                let x = backend.solve(&solver, &b).expect("pjrt solve");
                let dt = t0.elapsed();
                std::hint::black_box(&x);
                println!(
                    "pjrt solve: {} ({} levels, {:.1} us/level)",
                    fmt_duration(dt),
                    solver.num_levels(),
                    dt.as_micros() as f64 / solver.num_levels() as f64
                );
            }
            Err(e) => println!("pjrt solve: skipped ({e:#})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt solve: skipped (built without the `pjrt` feature)");
}
