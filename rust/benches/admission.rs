//! `cargo bench --bench admission` — latency-class p50/p99 under a bulk
//! flood, first-come vs bounded by-class admission (emits
//! BENCH_admission.json). Scale via MGD_BENCH_SCALE=small|full (default
//! small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("admission", &scale) {
        Ok(out) => {
            println!("==== admission (scale={scale}) ====");
            println!("{out}");
            println!("[admission completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("admission failed: {e:#}");
            std::process::exit(1);
        }
    }
}
