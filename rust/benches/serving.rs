//! `cargo bench --bench serving` — persistent-pool vs per-solve-spawn
//! serving latency on the barrier-free MGD path (emits
//! BENCH_serving.json). Scale via MGD_BENCH_SCALE=small|full (default
//! small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("serving", &scale) {
        Ok(out) => {
            println!("==== serving (scale={scale}) ====");
            println!("{out}");
            println!("[serving completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("serving failed: {e:#}");
            std::process::exit(1);
        }
    }
}
