//! `cargo bench --bench table2` — regenerates the paper's table2 (DESIGN.md §3).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("table2", &scale) {
        Ok(out) => {
            println!("==== table2 (scale={scale}) ====");
            println!("{out}");
            println!("[table2 completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("table2 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
