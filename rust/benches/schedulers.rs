//! `cargo bench --bench schedulers` — level-barrier vs barrier-free MGD
//! native scheduler comparison (emits BENCH_schedulers.json).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("schedulers", &scale) {
        Ok(out) => {
            println!("==== schedulers (scale={scale}) ====");
            println!("{out}");
            println!("[schedulers completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("schedulers failed: {e:#}");
            std::process::exit(1);
        }
    }
}
