//! `cargo bench --bench skew` — cold-key tail latency under a skewed
//! hot/cold key mix, cost-model placement vs round-robin (emits
//! BENCH_skew.json). Scale via MGD_BENCH_SCALE=small|full (default
//! small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("skew", &scale) {
        Ok(out) => {
            println!("==== skew (scale={scale}) ====");
            println!("{out}");
            println!("[skew completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("skew failed: {e:#}");
            std::process::exit(1);
        }
    }
}
