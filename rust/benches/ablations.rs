//! Ablations of the design choices DESIGN.md calls out: allocation policy,
//! operand forwarding, bank coloring, ICR, and medium-node splitting
//! (paper §V.E future work).

use mgd_sptrsv::arch::ArchConfig;
use mgd_sptrsv::bench_harness::workloads;
use mgd_sptrsv::compiler::{schedule_only, split, AllocationPolicy, CompilerConfig};
use mgd_sptrsv::util::Table;

fn gops(m: &mgd_sptrsv::matrix::CsrMatrix, cfg: &CompilerConfig) -> f64 {
    let s = schedule_only(m, cfg).expect("schedule");
    let flops = (2 * m.nnz() - m.n) as f64;
    flops / (s.stats.cycles as f64 / cfg.arch.clock_hz) / 1e9
}

fn main() {
    let arch = ArchConfig::default();
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let suite = if scale == "full" {
        workloads::suite()
    } else {
        workloads::suite_small(8)
    };
    let base = CompilerConfig {
        arch,
        ..CompilerConfig::default()
    };
    let mut table = Table::new(vec![
        "benchmark",
        "base GOPS",
        "least-loaded",
        "no forwarding",
        "no coloring",
        "no ICR",
        "split(16)",
    ]);
    for w in &suite {
        let m = &w.matrix;
        let b = gops(m, &base);
        let ll = gops(
            m,
            &CompilerConfig {
                allocation: AllocationPolicy::LeastLoaded,
                ..base.clone()
            },
        );
        let nf = gops(
            m,
            &CompilerConfig {
                forwarding: false,
                ..base.clone()
            },
        );
        let nc = gops(
            m,
            &CompilerConfig {
                use_coloring: false,
                ..base.clone()
            },
        );
        let ni = gops(
            m,
            &CompilerConfig {
                use_icr: false,
                ..base.clone()
            },
        );
        // Medium-node splitting: solve the rewritten system; throughput is
        // original flops over the (larger) split system's cycles.
        let sp = match split::split_heavy_nodes(m, 16) {
            Ok(s) if s.intermediates > 0 => {
                let sched = schedule_only(&s.matrix, &base).expect("split schedule");
                let flops = (2 * m.nnz() - m.n) as f64;
                flops / (sched.stats.cycles as f64 / arch.clock_hz) / 1e9
            }
            _ => b,
        };
        table.row(vec![
            w.name.to_string(),
            format!("{b:.2}"),
            format!("{ll:.2}"),
            format!("{nf:.2}"),
            format!("{nc:.2}"),
            format!("{ni:.2}"),
            format!("{sp:.2}"),
        ]);
    }
    println!("==== ablations (scale={scale}) ====");
    println!("{table}");
}
