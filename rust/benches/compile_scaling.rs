//! Compile-time scaling (paper §V.G: O(nnz·d) vs DPU-v2's O(nnz²)).
//!
//! Prints compile seconds vs nnz for this work's compiler and a quadratic
//! reference curve normalized at the smallest point (the DPU-v2 model).

use mgd_sptrsv::compiler::{compile, CompilerConfig};
use mgd_sptrsv::matrix::gen::{self, GenSeed};
use mgd_sptrsv::util::Table;

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let sizes: &[usize] = if scale == "full" {
        &[1_000, 2_000, 4_000, 8_000, 16_000, 32_000, 64_000]
    } else {
        &[1_000, 2_000, 4_000, 8_000]
    };
    let cfg = CompilerConfig::default();
    let mut table = Table::new(vec!["n", "nnz", "compile s", "us/nnz", "quadratic ref s"]);
    let mut base: Option<(f64, f64)> = None;
    for &n in sizes {
        let m = gen::circuit(n, 5, 0.8, GenSeed(9));
        let p = compile(&m, &cfg).expect("compile");
        let secs = p.compile.compile_seconds;
        let nnz = m.nnz() as f64;
        let quad = match base {
            None => {
                base = Some((secs, nnz));
                secs
            }
            Some((s0, z0)) => s0 * (nnz / z0) * (nnz / z0),
        };
        table.row(vec![
            n.to_string(),
            (nnz as usize).to_string(),
            format!("{secs:.4}"),
            format!("{:.3}", secs / nnz * 1e6),
            format!("{quad:.4}"),
        ]);
    }
    println!("==== compile_scaling (scale={scale}) ====");
    println!("{table}");
    println!(
        "(near-constant us/nnz => O(nnz*d); the quadratic column is what an \
         O(nnz^2) compiler would cost)"
    );
}
