//! `cargo bench --bench concurrency` — overlapped vs serialized pool
//! sessions on one shared native backend (emits BENCH_concurrency.json).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("concurrency", &scale) {
        Ok(out) => {
            println!("==== concurrency (scale={scale}) ====");
            println!("{out}");
            println!("[concurrency completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("concurrency failed: {e:#}");
            std::process::exit(1);
        }
    }
}
