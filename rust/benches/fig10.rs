//! `cargo bench --bench fig10` — regenerates the paper's fig10 (DESIGN.md §3).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("fig10", &scale) {
        Ok(out) => {
            println!("==== fig10 (scale={scale}) ====");
            println!("{out}");
            println!("[fig10 completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig10 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
