//! `cargo bench --bench fig12` — regenerates the paper's fig12 (DESIGN.md §3).
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("fig12", &scale) {
        Ok(out) => {
            println!("==== fig12 (scale={scale}) ====");
            println!("{out}");
            println!("[fig12 completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("fig12 failed: {e:#}");
            std::process::exit(1);
        }
    }
}
