//! `cargo bench --bench streaming` — pipelined solve sessions vs
//! call-per-solve on the circuit-transient workload (emits
//! BENCH_streaming.json). Scale via MGD_BENCH_SCALE=small|full (default
//! small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("streaming", &scale) {
        Ok(out) => {
            println!("==== streaming (scale={scale}) ====");
            println!("{out}");
            println!("[streaming completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("streaming failed: {e:#}");
            std::process::exit(1);
        }
    }
}
