//! `cargo bench --bench backends` — native-vs-PJRT backend comparison.
//! Scale via MGD_BENCH_SCALE=small|full (default small).

fn main() {
    let scale = std::env::var("MGD_BENCH_SCALE").unwrap_or_else(|_| "small".into());
    let t0 = std::time::Instant::now();
    match mgd_sptrsv::bench_harness::report::run_experiment("backends", &scale) {
        Ok(out) => {
            println!("==== backends (scale={scale}) ====");
            println!("{out}");
            println!("[backends completed in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => {
            eprintln!("backends failed: {e:#}");
            std::process::exit(1);
        }
    }
}
