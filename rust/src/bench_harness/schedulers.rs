//! Level-barrier vs barrier-free MGD scheduler comparison
//! (`mgd bench schedulers`): per-workload solve latency of the native
//! backend under both schedulers, scalar and batched, plus a
//! machine-readable `BENCH_schedulers.json` artifact.
//!
//! Every timed configuration is verified first — the level scheduler
//! against the serial-reference residual, the MGD scheduler **bitwise**
//! against [`solve_serial`] (its contract) — so the table cannot quietly
//! report a fast-but-wrong scheduler.

use super::workloads::Workload;
use crate::matrix::triangular::{max_relative_residual, solve_serial};
use crate::runtime::{LevelSolver, NativeBackend, NativeConfig, SchedulerKind, SolverBackend};
use crate::util::timing::bench_best;
use crate::util::Table;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Duration;

/// One workload's measurements (milliseconds; `*_rhs` are per-RHS over a
/// batched solve).
#[derive(Debug, Clone)]
pub struct SchedRow {
    /// Workload name.
    pub name: &'static str,
    /// Matrix order.
    pub n: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Level count (barriers per level-scheduler solve).
    pub levels: usize,
    /// Scalar solve, level scheduler.
    pub level_ms: f64,
    /// Scalar solve, MGD scheduler.
    pub mgd_ms: f64,
    /// Per-RHS batched solve, level scheduler.
    pub level_ms_rhs: f64,
    /// Per-RHS batched solve, MGD scheduler.
    pub mgd_ms_rhs: f64,
}

impl SchedRow {
    /// Scalar speedup of MGD over the level scheduler (> 1 = MGD wins).
    pub fn speedup(&self) -> f64 {
        self.level_ms / self.mgd_ms.max(1e-12)
    }

    /// Batched per-RHS speedup of MGD over the level scheduler.
    pub fn batched_speedup(&self) -> f64 {
        self.level_ms_rhs / self.mgd_ms_rhs.max(1e-12)
    }

    /// Deep/narrow workloads are the paper's target regime; the rest are
    /// wide controls.
    pub fn is_deep(&self) -> bool {
        self.name.starts_with("deep_") || self.name.starts_with("narrow_")
    }
}

fn time_scheduler(
    backend: &NativeBackend,
    plan: &LevelSolver,
    w: &Workload,
    rhs: usize,
) -> Result<(f64, f64)> {
    let b: Vec<f32> = (0..w.matrix.n).map(|i| (i % 7) as f32 - 3.0).collect();
    let x = backend.solve(plan, &b)?;
    match backend.resolve_scheduler(plan) {
        SchedulerKind::Mgd => {
            // The MGD contract is bitwise equality with the serial
            // reference, independent of thread count and steal order.
            let want = solve_serial(&w.matrix, &b);
            for i in 0..w.matrix.n {
                ensure!(
                    x[i].to_bits() == want[i].to_bits(),
                    "mgd scheduler not bitwise-serial on {} row {i}: {} vs {}",
                    w.name,
                    x[i],
                    want[i],
                );
            }
        }
        _ => {
            let resid = max_relative_residual(&w.matrix, &x, &b);
            ensure!(
                resid < 1e-3,
                "level scheduler wrong on {} (residual {resid:.2e})",
                w.name
            );
        }
    }
    let mut err: Option<anyhow::Error> = None;
    let scalar = bench_best(
        || match backend.solve(plan, &b) {
            Ok(x) => x,
            Err(e) => {
                err.get_or_insert(e);
                Vec::new()
            }
        },
        2,
        Duration::from_millis(20),
    );
    if let Some(e) = err {
        return Err(e.context(format!("scalar timing loop failed on {}", w.name)));
    }
    let bs: Vec<Vec<f32>> = (0..rhs)
        .map(|k| (0..w.matrix.n).map(|i| ((i + k) % 9) as f32 - 4.0).collect())
        .collect();
    let mut err: Option<anyhow::Error> = None;
    let batched = bench_best(
        || match backend.solve_multi(plan, &bs) {
            Ok(xs) => xs,
            Err(e) => {
                err.get_or_insert(e);
                Vec::new()
            }
        },
        2,
        Duration::from_millis(20),
    );
    if let Some(e) = err {
        return Err(e.context(format!("batched timing loop failed on {}", w.name)));
    }
    Ok((
        scalar.as_secs_f64() * 1e3,
        batched.as_secs_f64() * 1e3 / rhs as f64,
    ))
}

/// Compare both native schedulers over `suite`, batching `rhs` RHS per
/// multi-solve round.
pub fn scheduler_compare(suite: &[Workload], rhs: usize) -> Result<(Table, Vec<SchedRow>)> {
    let mk = |scheduler| {
        NativeBackend::new(NativeConfig {
            scheduler,
            ..NativeConfig::default()
        })
    };
    let level = mk(SchedulerKind::Level);
    let mgd = mk(SchedulerKind::Mgd);
    let mut t = Table::new(vec![
        "workload".to_string(),
        "n".to_string(),
        "nnz".to_string(),
        "levels".to_string(),
        "level ms".to_string(),
        "mgd ms".to_string(),
        "speedup".to_string(),
        format!("level ms/rhs (x{rhs})"),
        format!("mgd ms/rhs (x{rhs})"),
        "batched speedup".to_string(),
    ]);
    let mut rows = Vec::with_capacity(suite.len());
    for w in suite {
        let plan = LevelSolver::new(&w.matrix);
        let (level_ms, level_ms_rhs) = time_scheduler(&level, &plan, w, rhs)?;
        let (mgd_ms, mgd_ms_rhs) = time_scheduler(&mgd, &plan, w, rhs)?;
        let row = SchedRow {
            name: w.name,
            n: w.matrix.n,
            nnz: w.matrix.nnz(),
            levels: plan.num_levels(),
            level_ms,
            mgd_ms,
            level_ms_rhs,
            mgd_ms_rhs,
        };
        t.row(vec![
            row.name.to_string(),
            row.n.to_string(),
            row.nnz.to_string(),
            row.levels.to_string(),
            format!("{level_ms:.3}"),
            format!("{mgd_ms:.3}"),
            format!("{:.2}x", row.speedup()),
            format!("{level_ms_rhs:.3}"),
            format!("{mgd_ms_rhs:.3}"),
            format!("{:.2}x", row.batched_speedup()),
        ]);
        rows.push(row);
    }
    Ok((t, rows))
}

/// Geometric-mean MGD speedup over the deep/narrow rows (the paper's
/// target regime), scalar path.
pub fn deep_geomean_speedup(rows: &[SchedRow]) -> f64 {
    let deep: Vec<f64> = rows
        .iter()
        .filter(|r| r.is_deep())
        .map(|r| r.speedup())
        .collect();
    if deep.is_empty() {
        return 1.0;
    }
    (deep.iter().map(|s| s.ln()).sum::<f64>() / deep.len() as f64).exp()
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[SchedRow], rhs: usize) -> String {
    let mut out = String::from("{\n  \"experiment\": \"schedulers\",\n");
    out.push_str(&format!("  \"rhs_batch\": {rhs},\n"));
    out.push_str(&format!(
        "  \"deep_geomean_speedup\": {:.4},\n  \"rows\": [\n",
        deep_geomean_speedup(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"n\": {}, \"nnz\": {}, \"levels\": {}, \
             \"deep\": {}, \"level_ms\": {:.6}, \"mgd_ms\": {:.6}, \"speedup\": {:.4}, \
             \"level_ms_per_rhs\": {:.6}, \"mgd_ms_per_rhs\": {:.6}, \
             \"batched_speedup\": {:.4}}}{}\n",
            r.name,
            r.n,
            r.nnz,
            r.levels,
            r.is_deep(),
            r.level_ms,
            r.mgd_ms,
            r.speedup(),
            r.level_ms_rhs,
            r.mgd_ms_rhs,
            r.batched_speedup(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_schedulers.json`).
pub fn write_json(path: &Path, rows: &[SchedRow], rhs: usize) -> Result<()> {
    std::fs::write(path, render_json(rows, rhs))
        .with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::workloads;
    use crate::matrix::gen::{self, GenSeed};

    fn tiny_suite() -> Vec<Workload> {
        vec![
            Workload {
                name: "deep_chain",
                matrix: gen::chain(400, GenSeed(41)),
            },
            Workload {
                name: "wide_shallow",
                matrix: gen::shallow(600, 0.4, GenSeed(42)),
            },
        ]
    }

    #[test]
    fn compare_runs_and_verifies_both_schedulers() {
        let (t, rows) = scheduler_compare(&tiny_suite(), 3).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        let s = t.render();
        assert!(s.contains("level ms"));
        assert!(s.contains("mgd ms"));
        assert!(rows[0].is_deep());
        assert!(!rows[1].is_deep());
        for r in &rows {
            assert!(r.level_ms > 0.0 && r.mgd_ms > 0.0);
        }
    }

    #[test]
    fn json_is_well_formed_enough() {
        let (_, rows) = scheduler_compare(&tiny_suite(), 2).unwrap();
        let j = render_json(&rows, 2);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"schedulers\""));
        assert!(j.contains("\"workload\": \"deep_chain\""));
        assert!(j.contains("\"deep_geomean_speedup\""));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn scheduler_suite_shapes_are_as_labeled() {
        let suite = workloads::scheduler_suite("small");
        assert_eq!(suite.len(), 6);
        let avg_width = |w: &Workload| {
            let plan = crate::runtime::LevelSolver::new(&w.matrix);
            w.matrix.n / plan.num_levels().max(1)
        };
        for w in &suite {
            w.matrix.validate().unwrap();
        }
        // Guaranteed-by-construction shapes: the chain is width 1, the
        // tight band is chained at least every 3 rows, and shallow's
        // deps-from-the-first-quarter rule bounds its depth at ~log4(n).
        let by_name = |name: &str| suite.iter().find(|w| w.name == name).unwrap();
        assert_eq!(avg_width(by_name("deep_chain")), 1);
        assert!(avg_width(by_name("narrow_band")) <= 4);
        assert!(avg_width(by_name("wide_shallow")) >= 32);
        assert!(avg_width(by_name("wide_scatter")) >= 32);
    }
}
