//! Cold-key tail latency under a skewed key mix (`mgd bench skew`):
//! p50/p99 of requests to many cheap **cold** matrices while a flooder
//! keeps one expensive **hot** matrix backlogged, measured twice on the
//! same traffic shape — once with the legacy **round-robin** shard
//! placement (keys land by registration order, blind to their cost, so
//! some cold keys share the hot key's shard and queue behind its
//! backlog) and once with the **cost-model** least-loaded placement
//! (the hot key's registration-time cost weight claims its shard, so
//! every cold key is placed on the other shard and never waits behind
//! hot work). Emits the machine-readable `BENCH_skew.json` artifact
//! consumed by CI's bench-regression gate; the headline is the
//! round-robin-over-cost cold-probe p99 ratio (> 1 = cost placement
//! protects the cold tail).
//!
//! Every reply — hot and cold, warmup and measured — is verified
//! **bitwise** against [`solve_serial`] (the MGD contract), so the
//! comparison cannot quietly trade correctness for placement wins. The
//! bench also asserts the structural claim directly: under cost
//! placement no cold key may share the hot key's shard.

use crate::coordinator::{PlacementPolicy, ShardedServiceConfig, ShardedSolveService};
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::triangular::solve_serial;
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicBool, Ordering};
use crate::runtime::{BackendConfig, BackendKind, NativeConfig, SchedulerKind};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Worker-thread count of the shared native backend (fixed so the
/// artifact is comparable across machines with different core counts).
pub const SKEW_THREADS: usize = 4;

/// Shards the skewed service runs with. Two is the minimal shape that
/// exposes the placement decision: the hot key either owns one shard
/// (cost) or shares it with half the cold keys (round-robin).
pub const SKEW_SHARDS: usize = 2;

/// Hot requests the flooder keeps outstanding (in queue or in service),
/// enough to keep the hot shard's single worker permanently busy.
const FLOOD_WINDOW: usize = 6;

/// One placement mode's measurements.
#[derive(Debug, Clone)]
pub struct SkewRow {
    /// `"round_robin"` (registration-order placement, the baseline) or
    /// `"cost"` (least-loaded by registration-time cost weight).
    pub mode: &'static str,
    /// Cold-key probe requests measured.
    pub probes: u64,
    /// Median cold-probe latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile cold-probe latency, milliseconds.
    pub p99_ms: f64,
    /// Hot-key requests served to completion during the run (the
    /// throughput side of the headline: placement must not starve the
    /// hot key to buy its tail).
    pub hot_served: u64,
    /// Cold keys that landed on the hot key's shard (0 under cost
    /// placement — asserted, not just reported).
    pub colds_with_hot: u64,
}

/// The skewed suite: one expensive hot matrix plus several cheap cold
/// ones. All shallow scattered-dependency DAGs, so every solve opens a
/// real multi-worker MGD pool session and the hot solves are long
/// enough for a backlog to hurt anything queued behind them. `"tiny"`
/// is the unit-test scale (seconds of `cargo test` budget, not a
/// measurement); CI and the CLI use `"small"`/`"full"`.
fn suite(scale: &str) -> (CsrMatrix, Vec<CsrMatrix>) {
    let (hot_n, cold_n) = match scale {
        "tiny" => (1000, 300),
        "small" => (2800, 500),
        _ => (5600, 700),
    };
    let hot = gen::shallow(hot_n, 0.4, GenSeed(601));
    let colds = (0..4)
        .map(|k| gen::shallow(cold_n, 0.4, GenSeed(610 + k)))
        .collect();
    (hot, colds)
}

/// Cold probe count per mode.
fn probe_count(scale: &str) -> usize {
    match scale {
        "tiny" => 12,
        "small" => 40,
        _ => 100,
    }
}

fn service_config(placement: PlacementPolicy) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards: SKEW_SHARDS,
        // One worker per shard: a shard occupied by a hot solve makes
        // every co-located cold request wait, which is exactly the
        // contention placement is supposed to avoid.
        workers_per_shard: 1,
        batch_size: 4,
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                threads: SKEW_THREADS,
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        placement,
        ..ShardedServiceConfig::default()
    }
}

/// A fixed cycle of RHS vectors with their precomputed bitwise
/// references, so the flooder and the probes can verify every reply
/// cheaply.
struct VerifiedRhs {
    bs: Vec<Vec<f32>>,
    refs: Vec<Vec<f32>>,
}

impl VerifiedRhs {
    fn new(m: &CsrMatrix, variants: usize, salt: usize) -> Self {
        let bs: Vec<Vec<f32>> = (0..variants)
            .map(|k| {
                (0..m.n)
                    .map(|i| ((i + 3 * k + salt) % 9) as f32 - 4.0)
                    .collect()
            })
            .collect();
        let refs = bs.iter().map(|b| solve_serial(m, b)).collect();
        Self { bs, refs }
    }

    fn verify(&self, k: usize, x: &[f32], what: &str) -> Result<()> {
        let want = &self.refs[k % self.refs.len()];
        ensure!(x.len() == want.len(), "{what}: wrong solution length");
        for i in 0..want.len() {
            ensure!(
                x[i].to_bits() == want[i].to_bits(),
                "{what}: reply not bitwise-serial at row {i}"
            );
        }
        Ok(())
    }
}

/// Run one placement mode: register hot-then-colds, flood the hot key
/// from a background thread, and time sequential cold probes cycled over
/// the cold keys in a seeded shuffle. Every reply is verified bitwise.
fn run_mode(placement: PlacementPolicy, scale: &str) -> Result<SkewRow> {
    let (hot_m, cold_ms) = suite(scale);
    let svc = Arc::new(
        ShardedSolveService::start(service_config(placement)).context("start skew service")?,
    );
    // Registration order is the round-robin baseline's whole story: hot
    // first, then the colds, alternating shards blindly. The cost mode
    // sees the same order but places by accumulated weight.
    let hot_entry = svc.register("hot", &hot_m)?;
    let mut colds_with_hot = 0u64;
    let mut cold_keys = Vec::with_capacity(cold_ms.len());
    for (k, m) in cold_ms.iter().enumerate() {
        let key = format!("cold{k}");
        let entry = svc.register(&key, m)?;
        if entry.shard() == hot_entry.shard() {
            colds_with_hot += 1;
        }
        cold_keys.push(key);
    }
    if placement == PlacementPolicy::Cost {
        ensure!(
            colds_with_hot == 0,
            "cost placement co-located {colds_with_hot} cold keys with the hot key"
        );
    }
    let hot_rhs = Arc::new(VerifiedRhs::new(&hot_m, 4, 0));
    let cold_rhs: Vec<VerifiedRhs> = cold_ms.iter().map(|m| VerifiedRhs::new(m, 4, 1)).collect();

    // Warm every path (plans, pool, caches) and verify once before any
    // timing.
    let warm = svc.solve("hot", hot_rhs.bs[0].clone())?;
    hot_rhs.verify(0, &warm.x, "hot warmup")?;
    for (key, rhs) in cold_keys.iter().zip(&cold_rhs) {
        let warm = svc.solve(key, rhs.bs[0].clone())?;
        rhs.verify(0, &warm.x, "cold warmup")?;
    }

    let stop = Arc::new(AtomicBool::new(false));
    let flooder = {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let hot_rhs = Arc::clone(&hot_rhs);
        std::thread::spawn(move || -> Result<u64> {
            let mut pending = VecDeque::new();
            let mut served = 0u64;
            let mut k = 0usize;
            while !stop.load(Ordering::SeqCst) {
                pending
                    .push_back((k, svc.submit("hot", hot_rhs.bs[k % hot_rhs.bs.len()].clone())?));
                if pending.len() >= FLOOD_WINDOW {
                    let (kk, handle) = pending.pop_front().expect("window is non-empty");
                    hot_rhs.verify(kk, &handle.wait()?.x, "hot reply")?;
                    served += 1;
                }
                k += 1;
            }
            for (kk, handle) in pending {
                hot_rhs.verify(kk, &handle.wait()?.x, "hot drain")?;
                served += 1;
            }
            Ok(served)
        })
    };

    // Let the flood build a steady hot backlog before probing.
    std::thread::sleep(std::time::Duration::from_millis(30));

    // Sequential cold probes in a seeded shuffle across the cold keys —
    // the "many cold keys" side of the skewed mix. Under round-robin the
    // probes to co-located keys queue behind the hot backlog; under cost
    // placement no cold key shares that shard.
    let mut rng = crate::util::XorShift64::new(0x5EED_5EE7);
    let mut latencies_ms = Vec::with_capacity(probe_count(scale));
    for p in 0..probe_count(scale) {
        let which = rng.range(0, cold_keys.len());
        let b = cold_rhs[which].bs[p % cold_rhs[which].bs.len()].clone();
        let t0 = Instant::now();
        let resp = svc.solve(&cold_keys[which], b)?;
        latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        cold_rhs[which].verify(p, &resp.x, "cold reply")?;
    }

    stop.store(true, Ordering::SeqCst);
    let hot_served = flooder.join().expect("flooder thread panicked")?;
    let row = SkewRow {
        mode: match placement {
            PlacementPolicy::Cost => "cost",
            PlacementPolicy::RoundRobin => "round_robin",
        },
        probes: latencies_ms.len() as u64,
        p50_ms: percentile(&mut latencies_ms.clone(), 0.50),
        p99_ms: percentile(&mut latencies_ms, 0.99),
        hot_served,
        colds_with_hot,
    };
    Arc::try_unwrap(svc)
        .ok()
        .expect("flooder joined; sole owner")
        .shutdown();
    Ok(row)
}

/// Nearest-rank percentile (q in [0, 1]) of `values`; sorts in place.
fn percentile(values: &mut [f64], q: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let idx = ((values.len() - 1) as f64 * q).ceil() as usize;
    values[idx.min(values.len() - 1)]
}

/// Run both placement modes and render the comparison. Round-robin runs
/// first (the baseline), each mode on a fresh service.
pub fn skew_compare(scale: &str) -> Result<(crate::util::Table, Vec<SkewRow>)> {
    let rows = vec![
        run_mode(PlacementPolicy::RoundRobin, scale)?,
        run_mode(PlacementPolicy::Cost, scale)?,
    ];
    let mut t = crate::util::Table::new(vec![
        "placement",
        "cold probes",
        "p50 ms",
        "p99 ms",
        "hot served",
        "colds w/ hot",
    ]);
    for r in &rows {
        t.row(vec![
            r.mode.to_string(),
            r.probes.to_string(),
            format!("{:.4}", r.p50_ms),
            format!("{:.4}", r.p99_ms),
            r.hot_served.to_string(),
            r.colds_with_hot.to_string(),
        ]);
    }
    Ok((t, rows))
}

/// Headline ratio the CI bench-regression gate watches: round-robin cold
/// p99 over cost-placement cold p99 (> 1 = cost placement protects the
/// cold tail under a skewed mix).
pub fn cold_p99_ratio(rows: &[SkewRow]) -> f64 {
    let rr = rows.iter().find(|r| r.mode == "round_robin");
    let cost = rows.iter().find(|r| r.mode == "cost");
    match (rr, cost) {
        (Some(r), Some(c)) => r.p99_ms / c.p99_ms.max(1e-9),
        _ => 1.0,
    }
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[SkewRow]) -> String {
    let mut out = String::from("{\n  \"experiment\": \"skew\",\n");
    out.push_str(&format!("  \"threads\": {SKEW_THREADS},\n"));
    out.push_str(&format!("  \"shards\": {SKEW_SHARDS},\n"));
    out.push_str(&format!(
        "  \"skew_p99_ratio\": {:.4},\n  \"rows\": [\n",
        cold_p99_ratio(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"probes\": {}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \
             \"hot_served\": {}, \"colds_with_hot\": {}}}{}\n",
            r.mode,
            r.probes,
            r.p50_ms,
            r.p99_ms,
            r.hot_served,
            r.colds_with_hot,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_skew.json`).
pub fn write_json(path: &Path, rows: &[SkewRow]) -> Result<()> {
    std::fs::write(path, render_json(rows)).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let mut v = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&mut v.clone(), 0.0), 1.0);
        assert_eq!(percentile(&mut v.clone(), 0.5), 3.0);
        assert_eq!(percentile(&mut v, 0.99), 5.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }

    #[test]
    fn json_is_well_formed_enough() {
        let rows = vec![
            SkewRow {
                mode: "round_robin",
                probes: 40,
                p50_ms: 1.0,
                p99_ms: 8.0,
                hot_served: 120,
                colds_with_hot: 2,
            },
            SkewRow {
                mode: "cost",
                probes: 40,
                p50_ms: 0.5,
                p99_ms: 2.0,
                hot_served: 115,
                colds_with_hot: 0,
            },
        ];
        let j = render_json(&rows);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"skew\""));
        assert!(j.contains("\"skew_p99_ratio\": 4.0000"));
        assert!(j.contains("\"colds_with_hot\": 0"));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let r = cold_p99_ratio(&rows);
        assert!((r - 4.0).abs() < 1e-9, "{r}");
        assert_eq!(cold_p99_ratio(&rows[..1]), 1.0, "missing mode = neutral");
    }

    /// End-to-end smoke at the dedicated `"tiny"` test scale: both
    /// placement modes run, every reply verifies bitwise (inside
    /// `run_mode`), cost placement provably keeps every cold key off the
    /// hot shard while round-robin provably co-locates some, and the
    /// ratio is a positive finite number. The *size* of the ratio is
    /// asserted by the CI gate against the pinned baseline, not here —
    /// unit tests on loaded machines would flake.
    #[test]
    fn skew_compare_smoke() {
        let (t, rows) = skew_compare("tiny").unwrap();
        assert_eq!(rows.len(), 2);
        let s = t.render();
        assert!(s.contains("round_robin") && s.contains("cost"));
        for r in &rows {
            assert!(r.probes > 0);
            assert!(r.p50_ms >= 0.0 && r.p99_ms >= r.p50_ms);
            assert!(r.hot_served > 0, "flood never completed a hot solve");
        }
        let rr = &rows[0];
        let cost = &rows[1];
        assert!(
            rr.colds_with_hot > 0,
            "round-robin placed no cold key with the hot key — the baseline lost its contention"
        );
        assert_eq!(cost.colds_with_hot, 0);
        let ratio = cold_p99_ratio(&rows);
        assert!(ratio.is_finite() && ratio > 0.0, "{ratio}");
    }
}
