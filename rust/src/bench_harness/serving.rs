//! Pool-reuse vs per-solve-spawn serving latency (`mgd bench serving`):
//! per-solve latency of the barrier-free MGD path when the worker pool is
//! a **persistent** backend pool (spawned once, parked between solves)
//! versus when every solve constructs a fresh backend and therefore pays
//! the thread-spawn cost — the regime PR 2 lived in with its per-solve
//! `thread::scope`. Emits the machine-readable `BENCH_serving.json`
//! artifact consumed by CI.
//!
//! The suite is deliberately **small**: spawn cost is a fixed tax of
//! O(threads) × ~100 µs, so it dominates exactly on small
//! latency-critical solves — the paper's repeated-solve serving regime.
//! Wide workloads (node-level parallelism engages the pool) are the
//! measurement; a serial chain control documents the clamping contract
//! (no workers engaged → no spawns in either mode → speedup ≈ 1).
//!
//! Every timed configuration is verified **bitwise** against
//! [`solve_serial`] first (the MGD contract), so the table cannot quietly
//! report a fast-but-wrong runtime.

use super::workloads::Workload;
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::triangular::solve_serial;
use crate::runtime::{
    LevelSolver, MgdPlanConfig, NativeBackend, NativeConfig, SchedulerKind, SolverBackend,
};
use crate::util::timing::bench_best;
use crate::util::Table;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Duration;

/// Worker-thread count both modes run with (fixed so the artifact is
/// comparable across machines with different core counts).
pub const SERVING_THREADS: usize = 4;

/// One workload's measurements (milliseconds per solve).
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// Workload name (`serial_*` rows are the clamping control).
    pub name: &'static str,
    /// Matrix order.
    pub n: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Medium nodes of the cached MGD plan.
    pub nodes: usize,
    /// Node-DAG width (worker parallelism the plan exposes).
    pub par_width: usize,
    /// Per-solve latency with a fresh backend (thread spawn) per solve.
    pub spawn_ms: f64,
    /// Per-solve latency on one persistent backend (parked pool).
    pub pool_ms: f64,
}

impl ServeRow {
    /// Speedup of the persistent pool over per-solve spawning
    /// (> 1 = pool-reuse wins).
    pub fn speedup(&self) -> f64 {
        self.spawn_ms / self.pool_ms.max(1e-12)
    }

    /// Rows whose plan exposes worker parallelism — the rows the pool
    /// can help; serial controls are excluded from the geomean.
    pub fn is_parallel(&self) -> bool {
        self.par_width > 1
    }
}

/// Serving-latency workloads: wide shallow DAGs (small solves with real
/// node parallelism) plus a serial chain control. `scale` ∈
/// {"small", "full"} sizes the matrices.
pub fn serving_suite(scale: &str) -> Vec<Workload> {
    let f = if scale == "small" { 1 } else { 4 };
    let mk = |name, matrix| Workload { name, matrix };
    vec![
        // Wide, tiny: the strongest spawn-dominated case.
        mk("wide_small", gen::shallow(1000 * f, 0.3, GenSeed(301))),
        // Wide, still small; more edges per node.
        mk("wide_medium", gen::shallow(2500 * f, 0.4, GenSeed(302))),
        // Denser scattered deps: heavier nodes, same shallow shape.
        mk("scatter", gen::shallow(1600 * f, 0.7, GenSeed(303))),
        // Serial control: a chain clamps to one worker, so neither mode
        // spawns anything and the ratio documents the no-op overhead.
        mk("serial_chain", gen::chain(1500 * f, GenSeed(304))),
    ]
}

fn native_cfg() -> NativeConfig {
    NativeConfig {
        threads: SERVING_THREADS,
        scheduler: SchedulerKind::Mgd,
        ..NativeConfig::default()
    }
}

/// Assert the backend's solve is bitwise equal to the serial reference.
fn verify_bitwise(backend: &NativeBackend, plan: &LevelSolver, w: &Workload) -> Result<()> {
    let b: Vec<f32> = (0..w.matrix.n).map(|i| (i % 7) as f32 - 3.0).collect();
    let x = backend.solve(plan, &b)?;
    let want = solve_serial(&w.matrix, &b);
    for i in 0..w.matrix.n {
        ensure!(
            x[i].to_bits() == want[i].to_bits(),
            "serving path not bitwise-serial on {} row {i}: {} vs {}",
            w.name,
            x[i],
            want[i],
        );
    }
    Ok(())
}

/// Measure one suite: per-solve latency with a persistent backend (pool
/// parked between solves) vs a fresh backend per solve (spawn per solve).
pub fn serving_compare(suite: &[Workload]) -> Result<(Table, Vec<ServeRow>)> {
    let mut t = Table::new(vec![
        "workload", "n", "nnz", "nodes", "width", "spawn ms", "pool ms", "speedup",
    ]);
    let mut rows = Vec::with_capacity(suite.len());
    for w in suite {
        let plan = LevelSolver::new(&w.matrix);
        let b: Vec<f32> = (0..w.matrix.n).map(|i| ((i + 1) % 9) as f32 - 4.0).collect();
        // Persistent mode: one backend for the whole loop; the warm solve
        // spawns the pool and builds the cached MGD plan, so the timed
        // region sees only park/wake costs.
        let pooled = NativeBackend::new(native_cfg());
        verify_bitwise(&pooled, &plan, w)?;
        let mut err: Option<anyhow::Error> = None;
        let pool_best = bench_best(
            || match pooled.solve(&plan, &b) {
                Ok(x) => x,
                Err(e) => {
                    err.get_or_insert(e);
                    Vec::new()
                }
            },
            2,
            Duration::from_millis(20),
        );
        if let Some(e) = err {
            return Err(e.context(format!("pooled timing loop failed on {}", w.name)));
        }
        // Spawn mode: every iteration constructs (and drops) a backend,
        // paying lazy pool spawn during the solve and the join on drop —
        // the per-solve-spawn lifecycle the persistent pool replaces.
        let mut err: Option<anyhow::Error> = None;
        let spawn_best = bench_best(
            || {
                let fresh = NativeBackend::new(native_cfg());
                match fresh.solve(&plan, &b) {
                    Ok(x) => x,
                    Err(e) => {
                        err.get_or_insert(e);
                        Vec::new()
                    }
                }
            },
            2,
            Duration::from_millis(20),
        );
        if let Some(e) = err {
            return Err(e.context(format!("spawn timing loop failed on {}", w.name)));
        }
        // The plan was cached by the verify solve; read its shape for the
        // report (same auto sizing both modes used).
        let mgd = plan.mgd_plan(MgdPlanConfig::auto(
            plan.n(),
            plan.num_levels(),
            SERVING_THREADS,
        ));
        let row = ServeRow {
            name: w.name,
            n: w.matrix.n,
            nnz: w.matrix.nnz(),
            nodes: mgd.num_nodes(),
            par_width: mgd.par_width,
            spawn_ms: spawn_best.as_secs_f64() * 1e3,
            pool_ms: pool_best.as_secs_f64() * 1e3,
        };
        t.row(vec![
            row.name.to_string(),
            row.n.to_string(),
            row.nnz.to_string(),
            row.nodes.to_string(),
            row.par_width.to_string(),
            format!("{:.4}", row.spawn_ms),
            format!("{:.4}", row.pool_ms),
            format!("{:.2}x", row.speedup()),
        ]);
        rows.push(row);
    }
    Ok((t, rows))
}

/// Geometric-mean pool-reuse speedup over the parallel rows (serial
/// controls excluded — neither mode spawns there).
pub fn parallel_geomean_speedup(rows: &[ServeRow]) -> f64 {
    let par: Vec<f64> = rows
        .iter()
        .filter(|r| r.is_parallel())
        .map(|r| r.speedup())
        .collect();
    if par.is_empty() {
        return 1.0;
    }
    (par.iter().map(|s| s.ln()).sum::<f64>() / par.len() as f64).exp()
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[ServeRow]) -> String {
    let mut out = String::from("{\n  \"experiment\": \"serving\",\n");
    out.push_str(&format!("  \"threads\": {SERVING_THREADS},\n"));
    out.push_str(&format!(
        "  \"parallel_geomean_speedup\": {:.4},\n  \"rows\": [\n",
        parallel_geomean_speedup(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"n\": {}, \"nnz\": {}, \"nodes\": {}, \
             \"par_width\": {}, \"parallel\": {}, \"spawn_ms\": {:.6}, \
             \"pool_ms\": {:.6}, \"speedup\": {:.4}}}{}\n",
            r.name,
            r.n,
            r.nnz,
            r.nodes,
            r.par_width,
            r.is_parallel(),
            r.spawn_ms,
            r.pool_ms,
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_serving.json`).
pub fn write_json(path: &Path, rows: &[ServeRow]) -> Result<()> {
    std::fs::write(path, render_json(rows)).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_suite() -> Vec<Workload> {
        vec![
            Workload {
                name: "wide_tiny",
                matrix: gen::shallow(600, 0.4, GenSeed(311)),
            },
            Workload {
                name: "serial_tiny",
                matrix: gen::chain(300, GenSeed(312)),
            },
        ]
    }

    #[test]
    fn compare_runs_verifies_and_classifies() {
        let (t, rows) = serving_compare(&tiny_suite()).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        let s = t.render();
        assert!(s.contains("spawn ms"));
        assert!(s.contains("pool ms"));
        assert!(rows[0].is_parallel(), "{rows:?}");
        assert!(!rows[1].is_parallel(), "chain must clamp serial: {rows:?}");
        for r in &rows {
            assert!(r.spawn_ms > 0.0 && r.pool_ms > 0.0);
        }
    }

    #[test]
    fn json_is_well_formed_enough() {
        let (_, rows) = serving_compare(&tiny_suite()).unwrap();
        let j = render_json(&rows);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"serving\""));
        assert!(j.contains("\"parallel_geomean_speedup\""));
        assert!(j.contains("\"workload\": \"wide_tiny\""));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn serving_suite_shapes_are_as_labeled() {
        let suite = serving_suite("small");
        assert_eq!(suite.len(), 4);
        for w in &suite {
            w.matrix.validate().unwrap();
            let plan = LevelSolver::new(&w.matrix);
            let mgd = plan.mgd_plan(MgdPlanConfig::auto(
                plan.n(),
                plan.num_levels(),
                SERVING_THREADS,
            ));
            if w.name.starts_with("serial_") {
                assert_eq!(mgd.par_width, 1, "{}", w.name);
            } else {
                assert!(mgd.par_width > 1, "{}: no parallelism to measure", w.name);
            }
        }
    }
}
