//! Named benchmark workloads: analogs of the paper's Table III rows plus
//! the 245-matrix scaling sweep of Fig. 12.
//!
//! Each analog matches its SuiteSparse namesake's order and nonzero count
//! (Table III columns 3–4) and is generated with the DAG-shape family that
//! matches the domain (circuit simulation, power networks, FEM meshes,
//! chemical engineering...). The CDU statistics land in the same regime,
//! which is what determines dataflow behaviour.

use crate::matrix::gen::{self, GenSeed};
use crate::matrix::CsrMatrix;

/// A named benchmark.
pub struct Workload {
    /// Analog name (`*_like` of the Table III row).
    pub name: &'static str,
    /// The generated matrix.
    pub matrix: CsrMatrix,
}

fn nnz_target(m: CsrMatrix, _target: usize) -> CsrMatrix {
    // Generators are parameterized to land near the target nnz; exactness
    // is not required (the metrics are ratios).
    m
}

/// The 20-benchmark suite mirroring Table III.
pub fn suite() -> Vec<Workload> {
    let mk = |name, matrix| Workload { name, matrix };
    vec![
        // bp_200: 822 rows, 2874 nnz — LP basis, skewed in-degree.
        mk("bp_200_like", nnz_target(gen::power_law(822, 1.35, 90, GenSeed(101)), 2874)),
        // west2021: 2021 rows, 6160 nnz — chemical engineering.
        mk("west2021_like", nnz_target(gen::circuit(2021, 2, 0.75, GenSeed(102)), 6160)),
        // HB_jagmesh4: 1440 rows, 22600 nnz — FEM mesh, dense band.
        mk("jagmesh4_like", nnz_target(gen::banded(1440, 24, 0.62, GenSeed(103)), 22600)),
        // rdb968: 968 rows, 16101 nnz — reaction-diffusion stencil.
        mk("rdb968_like", nnz_target(gen::banded(968, 26, 0.6, GenSeed(104)), 16101)),
        // dw2048: 2048 rows, 31909 nnz — dielectric waveguide band.
        mk("dw2048_like", nnz_target(gen::banded(2048, 24, 0.62, GenSeed(105)), 31909)),
        // ACTIVSg2000: 4000 rows, 42840 nnz — synthetic power grid factor.
        mk("activsg2000_like", nnz_target(gen::factor_like(4000, 14, 6, GenSeed(106)), 42840)),
        // cz628: 628 rows, 9123 nnz — closest-point chemistry, dense-ish.
        mk("cz628_like", nnz_target(gen::banded(628, 22, 0.62, GenSeed(107)), 9123)),
        // bips98_606: 7135 rows, 28759 nnz — power system dynamics.
        mk("bips98_606_like", nnz_target(gen::circuit(7135, 3, 0.8, GenSeed(108)), 28759)),
        // nnc1374: 1374 rows, 17897 nnz — nuclear reactor model.
        mk("nnc1374_like", nnz_target(gen::banded(1374, 20, 0.6, GenSeed(109)), 17897)),
        // add20: 2395 rows, 9867 nnz — circuit (adder) with hubs.
        mk("add20_like", nnz_target(gen::circuit(2395, 3, 0.8, GenSeed(110)), 9867)),
        // fpga_trans_01: 1220 rows, 5371 nnz — FPGA transient sim.
        mk("fpga_trans_01_like", nnz_target(gen::circuit(1220, 3, 0.85, GenSeed(111)), 5371)),
        // c-36: 7479 rows, 12186 nnz — optimization KKT, huge levels.
        mk("c36_like", nnz_target(gen::shallow(7479, 0.55, GenSeed(112)), 12186)),
        // circuit204: 1020 rows, 8008 nnz — circuit simulation.
        mk("circuit204_like", nnz_target(gen::circuit(1020, 7, 0.8, GenSeed(113)), 8008)),
        // gemat12: 4929 rows, 28415 nnz — power flow basis.
        mk("gemat12_like", nnz_target(gen::circuit(4929, 5, 0.75, GenSeed(114)), 28415)),
        // bayer07: 3268 rows, 26316 nnz — chemical process factor.
        mk("bayer07_like", nnz_target(gen::factor_like(3268, 10, 5, GenSeed(115)), 26316)),
        // rajat04: 1041 rows, 7625 nnz — circuit with extreme hubs (the
        // paper's load-imbalance case, load balance degree 97.6).
        mk("rajat04_like", nnz_target(gen::power_law(1041, 1.15, 160, GenSeed(116)), 7625)),
        // add32: 4960 rows, 14451 nnz — sparse adder circuit.
        mk("add32_like", nnz_target(gen::circuit(4960, 2, 0.85, GenSeed(117)), 14451)),
        // fpga_dcop_01: 1220 rows, 4303 nnz — FPGA DC operating point.
        mk("fpga_dcop_01_like", nnz_target(gen::circuit(1220, 2, 0.85, GenSeed(118)), 4303)),
        // bcsstm10: 1086 rows, 14546 nnz — structural mass matrix.
        mk("bcsstm10_like", nnz_target(gen::banded(1086, 26, 0.55, GenSeed(119)), 14546)),
        // rajat19: 1157 rows, 3956 nnz — circuit with hubs.
        mk("rajat19_like", nnz_target(gen::power_law(1157, 1.25, 110, GenSeed(120)), 3956)),
    ]
}

/// A reduced suite for quick runs (first `k` of the full suite).
pub fn suite_small(k: usize) -> Vec<Workload> {
    let mut s = suite();
    s.truncate(k);
    s
}

/// Scheduler-comparison workloads (`mgd bench schedulers`): deep/narrow
/// DAG shapes where per-level barriers dominate the level scheduler —
/// the regime the paper's medium-granularity dataflow targets — plus
/// wide controls where barriers amortize and the level path is at its
/// best. `scale` ∈ {"small", "full"} sizes the matrices.
pub fn scheduler_suite(scale: &str) -> Vec<Workload> {
    let f = if scale == "small" { 1 } else { 4 };
    let mk = |name, matrix| Workload { name, matrix };
    vec![
        // ~n levels of width 1: the worst case for one-barrier-per-level.
        mk("deep_chain", gen::chain(30_000 * f, GenSeed(201))),
        // High-locality circuit: thousands of levels a few rows wide.
        mk("deep_circuit", gen::circuit(20_000 * f, 3, 0.95, GenSeed(202))),
        // Tight band: a long dependency ladder, width ≈ bandwidth.
        mk("narrow_band", gen::banded(20_000 * f, 3, 0.9, GenSeed(203))),
        // 2-D wavefront: level width grows then shrinks along the sweep.
        mk("grid_wavefront", gen::grid2d(100 * f, 100 * f, true, GenSeed(204))),
        // Few huge levels: the level scheduler's best case (control).
        mk("wide_shallow", gen::shallow(30_000 * f, 0.4, GenSeed(205))),
        // Denser scattered deps, still log-depth: a second wide control.
        mk("wide_scatter", gen::shallow(20_000 * f, 0.7, GenSeed(206))),
    ]
}

/// The 245-benchmark sweep of Fig. 12: node counts from 19 to ~85k across
/// all generator families. Returns (name, matrix) pairs ordered by binary
/// node count like the paper's x-axis.
pub fn sweep_245(max_n: usize) -> Vec<Workload> {
    let mut out: Vec<Workload> = Vec::with_capacity(245);
    // 5 families × 49 sizes, log-spaced from 19 to max_n (default 85392).
    let sizes: Vec<usize> = (0..49)
        .map(|i| {
            let lo = (19f64).ln();
            let hi = (max_n as f64).ln();
            (lo + (hi - lo) * i as f64 / 48.0).exp().round() as usize
        })
        .collect();
    let names: [&'static str; 5] = ["circuit", "banded", "grid", "powerlaw", "shallow"];
    for (fi, fam) in names.iter().enumerate() {
        for (si, &n) in sizes.iter().enumerate() {
            let seed = GenSeed((1000 + fi * 100 + si) as u64);
            let m = match fi {
                0 => gen::circuit(n.max(4), 4, 0.8, seed),
                1 => gen::banded(n.max(4), (n / 64).clamp(2, 24), 0.6, seed),
                2 => {
                    let side = (n as f64).sqrt().ceil() as usize;
                    gen::grid2d(side.max(2), side.max(2), si % 2 == 0, seed)
                }
                3 => gen::power_law(n.max(4), 1.2, (n / 8).clamp(4, 200), seed),
                _ => gen::shallow(n.max(4), 0.4, seed),
            };
            out.push(Workload {
                name: fam,
                matrix: m,
            });
        }
    }
    out.sort_by_key(|w| w.matrix.binary_nodes());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::graph::{Dag, DagStats, Levels};

    #[test]
    fn suite_has_20_named_workloads() {
        let s = suite();
        assert_eq!(s.len(), 20);
        for w in &s {
            w.matrix.validate().unwrap();
        }
    }

    #[test]
    fn suite_sizes_match_table3_orders() {
        let s = suite();
        let expect = [
            ("bp_200_like", 822),
            ("dw2048_like", 2048),
            ("c36_like", 7479),
            ("rajat04_like", 1041),
        ];
        for (name, n) in expect {
            let w = s.iter().find(|w| w.name == name).unwrap();
            assert_eq!(w.matrix.n, n);
        }
    }

    #[test]
    fn c36_analog_has_no_cdu_levels() {
        // Table III row 12: c-36 has 0.0% CDU nodes.
        let s = suite();
        let w = s.iter().find(|w| w.name == "c36_like").unwrap();
        let g = Dag::from_csr(&w.matrix);
        let lv = Levels::compute(&g);
        let st = DagStats::compute(&g, &lv, ArchConfig::default().num_cus());
        assert!(st.cdu_nodes_pct < 2.0, "{}", st.cdu_nodes_pct);
    }

    #[test]
    fn banded_analogs_are_cdu_heavy() {
        // Table III: dw2048 has 86.6% CDU edges.
        let s = suite();
        let w = s.iter().find(|w| w.name == "dw2048_like").unwrap();
        let g = Dag::from_csr(&w.matrix);
        let lv = Levels::compute(&g);
        let st = DagStats::compute(&g, &lv, 64);
        assert!(st.cdu_edges_pct > 50.0, "{}", st.cdu_edges_pct);
    }

    #[test]
    fn sweep_covers_the_size_range() {
        let sweep = sweep_245(20000); // reduced max for test speed
        assert_eq!(sweep.len(), 245);
        let first = sweep.first().unwrap().matrix.binary_nodes();
        let last = sweep.last().unwrap().matrix.binary_nodes();
        assert!(first < 200, "{first}");
        assert!(last > 20000, "{last}");
        // Sorted by binary nodes (the paper's x-axis).
        for w in sweep.windows(2) {
            assert!(w[0].matrix.binary_nodes() <= w[1].matrix.binary_nodes());
        }
    }
}
