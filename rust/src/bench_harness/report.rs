//! Experiment runner used by the CLI and the `cargo bench` targets: maps an
//! experiment id (DESIGN.md §3) to its harness and prints the rows.

use super::{
    admission, backends, concurrency, fig10, fig11, fig9, schedulers, serving, skew, streaming,
    tables, workloads,
};
use crate::arch::ArchConfig;
use anyhow::{bail, Result};

/// Run one experiment by id; `scale` ∈ {"small", "full"} controls the
/// workload count so CI stays fast.
pub fn run_experiment(id: &str, scale: &str) -> Result<String> {
    let arch = ArchConfig::default();
    let suite = match scale {
        "small" => workloads::suite_small(6),
        _ => workloads::suite(),
    };
    let out = match id {
        "fig9a" => fig9::fig9a(&suite, &arch)?.0.render(),
        "fig9bc" => fig9::fig9bc(&suite, &arch, &[0, 1, 2, 4, 8, 16])?.render(),
        "fig9def" => fig9::fig9def(&suite, &arch)?.render(),
        "fig10" => fig10::fig10(&suite, &arch)?.0.render(),
        "fig11" => {
            let (t, rows) = fig11::compare(&suite, &arch, 3)?;
            format!("{}\n{}", t.render(), fig11::speedup_summary(&rows).render())
        }
        "fig12" => {
            let max_n = if scale == "small" { 8_000 } else { 85_392 };
            let sweep = workloads::sweep_245(max_n);
            let (t, rows) = fig11::compare(&sweep, &arch, 1)?;
            format!("{}\n{}", t.render(), fig11::speedup_summary(&rows).render())
        }
        "backends" => backends::backend_compare(&suite, 8)?.render(),
        "schedulers" => {
            let sched_suite = workloads::scheduler_suite(scale);
            let rhs = 8;
            let (t, rows) = schedulers::scheduler_compare(&sched_suite, rhs)?;
            let json_path = std::path::Path::new("BENCH_schedulers.json");
            schedulers::write_json(json_path, &rows, rhs)?;
            format!(
                "{}\ndeep/narrow geomean speedup (mgd over level): {:.2}x\n\
                 wrote {}",
                t.render(),
                schedulers::deep_geomean_speedup(&rows),
                json_path.display(),
            )
        }
        "serving" => {
            let serve_suite = serving::serving_suite(scale);
            let (t, rows) = serving::serving_compare(&serve_suite)?;
            let json_path = std::path::Path::new("BENCH_serving.json");
            serving::write_json(json_path, &rows)?;
            format!(
                "{}\nparallel-workload geomean speedup (persistent pool over per-solve spawn): {:.2}x\n\
                 wrote {}",
                t.render(),
                serving::parallel_geomean_speedup(&rows),
                json_path.display(),
            )
        }
        "concurrency" => {
            let conc_suite = concurrency::concurrency_suite(scale);
            let (t, rows) = concurrency::concurrency_compare(&conc_suite)?;
            let json_path = std::path::Path::new("BENCH_concurrency.json");
            concurrency::write_json(json_path, &rows)?;
            format!(
                "{}\noverlapped-submitters geomean speedup (concurrent sessions over serialized): {:.2}x\n\
                 wrote {}",
                t.render(),
                concurrency::overlap_geomean_speedup(&rows),
                json_path.display(),
            )
        }
        "admission" => {
            let (t, rows) = admission::admission_compare(scale)?;
            let json_path = std::path::Path::new("BENCH_admission.json");
            admission::write_json(json_path, &rows)?;
            format!(
                "{}\nlatency-probe p99 ratio (first-come over by-class admission): {:.2}x\n\
                 wrote {}",
                t.render(),
                admission::latency_p99_ratio(&rows),
                json_path.display(),
            )
        }
        "skew" => {
            let (t, rows) = skew::skew_compare(scale)?;
            let json_path = std::path::Path::new("BENCH_skew.json");
            skew::write_json(json_path, &rows)?;
            format!(
                "{}\ncold-probe p99 ratio (round-robin over cost placement): {:.2}x\n\
                 wrote {}",
                t.render(),
                skew::cold_p99_ratio(&rows),
                json_path.display(),
            )
        }
        "streaming" => {
            let stream_suite = streaming::streaming_suite(scale);
            let steps = if scale == "small" { 64 } else { 256 };
            let (t, rows) =
                streaming::streaming_compare(&stream_suite, steps, streaming::SESSION_DEPTH)?;
            let json_path = std::path::Path::new("BENCH_streaming.json");
            streaming::write_json(json_path, &rows)?;
            format!(
                "{}\npipelined-session geomean speedup (streaming session over call-per-solve): {:.2}x\n\
                 wrote {}",
                t.render(),
                streaming::pipelined_speedup(&rows),
                json_path.display(),
            )
        }
        "table2" => tables::table2(&suite, &arch)?.render(),
        "table3" => tables::table3(&suite, &arch)?.render(),
        "table4" => {
            let (_, rows) = fig11::compare(&suite, &arch, 3)?;
            // Average compile time over the suite.
            let mut total = 0.0;
            for w in &suite {
                let cfg = crate::compiler::CompilerConfig {
                    arch,
                    ..Default::default()
                };
                total += crate::compiler::compile(&w.matrix, &cfg)?
                    .compile
                    .compile_seconds;
            }
            tables::table4(&rows, &arch, total / suite.len() as f64).render()
        }
        other => bail!("unknown experiment id {other} (see DESIGN.md §3)"),
    };
    Ok(out)
}

/// All experiment ids, in paper order.
pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig9a",
    "fig9bc",
    "fig9def",
    "fig10",
    "fig11",
    "fig12",
    "table2",
    "table3",
    "table4",
    "backends",
    "schedulers",
    "serving",
    "concurrency",
    "admission",
    "streaming",
    "skew",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run_experiment("fig99", "small").is_err());
    }

    #[test]
    fn fig10_small_runs() {
        let s = run_experiment("fig10", "small").unwrap();
        assert!(s.contains("exec%"));
    }
}
