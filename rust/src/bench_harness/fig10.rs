//! Fig. 10: instruction breakdown (execute / Bnop / Pnop / Dnop / Lnop).

use super::workloads::Workload;
use crate::arch::ArchConfig;
use crate::compiler::{schedule_only, CompilerConfig};
use crate::util::Table;
use anyhow::Result;

/// One benchmark's instruction mix (fractions summing to 1).
#[derive(Debug, Clone)]
pub struct Fig10Row {
    /// Workload name.
    pub name: &'static str,
    /// Executed fraction.
    pub exec: f64,
    /// Bank-conflict nops.
    pub bnop: f64,
    /// psum-capacity nops.
    pub pnop: f64,
    /// Dependency nops.
    pub dnop: f64,
    /// Load-imbalance nops.
    pub lnop: f64,
}

/// Compute the Fig. 10 breakdown for the suite.
pub fn fig10(suite: &[Workload], arch: &ArchConfig) -> Result<(Table, Vec<Fig10Row>)> {
    let mut table = Table::new(vec!["benchmark", "exec%", "Bnop%", "Pnop%", "Dnop%", "Lnop%"]);
    let mut rows = Vec::new();
    for w in suite {
        let cfg = CompilerConfig {
            arch: *arch,
            ..CompilerConfig::default()
        };
        let s = schedule_only(&w.matrix, &cfg)?;
        let slots = (s.stats.cycles * arch.num_cus() as u64) as f64;
        let row = Fig10Row {
            name: w.name,
            exec: s.stats.exec as f64 / slots,
            bnop: s.stats.bnop as f64 / slots,
            pnop: s.stats.pnop as f64 / slots,
            dnop: s.stats.dnop as f64 / slots,
            lnop: s.stats.lnop as f64 / slots,
        };
        table.row(vec![
            w.name.to_string(),
            format!("{:.1}", 100.0 * row.exec),
            format!("{:.1}", 100.0 * row.bnop),
            format!("{:.1}", 100.0 * row.pnop),
            format!("{:.1}", 100.0 * row.dnop),
            format!("{:.1}", 100.0 * row.lnop),
        ]);
        rows.push(row);
    }
    Ok((table, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::workloads::suite_small;

    #[test]
    fn fractions_sum_to_one() {
        let (_, rows) = fig10(&suite_small(4), &ArchConfig::default()).unwrap();
        for r in rows {
            let total = r.exec + r.bnop + r.pnop + r.dnop + r.lnop;
            assert!((total - 1.0).abs() < 1e-9, "{}: {total}", r.name);
            assert!(r.exec > 0.0);
        }
    }
}
