//! Native-vs-PJRT backend comparison: per-workload solve latency on each
//! available [`SolverBackend`], scalar and batched (the ROADMAP's
//! multi-backend scaling angle).
//!
//! The native executor always reports; the PJRT column reads `n/a` unless
//! the crate was built with `--features pjrt` *and* the AOT artifacts
//! load. Every timed solve is first checked against the serial reference,
//! so the table cannot quietly report a fast-but-wrong backend.

use super::workloads::Workload;
use crate::matrix::triangular::max_relative_residual;
use crate::runtime::{LevelSolver, NativeBackend, NativeConfig, SolverBackend};
use crate::util::timing::bench_best;
use crate::util::Table;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::Duration;

/// Time one backend on one plan: verified solve, then best-of latency for
/// a scalar solve and a batched `rhs`-wide solve (per-RHS).
fn time_backend(
    backend: &dyn SolverBackend,
    plan: &LevelSolver,
    w: &Workload,
    rhs: usize,
) -> Result<(f64, f64)> {
    let b: Vec<f32> = (0..w.matrix.n).map(|i| (i % 7) as f32 - 3.0).collect();
    let x = backend.solve(plan, &b)?;
    let resid = max_relative_residual(&w.matrix, &x, &b);
    ensure!(
        resid < 1e-3,
        "{} backend wrong on {} (residual {resid:.2e})",
        backend.name(),
        w.name
    );
    // A timed iteration that errors would otherwise register as a (bogus)
    // fast latency — capture the first failure and surface it.
    let mut err: Option<anyhow::Error> = None;
    let scalar = bench_best(
        || match backend.solve(plan, &b) {
            Ok(x) => x,
            Err(e) => {
                err.get_or_insert(e);
                Vec::new()
            }
        },
        2,
        Duration::from_millis(20),
    );
    if let Some(e) = err {
        return Err(e.context(format!("{} timing loop failed on {}", backend.name(), w.name)));
    }
    let bs: Vec<Vec<f32>> = (0..rhs)
        .map(|k| (0..w.matrix.n).map(|i| ((i + k) % 9) as f32 - 4.0).collect())
        .collect();
    let mut err: Option<anyhow::Error> = None;
    let batched = bench_best(
        || match backend.solve_multi(plan, &bs) {
            Ok(xs) => xs,
            Err(e) => {
                err.get_or_insert(e);
                Vec::new()
            }
        },
        2,
        Duration::from_millis(20),
    );
    if let Some(e) = err {
        return Err(e.context(format!(
            "{} batched timing loop failed on {}",
            backend.name(),
            w.name
        )));
    }
    Ok((
        scalar.as_secs_f64() * 1e3,
        batched.as_secs_f64() * 1e3 / rhs as f64,
    ))
}

/// Build the comparison table over `suite`, batching `rhs` RHS per
/// multi-solve round.
pub fn backend_compare(suite: &[Workload], rhs: usize) -> Result<Table> {
    let native: Arc<dyn SolverBackend> = Arc::new(NativeBackend::new(NativeConfig::default()));
    let pjrt = pjrt_backend();
    let mut t = Table::new(vec![
        "workload".to_string(),
        "n".to_string(),
        "nnz".to_string(),
        "levels".to_string(),
        "native ms".to_string(),
        format!("native ms/rhs (x{rhs})"),
        "pjrt ms".to_string(),
        format!("pjrt ms/rhs (x{rhs})"),
    ]);
    for w in suite {
        let plan = LevelSolver::new(&w.matrix);
        let (n_scalar, n_batched) = time_backend(native.as_ref(), &plan, w, rhs)?;
        let (p_scalar, p_batched) = match &pjrt {
            Some(p) => {
                let (s, b) = time_backend(p.as_ref(), &plan, w, rhs)?;
                (format!("{s:.3}"), format!("{b:.3}"))
            }
            None => ("n/a".to_string(), "n/a".to_string()),
        };
        t.row(vec![
            w.name.to_string(),
            w.matrix.n.to_string(),
            w.matrix.nnz().to_string(),
            plan.num_levels().to_string(),
            format!("{n_scalar:.3}"),
            format!("{n_batched:.3}"),
            p_scalar,
            p_batched,
        ]);
    }
    Ok(t)
}

/// The PJRT backend, when the feature is compiled in and artifacts load.
/// Uses the crate-relative `rust/artifacts` convention shared with
/// `client.rs` and `benches/micro.rs` so the column resolves regardless of
/// the invocation directory.
fn pjrt_backend() -> Option<Arc<dyn SolverBackend>> {
    #[cfg(feature = "pjrt")]
    {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if let Ok(b) = crate::runtime::PjrtBackend::load(&dir) {
            return Some(Arc::new(b));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::workloads;

    #[test]
    fn compare_runs_on_a_small_suite() {
        let suite = workloads::suite_small(2);
        let t = backend_compare(&suite, 4).unwrap();
        assert_eq!(t.len(), 2);
        let s = t.render();
        assert!(s.contains("native ms"));
        assert!(s.contains("pjrt"));
    }
}
