//! Pipelined-session vs call-per-solve serving (`mgd bench streaming`):
//! the circuit-transient workload — one factor, a long stream of
//! time-step RHS — solved through a [`SolveSession`] (admission paid
//! once, up to `depth` solves in flight) versus one blocking
//! [`SolveService::solve`] round trip per RHS. Emits the
//! machine-readable `BENCH_streaming.json` artifact consumed by CI.
//!
//! Why the session wins: call-per-solve serializes the full service
//! round trip — enqueue, worker wake, scalar solve, reply, caller wake —
//! so the queue is empty every time a worker looks at it. A session
//! keeps the next RHS already queued, which lets [`ShardQueue::pop`]'s
//! group extension batch same-matrix neighbors through the backend's
//! multi-RHS path and overlap solve N's reply/epilogue with N+1's
//! gather. The headline `pipelined_speedup` is the geometric mean over
//! the suite.
//!
//! Every configuration is verified **bitwise** against [`solve_serial`]
//! before timing — both modes, every streamed reply — so the table
//! cannot quietly report a fast-but-wrong pipeline.
//!
//! [`ShardQueue::pop`]: crate::coordinator::service
//! [`SolveSession`]: crate::coordinator::SolveSession

use super::workloads::Workload;
use crate::coordinator::{ServiceConfig, SolveService};
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::triangular::solve_serial;
use crate::runtime::{BackendConfig, BackendKind, NativeConfig, SchedulerKind};
use crate::util::timing::bench_best;
use crate::util::Table;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Duration;

/// Pool worker-thread count both modes run with (fixed so the artifact
/// is comparable across machines with different core counts).
pub const STREAMING_THREADS: usize = 4;

/// In-session pipeline depth the pipelined mode runs with.
pub const SESSION_DEPTH: usize = 8;

/// One workload's measurements (milliseconds per solve).
#[derive(Debug, Clone)]
pub struct StreamRow {
    /// Workload name.
    pub name: &'static str,
    /// Matrix order.
    pub n: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// Time steps streamed per timed iteration.
    pub steps: usize,
    /// Session pipeline depth of the pipelined mode.
    pub depth: usize,
    /// Per-solve latency of one blocking call per RHS.
    pub call_ms: f64,
    /// Per-solve latency of the pipelined session.
    pub pipelined_ms: f64,
}

impl StreamRow {
    /// Speedup of the pipelined session over call-per-solve
    /// (> 1 = pipelining wins).
    pub fn speedup(&self) -> f64 {
        self.call_ms / self.pipelined_ms.max(1e-12)
    }
}

/// Circuit-transient workloads (`gen::circuit`: geometric in-degree,
/// local wiring — the paper's motivating application). `scale` ∈
/// {"small", "full"} sizes the matrices.
pub fn streaming_suite(scale: &str) -> Vec<Workload> {
    let f = if scale == "small" { 1 } else { 4 };
    let mk = |name, matrix| Workload { name, matrix };
    vec![
        // The example's shape: mid-size, moderately local.
        mk("transient_mid", gen::circuit(1000 * f, 5, 0.8, GenSeed(401))),
        // Larger net with sparser coupling: more steps outweigh setup.
        mk("transient_wide", gen::circuit(2400 * f, 4, 0.7, GenSeed(402))),
        // Denser coupling: heavier solves, batching has more to amortize.
        mk("transient_dense", gen::circuit(1500 * f, 8, 0.9, GenSeed(403))),
    ]
}

fn service_cfg() -> ServiceConfig {
    ServiceConfig {
        workers: 2,
        batch_size: SESSION_DEPTH,
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                threads: STREAMING_THREADS,
                scheduler: SchedulerKind::Mgd,
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        ..ServiceConfig::default()
    }
}

/// The transient time-step RHS stream: step `t`'s vector is a smooth
/// perturbation, deterministic so references can be precomputed.
fn step_rhs(n: usize, t: usize) -> Vec<f32> {
    (0..n)
        .map(|i| 1.0 + 0.2 * ((i as f32) * 0.01 + (t as f32) * 0.05).sin())
        .collect()
}

/// Bitwise check of one reply stream against the serial references.
fn verify_stream(name: &str, mode: &str, xs: &[Vec<f32>], want: &[Vec<f32>]) -> Result<()> {
    ensure!(
        xs.len() == want.len(),
        "{mode} on {name}: {} replies for {} steps",
        xs.len(),
        want.len(),
    );
    for (t, (x, w)) in xs.iter().zip(want).enumerate() {
        for i in 0..w.len() {
            ensure!(
                x[i].to_bits() == w[i].to_bits(),
                "{mode} not bitwise-serial on {name} step {t} row {i}: {} vs {}",
                x[i],
                w[i],
            );
        }
    }
    Ok(())
}

/// Measure one suite: `steps` RHS per iteration, call-per-solve vs a
/// pipelined session of the given `depth`. Both modes are bitwise-
/// verified against [`solve_serial`] on every step before timing.
pub fn streaming_compare(
    suite: &[Workload],
    steps: usize,
    depth: usize,
) -> Result<(Table, Vec<StreamRow>)> {
    let mut t = Table::new(vec![
        "workload", "n", "nnz", "steps", "depth", "call ms", "pipelined ms", "speedup",
    ]);
    let mut rows = Vec::with_capacity(suite.len());
    for w in suite {
        let svc = SolveService::start(&w.matrix, service_cfg())
            .with_context(|| format!("start service for {}", w.name))?;
        let bs: Vec<Vec<f32>> = (0..steps).map(|s| step_rhs(w.matrix.n, s)).collect();
        let want: Vec<Vec<f32>> = bs.iter().map(|b| solve_serial(&w.matrix, b)).collect();
        // Verification pass: both modes must stream bitwise-serial
        // replies before either is timed.
        let xs: Vec<Vec<f32>> = bs
            .iter()
            .map(|b| svc.solve(b.clone()).map(|r| r.x))
            .collect::<Result<_>>()
            .with_context(|| format!("call-per-solve verify on {}", w.name))?;
        verify_stream(w.name, "call-per-solve", &xs, &want)?;
        let mut session = svc.open_session(depth)?;
        for b in &bs {
            session.submit(b.clone())?;
        }
        let xs: Vec<Vec<f32>> = session
            .drain()
            .into_iter()
            .map(|r| r.map(|resp| resp.x))
            .collect::<Result<_>>()
            .with_context(|| format!("pipelined verify on {}", w.name))?;
        verify_stream(w.name, "pipelined-session", &xs, &want)?;
        drop(session);
        // Call-per-solve: one blocking round trip per RHS.
        let mut err: Option<anyhow::Error> = None;
        let call_best = bench_best(
            || {
                let mut last = 0.0f32;
                for b in &bs {
                    match svc.solve(b.clone()) {
                        Ok(r) => last = r.x[0],
                        Err(e) => {
                            err.get_or_insert(e);
                        }
                    }
                }
                last
            },
            2,
            Duration::from_millis(20),
        );
        if let Some(e) = err {
            return Err(e.context(format!("call-per-solve timing loop failed on {}", w.name)));
        }
        // Pipelined: one session per iteration, every RHS submitted
        // through the bounded pipeline, then drained.
        let mut err: Option<anyhow::Error> = None;
        let pipe_best = bench_best(
            || {
                let mut last = 0.0f32;
                let run = || -> Result<f32> {
                    let mut session = svc.open_session(depth)?;
                    for b in &bs {
                        session.submit(b.clone())?;
                    }
                    let mut out = 0.0f32;
                    for reply in session.drain() {
                        out = reply?.x[0];
                    }
                    Ok(out)
                };
                match run() {
                    Ok(x) => last = x,
                    Err(e) => {
                        err.get_or_insert(e);
                    }
                }
                last
            },
            2,
            Duration::from_millis(20),
        );
        if let Some(e) = err {
            return Err(e.context(format!("pipelined timing loop failed on {}", w.name)));
        }
        let row = StreamRow {
            name: w.name,
            n: w.matrix.n,
            nnz: w.matrix.nnz(),
            steps,
            depth,
            call_ms: call_best.as_secs_f64() * 1e3 / steps as f64,
            pipelined_ms: pipe_best.as_secs_f64() * 1e3 / steps as f64,
        };
        t.row(vec![
            row.name.to_string(),
            row.n.to_string(),
            row.nnz.to_string(),
            row.steps.to_string(),
            row.depth.to_string(),
            format!("{:.4}", row.call_ms),
            format!("{:.4}", row.pipelined_ms),
            format!("{:.2}x", row.speedup()),
        ]);
        rows.push(row);
        svc.shutdown();
    }
    Ok((t, rows))
}

/// Geometric-mean pipelined-session speedup over the suite — the
/// headline ratio CI gates (`ci/bench_baselines/streaming.json`).
pub fn pipelined_speedup(rows: &[StreamRow]) -> f64 {
    if rows.is_empty() {
        return 1.0;
    }
    (rows.iter().map(|r| r.speedup().ln()).sum::<f64>() / rows.len() as f64).exp()
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[StreamRow]) -> String {
    let mut out = String::from("{\n  \"experiment\": \"streaming\",\n");
    out.push_str(&format!("  \"threads\": {STREAMING_THREADS},\n"));
    out.push_str(&format!(
        "  \"pipelined_speedup\": {:.4},\n  \"rows\": [\n",
        pipelined_speedup(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"workload\": \"{}\", \"n\": {}, \"nnz\": {}, \"steps\": {}, \
             \"depth\": {}, \"call_ms\": {:.6}, \"pipelined_ms\": {:.6}, \
             \"speedup\": {:.4}}}{}\n",
            r.name,
            r.n,
            r.nnz,
            r.steps,
            r.depth,
            r.call_ms,
            r.pipelined_ms,
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_streaming.json`).
pub fn write_json(path: &Path, rows: &[StreamRow]) -> Result<()> {
    std::fs::write(path, render_json(rows)).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_suite() -> Vec<Workload> {
        vec![Workload {
            name: "transient_tiny",
            matrix: gen::circuit(250, 4, 0.8, GenSeed(411)),
        }]
    }

    #[test]
    fn compare_runs_and_verifies_bitwise() {
        let (t, rows) = streaming_compare(&tiny_suite(), 8, 4).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(rows.len(), 1);
        let s = t.render();
        assert!(s.contains("call ms"));
        assert!(s.contains("pipelined ms"));
        for r in &rows {
            assert!(r.call_ms > 0.0 && r.pipelined_ms > 0.0, "{rows:?}");
            assert_eq!(r.steps, 8);
            assert_eq!(r.depth, 4);
        }
    }

    #[test]
    fn json_is_well_formed_enough() {
        let (_, rows) = streaming_compare(&tiny_suite(), 6, 2).unwrap();
        let j = render_json(&rows);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"streaming\""));
        assert!(j.contains("\"pipelined_speedup\""));
        assert!(j.contains("\"workload\": \"transient_tiny\""));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn streaming_suite_is_circuit_shaped() {
        let suite = streaming_suite("small");
        assert_eq!(suite.len(), 3);
        for w in &suite {
            w.matrix.validate().unwrap();
            assert!(w.name.starts_with("transient_"), "{}", w.name);
        }
    }
}
