//! Latency-class tail latency under a bulk flood (`mgd bench admission`):
//! p50/p99 of latency-critical probe requests while bulk submitters keep
//! the shard queue saturated, measured twice on the same traffic shape —
//! once through the **first-come** front end (unbounded single-priority
//! queueing: probes ride the bulk lane, nothing is reserved) and once
//! through the **by-class** admission stack (bounded lanes, bulk shed at
//! the queue cap, probes in the latency lane, one pool worker reserved
//! for latency sessions). Emits the machine-readable
//! `BENCH_admission.json` artifact consumed by CI's bench-regression
//! gate; the headline is the first-come-over-by-class p99 ratio (> 1 =
//! the admission stack protects the tail).
//!
//! The bench also *enforces* the admission invariants while it runs:
//! every admitted reply is verified **bitwise** against
//! [`solve_serial`] (the MGD contract — shedding must never corrupt the
//! numerics of what it admits), and the observed per-shard queue depth
//! must never exceed the configured cap.

use crate::coordinator::{Admission, AdmissionPolicy, ShardedServiceConfig, ShardedSolveService};
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::triangular::solve_serial;
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::runtime::{BackendConfig, BackendKind, NativeConfig, RequestClass, SchedulerKind};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Worker-thread count of the shared native backend (fixed so the
/// artifact is comparable across machines with different core counts).
pub const ADMISSION_THREADS: usize = 4;

/// Per-lane queue cap of the by-class mode (the first-come baseline runs
/// unbounded, which is exactly the regime being measured against).
pub const QUEUE_CAP: usize = 16;

/// Bulk requests each flooder keeps outstanding (in queue or in
/// service). Two flooders × this window comfortably exceeds
/// [`QUEUE_CAP`], so the bounded mode visibly sheds.
const FLOOD_WINDOW: usize = 16;

/// Flooder threads saturating the bulk lane.
const FLOODERS: usize = 2;

/// One mode's measurements.
#[derive(Debug, Clone)]
pub struct AdmissionRow {
    /// `"first_come"` (unbounded, single priority) or `"by_class"`
    /// (bounded lanes + latency reserve).
    pub mode: &'static str,
    /// Latency-class probe requests measured.
    pub probes: u64,
    /// Median probe latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile probe latency, milliseconds.
    pub p99_ms: f64,
    /// Bulk requests served to completion during the run.
    pub bulk_served: u64,
    /// Bulk requests shed at admission (0 in the unbounded mode).
    pub bulk_shed: u64,
    /// Deepest queue lane observed on the shard.
    pub peak_queue_depth: u64,
    /// The lane cap this mode ran under (0 = unbounded).
    pub queue_cap: u64,
}

/// The two matrices of the traffic mix: a bulk workload large enough
/// that a backlog of them dominates an unprotected queue, and a small
/// latency-critical probe. Both are shallow scattered-dependency DAGs so
/// every solve opens a real multi-worker MGD pool session. `"tiny"` is
/// the unit-test scale (seconds of `cargo test` budget, not a
/// measurement); CI and the CLI use `"small"`/`"full"`.
fn suite(scale: &str) -> (CsrMatrix, CsrMatrix) {
    let (bulk_n, probe_n) = match scale {
        "tiny" => (800, 300),
        "small" => (2400, 600),
        _ => (4800, 600),
    };
    let bulk = gen::shallow(bulk_n, 0.4, GenSeed(501));
    let probe = gen::shallow(probe_n, 0.4, GenSeed(502));
    (bulk, probe)
}

/// Probe request count per mode.
fn probe_count(scale: &str) -> usize {
    match scale {
        "tiny" => 8,
        "small" => 30,
        _ => 80,
    }
}

fn service_config(by_class: bool) -> ShardedServiceConfig {
    ShardedServiceConfig {
        shards: 1,
        workers_per_shard: 2,
        batch_size: 4,
        backend: BackendConfig {
            kind: BackendKind::Native,
            native: NativeConfig {
                threads: ADMISSION_THREADS,
                scheduler: SchedulerKind::Mgd,
                reserved_latency_workers: if by_class { 1 } else { 0 },
                ..NativeConfig::default()
            },
            ..BackendConfig::default()
        },
        queue_cap: if by_class { QUEUE_CAP } else { 0 },
        admission: if by_class {
            AdmissionPolicy::ByClass
        } else {
            AdmissionPolicy::Block
        },
        ..ShardedServiceConfig::default()
    }
}

/// A fixed cycle of RHS vectors with their precomputed bitwise
/// references, so flooders and probes can verify every reply cheaply.
struct VerifiedRhs {
    bs: Vec<Vec<f32>>,
    refs: Vec<Vec<f32>>,
}

impl VerifiedRhs {
    fn new(m: &CsrMatrix, variants: usize, salt: usize) -> Self {
        let bs: Vec<Vec<f32>> = (0..variants)
            .map(|k| {
                (0..m.n)
                    .map(|i| ((i + 3 * k + salt) % 9) as f32 - 4.0)
                    .collect()
            })
            .collect();
        let refs = bs.iter().map(|b| solve_serial(m, b)).collect();
        Self { bs, refs }
    }

    fn verify(&self, k: usize, x: &[f32], what: &str) -> Result<()> {
        let want = &self.refs[k % self.refs.len()];
        ensure!(x.len() == want.len(), "{what}: wrong solution length");
        for i in 0..want.len() {
            ensure!(
                x[i].to_bits() == want[i].to_bits(),
                "{what}: reply not bitwise-serial at row {i}"
            );
        }
        Ok(())
    }
}

/// Run one mode: flood the bulk lane from [`FLOODERS`] threads while the
/// main thread issues sequential latency probes, each timed and verified
/// bitwise. Returns the row.
fn run_mode(by_class: bool, scale: &str) -> Result<AdmissionRow> {
    let (bulk_m, probe_m) = suite(scale);
    let svc = Arc::new(
        ShardedSolveService::start(service_config(by_class)).context("start admission service")?,
    );
    // Both keys on the one shard: the whole point is that they contend
    // for the same queue. The probe key defaults to Latency in by-class
    // mode — the per-key default set at registration, not per request.
    svc.register("bulk", &bulk_m)?;
    if by_class {
        svc.register_with_class("probe", &probe_m, RequestClass::Latency)?;
    } else {
        svc.register("probe", &probe_m)?;
    }
    let bulk_rhs = Arc::new(VerifiedRhs::new(&bulk_m, 4, 0));
    let probe_rhs = VerifiedRhs::new(&probe_m, 4, 1);

    // Warm both paths (plans, pool, caches) and verify once before any
    // timing.
    let warm = svc.solve("bulk", bulk_rhs.bs[0].clone())?;
    bulk_rhs.verify(0, &warm.x, "bulk warmup")?;
    let warm = svc.solve("probe", probe_rhs.bs[0].clone())?;
    probe_rhs.verify(0, &warm.x, "probe warmup")?;

    let stop = Arc::new(AtomicBool::new(false));
    let shed_total = Arc::new(AtomicU64::new(0));
    let mut flooders = Vec::new();
    for f in 0..FLOODERS {
        let svc = Arc::clone(&svc);
        let stop = Arc::clone(&stop);
        let shed_total = Arc::clone(&shed_total);
        let bulk_rhs = Arc::clone(&bulk_rhs);
        flooders.push(std::thread::spawn(move || -> Result<()> {
            let mut pending = VecDeque::new();
            let mut k = f; // stagger the RHS cycle across flooders
            while !stop.load(Ordering::SeqCst) {
                match svc.try_route("bulk", bulk_rhs.bs[k % bulk_rhs.bs.len()].clone(), None)? {
                    Admission::Admitted(handle) => pending.push_back((k, handle)),
                    Admission::Shed(_) => {
                        // relaxed: telemetry tally, read after join.
                        shed_total.fetch_add(1, Ordering::Relaxed);
                        // Back off by reaping a reply: admission said the
                        // lane is full, so wait for service-side progress
                        // instead of hammering the cap.
                        if let Some((kk, handle)) = pending.pop_front() {
                            bulk_rhs.verify(kk, &handle.wait()?.x, "bulk reply")?;
                        } else {
                            std::thread::yield_now();
                        }
                    }
                }
                if pending.len() >= FLOOD_WINDOW {
                    let (kk, handle) = pending.pop_front().expect("window is non-empty");
                    bulk_rhs.verify(kk, &handle.wait()?.x, "bulk reply")?;
                }
                k += FLOODERS;
            }
            for (kk, handle) in pending {
                bulk_rhs.verify(kk, &handle.wait()?.x, "bulk drain")?;
            }
            Ok(())
        }));
    }

    // Let the flood build a steady backlog before probing.
    std::thread::sleep(std::time::Duration::from_millis(30));

    // Sequential latency probes: in the first-come baseline they queue
    // behind the backlog like everyone else (the key's default class is
    // Bulk there); in by-class mode the Latency default puts them in the
    // priority lane and the reserved pool worker serves their session.
    let mut latencies_ms = Vec::with_capacity(probe_count(scale));
    for p in 0..probe_count(scale) {
        let b = probe_rhs.bs[p % probe_rhs.bs.len()].clone();
        let t0 = Instant::now();
        let resp = svc.solve("probe", b)?;
        latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        probe_rhs.verify(p, &resp.x, "probe reply")?;
    }

    stop.store(true, Ordering::SeqCst);
    for f in flooders {
        f.join().expect("flooder thread panicked")?;
    }
    let stats = svc.stats();
    let cap = if by_class { QUEUE_CAP as u64 } else { 0 };
    if cap > 0 {
        ensure!(
            stats.peak_queue_depth <= cap,
            "queue depth {} exceeded the cap {cap}",
            stats.peak_queue_depth
        );
    }
    ensure!(
        stats.shed_latency == 0,
        "latency probes must never shed ({} did)",
        stats.shed_latency
    );
    let row = AdmissionRow {
        mode: if by_class { "by_class" } else { "first_come" },
        probes: latencies_ms.len() as u64,
        p50_ms: percentile(&mut latencies_ms.clone(), 0.50),
        p99_ms: percentile(&mut latencies_ms, 0.99),
        // Everything served minus the probes and the two warmup solves.
        bulk_served: stats.served.saturating_sub(probe_count(scale) as u64 + 2),
        bulk_shed: stats.shed_bulk,
        peak_queue_depth: stats.peak_queue_depth,
        queue_cap: cap,
    };
    // Sanity: the service-side shed count and the flooders' view agree.
    // relaxed: flooder threads were joined above (happens-before edge).
    ensure!(
        row.bulk_shed == shed_total.load(Ordering::Relaxed),
        "shed accounting diverged: counters {} vs flooders {}",
        row.bulk_shed,
        shed_total.load(Ordering::Relaxed)
    );
    Arc::try_unwrap(svc)
        .ok()
        .expect("flooders joined; sole owner")
        .shutdown();
    Ok(row)
}

/// Nearest-rank percentile (q in [0, 1]) of `values`; sorts in place.
fn percentile(values: &mut [f64], q: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let idx = ((values.len() - 1) as f64 * q).ceil() as usize;
    values[idx.min(values.len() - 1)]
}

/// Run both modes and render the comparison. First-come runs first so
/// its unbounded backlog cannot leak into the bounded measurement.
pub fn admission_compare(scale: &str) -> Result<(crate::util::Table, Vec<AdmissionRow>)> {
    let rows = vec![run_mode(false, scale)?, run_mode(true, scale)?];
    let mut t = crate::util::Table::new(vec![
        "mode",
        "probes",
        "p50 ms",
        "p99 ms",
        "bulk served",
        "bulk shed",
        "peak depth",
        "cap",
    ]);
    for r in &rows {
        t.row(vec![
            r.mode.to_string(),
            r.probes.to_string(),
            format!("{:.4}", r.p50_ms),
            format!("{:.4}", r.p99_ms),
            r.bulk_served.to_string(),
            r.bulk_shed.to_string(),
            r.peak_queue_depth.to_string(),
            r.queue_cap.to_string(),
        ]);
    }
    Ok((t, rows))
}

/// Headline ratio the CI bench-regression gate watches: first-come p99
/// over by-class p99 for the latency probes (> 1 = bounded by-class
/// admission protects the latency tail).
pub fn latency_p99_ratio(rows: &[AdmissionRow]) -> f64 {
    let first = rows.iter().find(|r| r.mode == "first_come");
    let byclass = rows.iter().find(|r| r.mode == "by_class");
    match (first, byclass) {
        (Some(f), Some(b)) => f.p99_ms / b.p99_ms.max(1e-9),
        _ => 1.0,
    }
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[AdmissionRow]) -> String {
    let mut out = String::from("{\n  \"experiment\": \"admission\",\n");
    out.push_str(&format!("  \"threads\": {ADMISSION_THREADS},\n"));
    out.push_str(&format!(
        "  \"latency_p99_ratio\": {:.4},\n  \"rows\": [\n",
        latency_p99_ratio(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"mode\": \"{}\", \"probes\": {}, \"p50_ms\": {:.6}, \"p99_ms\": {:.6}, \
             \"bulk_served\": {}, \"bulk_shed\": {}, \"peak_queue_depth\": {}, \
             \"queue_cap\": {}}}{}\n",
            r.mode,
            r.probes,
            r.p50_ms,
            r.p99_ms,
            r.bulk_served,
            r.bulk_shed,
            r.peak_queue_depth,
            r.queue_cap,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_admission.json`).
pub fn write_json(path: &Path, rows: &[AdmissionRow]) -> Result<()> {
    std::fs::write(path, render_json(rows)).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let mut v = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile(&mut v.clone(), 0.0), 1.0);
        assert_eq!(percentile(&mut v.clone(), 0.5), 3.0);
        assert_eq!(percentile(&mut v.clone(), 0.99), 5.0);
        assert_eq!(percentile(&mut v, 1.0), 5.0);
        assert_eq!(percentile(&mut [], 0.5), 0.0);
    }

    #[test]
    fn json_is_well_formed_enough() {
        let rows = vec![
            AdmissionRow {
                mode: "first_come",
                probes: 30,
                p50_ms: 2.0,
                p99_ms: 9.0,
                bulk_served: 200,
                bulk_shed: 0,
                peak_queue_depth: 40,
                queue_cap: 0,
            },
            AdmissionRow {
                mode: "by_class",
                probes: 30,
                p50_ms: 0.4,
                p99_ms: 1.5,
                bulk_served: 180,
                bulk_shed: 25,
                peak_queue_depth: 16,
                queue_cap: 16,
            },
        ];
        let j = render_json(&rows);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"admission\""));
        assert!(j.contains("\"latency_p99_ratio\": 6.0000"));
        assert!(j.contains("\"queue_cap\": 16"));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let r = latency_p99_ratio(&rows);
        assert!((r - 6.0).abs() < 1e-9, "{r}");
        assert_eq!(latency_p99_ratio(&rows[..1]), 1.0, "missing mode = neutral");
    }

    /// End-to-end smoke at the dedicated `"tiny"` test scale (small
    /// matrices, 8 probes — the measurement scales stay off the
    /// `cargo test` budget): both modes run, every reply verifies
    /// bitwise (inside `run_mode`), the bounded mode respects its cap,
    /// and the ratio is a positive finite number. The *size* of the
    /// ratio is asserted by the CI gate against the pinned baseline,
    /// not here — unit tests on loaded machines would flake.
    #[test]
    fn admission_compare_smoke() {
        let (t, rows) = admission_compare("tiny").unwrap();
        assert_eq!(rows.len(), 2);
        let s = t.render();
        assert!(s.contains("first_come") && s.contains("by_class"));
        for r in &rows {
            assert!(r.probes > 0);
            assert!(r.p50_ms >= 0.0 && r.p99_ms >= r.p50_ms);
            if r.queue_cap > 0 {
                assert!(r.peak_queue_depth <= r.queue_cap, "{r:?}");
            }
        }
        let ratio = latency_p99_ratio(&rows);
        assert!(ratio.is_finite() && ratio > 0.0, "{ratio}");
    }
}
