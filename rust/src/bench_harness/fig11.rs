//! Figs. 11/12: throughput comparison CPU / GPU / DPU-v2 / this work, on
//! the named suite (Fig. 11) and the 245-benchmark sweep (Fig. 12).

use super::workloads::Workload;
use crate::arch::ArchConfig;
use crate::baselines::{cpu, fine, gpu};
use crate::compiler::{schedule_only, CompilerConfig};
use crate::graph::Dag;
use crate::util::{stats::geomean, Table};
use anyhow::Result;

/// One platform-comparison row.
#[derive(Debug, Clone)]
pub struct PlatformRow {
    /// Workload name.
    pub name: &'static str,
    /// Binary-node count (Fig. 12 x-axis).
    pub binary_nodes: usize,
    /// CPU GOPS (native serial, MKL small-matrix stand-in).
    pub cpu_gops: f64,
    /// GPU GOPS (analytic sync-free model).
    pub gpu_gops: f64,
    /// DPU-v2 GOPS (fine-dataflow model).
    pub dpu_gops: f64,
    /// This work GOPS (full medium dataflow: psum caching + ICR +
    /// coloring).
    pub this_gops: f64,
}

/// Run the comparison over a set of workloads.
pub fn compare(suite: &[Workload], arch: &ArchConfig, cpu_reps: usize) -> Result<(Table, Vec<PlatformRow>)> {
    let mut table = Table::new(vec![
        "benchmark",
        "binary nodes",
        "CPU GOPS",
        "GPU GOPS",
        "DPU-v2 GOPS",
        "this work GOPS",
    ]);
    let mut rows = Vec::new();
    for w in suite {
        let m = &w.matrix;
        let flops = m.binary_nodes() as u64;
        let g = Dag::from_csr(m);
        let b = vec![1.0f32; m.n];
        let cpu_gops = cpu::serial_gops(m, &b, cpu_reps).gops;
        let gpu_gops = gpu::simulate(&g, &gpu::GpuModel::default()).gops;
        let fine_cfg = fine::FineConfig::default();
        let dpu_gops = fine::simulate(&g, &fine_cfg)?.gops(&fine_cfg);
        let cfg = CompilerConfig {
            arch: *arch,
            ..CompilerConfig::default()
        };
        let s = schedule_only(m, &cfg)?;
        let this_gops = flops as f64 / (s.stats.cycles as f64 / arch.clock_hz) / 1e9;
        table.row(vec![
            w.name.to_string(),
            m.binary_nodes().to_string(),
            format!("{cpu_gops:.2}"),
            format!("{gpu_gops:.2}"),
            format!("{dpu_gops:.2}"),
            format!("{this_gops:.2}"),
        ]);
        rows.push(PlatformRow {
            name: w.name,
            binary_nodes: m.binary_nodes(),
            cpu_gops,
            gpu_gops,
            dpu_gops,
            this_gops,
        });
    }
    Ok((table, rows))
}

/// Summary speedups (geometric mean and max, this-work vs each platform).
pub fn speedup_summary(rows: &[PlatformRow]) -> Table {
    let mut table = Table::new(vec!["vs", "geomean speedup", "max speedup"]);
    for (name, get) in [
        ("CPU", Box::new(|r: &PlatformRow| r.cpu_gops) as Box<dyn Fn(&PlatformRow) -> f64>),
        ("GPU", Box::new(|r: &PlatformRow| r.gpu_gops)),
        ("DPU-v2", Box::new(|r: &PlatformRow| r.dpu_gops)),
    ] {
        let ratios: Vec<f64> = rows
            .iter()
            .filter(|r| get(r) > 0.0)
            .map(|r| r.this_gops / get(r))
            .collect();
        let max = ratios.iter().cloned().fold(0.0f64, f64::max);
        table.row(vec![
            name.to_string(),
            format!("{:.2}x", geomean(&ratios)),
            format!("{max:.2}x"),
        ]);
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::workloads::suite_small;

    #[test]
    fn this_work_beats_baselines_on_average() {
        let (_, rows) = compare(&suite_small(6), &ArchConfig::default(), 1).unwrap();
        let this_avg = geomean(&rows.iter().map(|r| r.this_gops).collect::<Vec<_>>());
        let dpu_avg = geomean(&rows.iter().map(|r| r.dpu_gops).collect::<Vec<_>>());
        let gpu_avg = geomean(&rows.iter().map(|r| r.gpu_gops).collect::<Vec<_>>());
        assert!(this_avg > dpu_avg, "this {this_avg} vs dpu {dpu_avg}");
        assert!(this_avg > gpu_avg, "this {this_avg} vs gpu {gpu_avg}");
    }

    #[test]
    fn summary_has_three_rows() {
        let (_, rows) = compare(&suite_small(3), &ArchConfig::default(), 1).unwrap();
        let t = speedup_summary(&rows);
        assert_eq!(t.len(), 3);
    }
}
