//! Overlapped vs serialized pool-session throughput
//! (`mgd bench concurrency`): total wall time for a fixed batch of small
//! solves on **distinct matrices** when the solves are issued one at a
//! time (the serialized-session regime PR 3 lived in — every solve owned
//! the whole pool) versus issued from several submitter threads at once,
//! overlapping as concurrent slot-leased sessions of one shared
//! [`MgdPool`](crate::runtime::MgdPool). Emits the machine-readable
//! `BENCH_concurrency.json` artifact consumed by CI's bench-regression
//! gate.
//!
//! The suite is deliberately **small and mixed**: small solves cannot
//! keep every worker busy for their whole duration (serial DAG
//! stretches, session setup, unclaimed slots), which is exactly where
//! overlapping independent solve fronts — the scheduling insight of the
//! parallel-SpTRSV literature — recovers throughput. Each scenario also
//! reports the pool's observed `peak_concurrency`, the proof that the
//! overlapped mode really ran sessions side by side.
//!
//! Every matrix is verified **bitwise** against
//! [`solve_serial`] before any timing (the MGD contract), so the table
//! cannot quietly report a fast-but-wrong runtime.

use super::workloads::Workload;
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::triangular::solve_serial;
use crate::runtime::{LevelSolver, NativeBackend, NativeConfig, SchedulerKind, SolverBackend};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Worker-thread count the shared backend runs with (fixed so the
/// artifact is comparable across machines with different core counts).
pub const CONCURRENCY_THREADS: usize = 4;

/// Solves each submitter issues per timed run.
pub const SOLVES_PER_SUBMITTER: usize = 24;

/// One scenario's measurements.
#[derive(Debug, Clone)]
pub struct ConcRow {
    /// Submitter threads in the overlapped mode.
    pub submitters: usize,
    /// Total solves per mode (`submitters × SOLVES_PER_SUBMITTER`).
    pub solves: u64,
    /// Wall milliseconds for the whole batch, solves issued one at a
    /// time from a single thread (sessions never overlap).
    pub serial_ms: f64,
    /// Wall milliseconds for the same batch issued from `submitters`
    /// threads against the same backend (sessions overlap).
    pub overlapped_ms: f64,
    /// Pool session-concurrency high-water mark observed during the
    /// overlapped run (`>= 2` proves sessions really overlapped).
    pub peak_concurrency: usize,
}

impl ConcRow {
    /// Throughput gain of overlapped sessions over serialized issue
    /// (> 1 = concurrency wins).
    pub fn speedup(&self) -> f64 {
        self.serial_ms / self.overlapped_ms.max(1e-12)
    }
}

/// Concurrency workloads: distinct small matrices whose node DAGs expose
/// real parallelism (`par_width > 1`, so every solve actually opens a
/// multi-worker pool session) without any single solve saturating the
/// pool for its whole duration — the regime where overlapping sessions
/// has room to help. Contiguous clustering keeps chains and bands
/// serial, so the suite sticks to shallow scattered-dependency shapes.
/// `scale` ∈ {"small", "full"} sizes the matrices.
pub fn concurrency_suite(scale: &str) -> Vec<Workload> {
    let f = if scale == "small" { 1 } else { 4 };
    let mk = |name, matrix| Workload { name, matrix };
    vec![
        mk("wide_a", gen::shallow(900 * f, 0.3, GenSeed(401))),
        mk("wide_b", gen::shallow(1200 * f, 0.4, GenSeed(402))),
        mk("wide_c", gen::shallow(700 * f, 0.5, GenSeed(403))),
        mk("wide_d", gen::shallow(1500 * f, 0.35, GenSeed(404))),
    ]
}

fn native_cfg() -> NativeConfig {
    NativeConfig {
        threads: CONCURRENCY_THREADS,
        scheduler: SchedulerKind::Mgd,
        ..NativeConfig::default()
    }
}

/// The fixed request mix of one timed run: `(matrix index, rhs)` pairs,
/// identical for both modes so the comparison is solve-for-solve fair.
/// Matrix choice is a seeded PRNG draw, not `k % len` — a cyclic pattern
/// would let the submitters' strided slices each pin one matrix instead
/// of genuinely mixing traffic.
fn request_mix(plans: &[Arc<LevelSolver>], total: usize) -> Vec<(usize, Vec<f32>)> {
    let mut rng = crate::util::XorShift64::new(0x5EED_C0DE);
    (0..total)
        .map(|k| {
            let which = rng.range(0, plans.len());
            let n = plans[which].n();
            let b = (0..n).map(|i| ((i + 2 * k) % 9) as f32 - 4.0).collect();
            (which, b)
        })
        .collect()
}

/// Run the whole mix through `backend`, issued from `submitters` threads
/// (1 = the serialized baseline). Returns the wall time in milliseconds.
/// Each submitter takes a strided slice of the mix, so the per-matrix
/// composition is identical across modes and thread counts.
fn run_mix(
    backend: &NativeBackend,
    plans: &[Arc<LevelSolver>],
    mix: &[(usize, Vec<f32>)],
    submitters: usize,
) -> Result<f64> {
    let t0 = Instant::now();
    if submitters <= 1 {
        for (which, b) in mix {
            backend.solve(&plans[*which], b)?;
        }
    } else {
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(submitters);
            for s in 0..submitters {
                handles.push(scope.spawn(move || -> Result<()> {
                    for (which, b) in mix.iter().skip(s).step_by(submitters) {
                        backend.solve(&plans[*which], b)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                h.join().expect("submitter thread panicked")?;
            }
            Ok(())
        })?;
    }
    Ok(t0.elapsed().as_secs_f64() * 1e3)
}

/// Measure overlapped vs serialized session throughput over `suite` for
/// each submitter count, on one shared backend per scenario.
pub fn concurrency_compare(suite: &[Workload]) -> Result<(crate::util::Table, Vec<ConcRow>)> {
    ensure!(!suite.is_empty(), "concurrency suite is empty");
    let mut t = crate::util::Table::new(vec![
        "submitters",
        "solves",
        "serial ms",
        "overlapped ms",
        "speedup",
        "peak concurrency",
    ]);
    let mut rows = Vec::new();
    for &submitters in &[2usize, 4] {
        // A fresh backend per scenario so `peak_concurrency` reflects
        // this scenario's overlapped run alone (the serialized phase
        // only ever holds one session in flight).
        let backend = NativeBackend::new(native_cfg());
        let plans: Vec<Arc<LevelSolver>> = suite
            .iter()
            .map(|w| Arc::new(LevelSolver::new(&w.matrix)))
            .collect();
        // Verify bitwise and warm the cached plans + pool before timing.
        for (w, plan) in suite.iter().zip(&plans) {
            let b: Vec<f32> = (0..w.matrix.n).map(|i| (i % 7) as f32 - 3.0).collect();
            let x = backend.solve(plan, &b)?;
            let want = solve_serial(&w.matrix, &b);
            for i in 0..w.matrix.n {
                ensure!(
                    x[i].to_bits() == want[i].to_bits(),
                    "concurrency path not bitwise-serial on {} row {i}: {} vs {}",
                    w.name,
                    x[i],
                    want[i],
                );
            }
        }
        let mix = request_mix(&plans, submitters * SOLVES_PER_SUBMITTER);
        // Best-of-2 on each mode to shave scheduler noise; the serialized
        // baseline runs first so its sessions cannot inflate the
        // overlapped phase's peak-concurrency reading.
        let serial_ms = run_mix(&backend, &plans, &mix, 1)?
            .min(run_mix(&backend, &plans, &mix, 1)?);
        debug_assert!(backend.mgd_pool_stats().peak_concurrency <= 1);
        let overlapped_ms = run_mix(&backend, &plans, &mix, submitters)?
            .min(run_mix(&backend, &plans, &mix, submitters)?);
        let peak = backend.mgd_pool_stats().peak_concurrency;
        let row = ConcRow {
            submitters,
            solves: mix.len() as u64,
            serial_ms,
            overlapped_ms,
            peak_concurrency: peak,
        };
        t.row(vec![
            row.submitters.to_string(),
            row.solves.to_string(),
            format!("{:.4}", row.serial_ms),
            format!("{:.4}", row.overlapped_ms),
            format!("{:.2}x", row.speedup()),
            row.peak_concurrency.to_string(),
        ]);
        rows.push(row);
    }
    Ok((t, rows))
}

/// Geometric-mean overlapped-over-serialized speedup across scenarios —
/// the headline ratio the CI bench-regression gate watches.
pub fn overlap_geomean_speedup(rows: &[ConcRow]) -> f64 {
    if rows.is_empty() {
        return 1.0;
    }
    (rows.iter().map(|r| r.speedup().ln()).sum::<f64>() / rows.len() as f64).exp()
}

/// Render the rows as a self-describing JSON document.
pub fn render_json(rows: &[ConcRow]) -> String {
    let mut out = String::from("{\n  \"experiment\": \"concurrency\",\n");
    out.push_str(&format!("  \"threads\": {CONCURRENCY_THREADS},\n"));
    out.push_str(&format!(
        "  \"overlap_geomean_speedup\": {:.4},\n  \"rows\": [\n",
        overlap_geomean_speedup(rows)
    ));
    for (i, r) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"submitters\": {}, \"solves\": {}, \"serial_ms\": {:.6}, \
             \"overlapped_ms\": {:.6}, \"speedup\": {:.4}, \"peak_concurrency\": {}}}{}\n",
            r.submitters,
            r.solves,
            r.serial_ms,
            r.overlapped_ms,
            r.speedup(),
            r.peak_concurrency,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write the JSON artifact (the CI-consumed `BENCH_concurrency.json`).
pub fn write_json(path: &Path, rows: &[ConcRow]) -> Result<()> {
    std::fs::write(path, render_json(rows)).with_context(|| format!("write {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_suite() -> Vec<Workload> {
        vec![
            Workload {
                name: "wide_tiny_a",
                matrix: gen::shallow(500, 0.4, GenSeed(411)),
            },
            Workload {
                name: "wide_tiny_b",
                matrix: gen::shallow(650, 0.3, GenSeed(412)),
            },
        ]
    }

    #[test]
    fn compare_runs_verifies_and_overlaps() {
        let (t, rows) = concurrency_compare(&tiny_suite()).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(rows.len(), 2);
        let s = t.render();
        assert!(s.contains("serial ms"));
        assert!(s.contains("overlapped ms"));
        for r in &rows {
            assert!(r.serial_ms > 0.0 && r.overlapped_ms > 0.0);
            assert_eq!(r.solves, (r.submitters * SOLVES_PER_SUBMITTER) as u64);
            // Dozens of simultaneous submissions of multi-node solves:
            // at least one pair must have been in flight together.
            assert!(
                r.peak_concurrency >= 2,
                "overlapped mode never overlapped: {r:?}"
            );
        }
    }

    #[test]
    fn json_is_well_formed_enough() {
        let rows = vec![
            ConcRow {
                submitters: 2,
                solves: 48,
                serial_ms: 10.0,
                overlapped_ms: 6.5,
                peak_concurrency: 2,
            },
            ConcRow {
                submitters: 4,
                solves: 96,
                serial_ms: 20.0,
                overlapped_ms: 11.0,
                peak_concurrency: 4,
            },
        ];
        let j = render_json(&rows);
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        assert!(j.contains("\"experiment\": \"concurrency\""));
        assert!(j.contains("\"overlap_geomean_speedup\""));
        assert!(j.contains("\"peak_concurrency\": 4"));
        // Balanced braces/brackets (hand-rolled writer smoke check).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        let g = overlap_geomean_speedup(&rows);
        assert!(g > 1.0 && g < 2.0, "{g}");
    }

    #[test]
    fn concurrency_suite_has_distinct_parallel_matrices() {
        use crate::runtime::MgdPlanConfig;
        let suite = concurrency_suite("small");
        assert!(suite.len() >= 2, "need distinct matrices to overlap");
        for w in &suite {
            w.matrix.validate().unwrap();
            let plan = LevelSolver::new(&w.matrix);
            let mgd = plan.mgd_plan(MgdPlanConfig::auto(
                plan.n(),
                plan.num_levels(),
                CONCURRENCY_THREADS,
            ));
            assert!(mgd.par_width > 1, "{}: no parallelism to schedule", w.name);
        }
    }
}
