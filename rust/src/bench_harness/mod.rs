//! Regenerates every table and figure of the paper's evaluation
//! (per-experiment index in DESIGN.md §3).
//!
//! Numbers are produced on synthetic SuiteSparse analogs (DESIGN.md
//! "Substitutions"); the comparison *shape* — who wins, by what factor,
//! where the crossovers fall — is the reproduction target, not absolute
//! values from the authors' testbed.

pub mod admission;
pub mod backends;
pub mod concurrency;
pub mod fig10;
pub mod fig11;
pub mod fig9;
pub mod report;
pub mod schedulers;
pub mod serving;
pub mod skew;
pub mod streaming;
pub mod tables;
pub mod workloads;

pub use workloads::{suite, sweep_245, Workload};
