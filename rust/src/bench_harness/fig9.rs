//! Fig. 9 experiments: (a) dataflow comparison, (b)/(c) psum capacity
//! sweep, (d)/(e)/(f) ICR ablation.

use super::workloads::Workload;
use crate::arch::ArchConfig;
use crate::baselines::{coarse, fine};
use crate::compiler::allocation::{allocate, AllocationPolicy};
use crate::compiler::{schedule_only, CompilerConfig};
use crate::graph::Dag;
use crate::util::Table;
use anyhow::Result;

/// One Fig. 9(a) row.
#[derive(Debug, Clone)]
pub struct Fig9aRow {
    /// Workload name.
    pub name: &'static str,
    /// Coarse dataflow GOPS.
    pub coarse_gops: f64,
    /// Fine (DPU-v2 model) GOPS.
    pub fine_gops: f64,
    /// This-work (medium) GOPS — psum caching *off*, per the paper.
    pub medium_gops: f64,
}

/// Fig. 9(a): throughput of coarse / fine / this-work dataflows.
pub fn fig9a(suite: &[Workload], arch: &ArchConfig) -> Result<(Table, Vec<Fig9aRow>)> {
    let mut rows = Vec::new();
    let mut table = Table::new(vec!["benchmark", "coarse GOPS", "fine GOPS", "this-work GOPS"]);
    for w in suite {
        let g = Dag::from_csr(&w.matrix);
        let flops = w.matrix.binary_nodes() as u64;
        let alloc = allocate(&g, arch.num_cus(), AllocationPolicy::RoundRobin);
        let c = coarse::simulate(&g, &alloc)?;
        let coarse_gops = c.gops(arch.clock_hz, flops);
        let fine_cfg = fine::FineConfig::default();
        let f = fine::simulate(&g, &fine_cfg)?;
        let fine_gops = f.gops(&fine_cfg);
        // "This work dataflow does not utilize the partial sum caching
        // mechanism" in Fig. 9(a).
        let cfg = CompilerConfig {
            arch: ArchConfig {
                psum_words: 0,
                ..*arch
            },
            ..CompilerConfig::default()
        };
        let s = schedule_only(&w.matrix, &cfg)?;
        let medium_gops = flops as f64 / (s.stats.cycles as f64 / arch.clock_hz) / 1e9;
        table.row(vec![
            w.name.to_string(),
            format!("{coarse_gops:.2}"),
            format!("{fine_gops:.2}"),
            format!("{medium_gops:.2}"),
        ]);
        rows.push(Fig9aRow {
            name: w.name,
            coarse_gops,
            fine_gops,
            medium_gops,
        });
    }
    Ok((table, rows))
}

/// Fig. 9(b)/(c): total and blocking cycles vs psum capacity (normalized
/// to capacity 0).
pub fn fig9bc(
    suite: &[Workload],
    arch: &ArchConfig,
    capacities: &[u32],
) -> Result<Table> {
    let mut table = Table::new(
        std::iter::once("benchmark".to_string())
            .chain(capacities.iter().flat_map(|c| {
                [format!("total@{c}"), format!("block@{c}")]
            }))
            .collect::<Vec<_>>(),
    );
    for w in suite {
        let mut cells = vec![w.name.to_string()];
        let mut base_total = 0f64;
        let mut base_block = 0f64;
        for (i, &cap) in capacities.iter().enumerate() {
            let cfg = CompilerConfig {
                arch: ArchConfig {
                    psum_words: cap,
                    ..*arch
                },
                ..CompilerConfig::default()
            };
            let s = schedule_only(&w.matrix, &cfg)?;
            let total = s.stats.cycles as f64;
            let block = (s.stats.bnop + s.stats.pnop + s.stats.dnop + s.stats.lnop) as f64;
            if i == 0 {
                base_total = total;
                base_block = block.max(1.0);
            }
            cells.push(format!("{:.3}", total / base_total));
            cells.push(format!("{:.3}", block / base_block));
        }
        table.row(cells);
    }
    Ok(table)
}

/// Fig. 9(d)/(e)/(f): constraints, bank conflicts and data reuse with and
/// without ICR.
pub fn fig9def(suite: &[Workload], arch: &ArchConfig) -> Result<Table> {
    let mut table = Table::new(vec![
        "benchmark",
        "constraints noICR",
        "constraints ICR",
        "conflicts noICR",
        "conflicts ICR",
        "reuse noICR",
        "reuse ICR",
    ]);
    for w in suite {
        let mut vals = Vec::new();
        for icr in [false, true] {
            let cfg = CompilerConfig {
                arch: *arch,
                use_icr: icr,
                ..CompilerConfig::default()
            };
            let s = schedule_only(&w.matrix, &cfg)?;
            vals.push((s.stats.constraints, s.stats.conflicts, s.stats.reuse_fraction()));
        }
        table.row(vec![
            w.name.to_string(),
            vals[0].0.to_string(),
            vals[1].0.to_string(),
            vals[0].1.to_string(),
            vals[1].1.to_string(),
            format!("{:.3}", vals[0].2),
            format!("{:.3}", vals[1].2),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::workloads::suite_small;

    #[test]
    fn fig9a_medium_wins_on_average() {
        let arch = ArchConfig::default();
        let suite = suite_small(6);
        let (_, rows) = fig9a(&suite, &arch).unwrap();
        let med: f64 = rows.iter().map(|r| r.medium_gops).sum();
        let coarse: f64 = rows.iter().map(|r| r.coarse_gops).sum();
        assert!(med > coarse, "medium {med} vs coarse {coarse}");
    }

    #[test]
    fn fig9bc_capacity_monotone_trend() {
        let arch = ArchConfig::default();
        let suite = suite_small(3);
        let t = fig9bc(&suite, &arch, &[0, 4, 8]).unwrap();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn fig9def_runs() {
        let arch = ArchConfig::default();
        let suite = suite_small(3);
        let t = fig9def(&suite, &arch).unwrap();
        assert_eq!(t.len(), 3);
    }
}
