//! Tables II, III and IV of the paper.

use super::fig11::PlatformRow;
use super::workloads::Workload;
use crate::arch::ArchConfig;
use crate::baselines::fine;
use crate::compiler::{compile, CompilerConfig};
use crate::graph::{Dag, DagStats, Levels};
use crate::sim::{Accelerator, EnergyModel};
use crate::util::{stats::geomean, Table};
use anyhow::Result;

/// Table II: area/power breakdown (the model's coefficients) plus the
/// activity-scaled measured power for a representative workload.
pub fn table2(suite: &[Workload], arch: &ArchConfig) -> Result<Table> {
    let model = EnergyModel::paper_28nm();
    let mut table = Table::new(vec!["component", "area mm2", "power mW (peak)", "power mW (measured)"]);
    // Representative run: first suite workload.
    let w = &suite[0];
    let cfg = CompilerConfig {
        arch: *arch,
        ..CompilerConfig::default()
    };
    let prog = compile(&w.matrix, &cfg)?;
    let mut acc = Accelerator::new(*arch);
    let run = acc.run(&prog, &vec![1.0f32; w.matrix.n])?;
    let rep = model.estimate(&run.stats, arch);
    for (c, (name, watts, _)) in crate::sim::energy::PAPER_TABLE2.iter().zip(&rep.per_component) {
        table.row(vec![
            c.name.to_string(),
            format!("{:.2}", c.area_mm2),
            format!("{:.2}", c.power_mw),
            format!("{:.2} ({})", watts * 1e3, name),
        ]);
    }
    table.row(vec![
        "TOTAL".to_string(),
        format!("{:.2}", model.total_area_mm2()),
        format!("{:.2}", model.peak_power_w() * 1e3),
        format!("{:.2}", rep.avg_power_w * 1e3),
    ]);
    Ok(table)
}

/// Table III: benchmark characteristics + compile time.
pub fn table3(suite: &[Workload], arch: &ArchConfig) -> Result<Table> {
    let mut table = Table::new(vec![
        "name",
        "N",
        "NNZ",
        "binary nodes",
        "CDU nodes %",
        "CDU edges %",
        "CDU levels %",
        "edges/CDU node",
        "load balance",
        "peak GOPS",
        "compile ms",
    ]);
    for w in suite {
        let m = &w.matrix;
        let g = Dag::from_csr(m);
        let lv = Levels::compute(&g);
        let st = DagStats::compute(&g, &lv, arch.num_cus());
        let cfg = CompilerConfig {
            arch: *arch,
            ..CompilerConfig::default()
        };
        let prog = compile(m, &cfg)?;
        let peak = crate::graph::stats::peak_throughput_gops(m.n, m.nnz(), arch.num_cus(), arch.clock_hz);
        table.row(vec![
            w.name.to_string(),
            m.n.to_string(),
            m.nnz().to_string(),
            st.binary_nodes.to_string(),
            format!("{:.1}", st.cdu_nodes_pct),
            format!("{:.1}", st.cdu_edges_pct),
            format!("{:.1}", st.cdu_levels_pct),
            format!("{:.1}", st.cdu_avg_edges_per_node),
            format!("{:.1}", prog.compile.load_balance_degree),
            format!("{peak:.1}"),
            format!("{:.1}", prog.compile.compile_seconds * 1e3),
        ]);
    }
    Ok(table)
}

/// Table IV: platform summary over a (possibly large) comparison run.
pub fn table4(rows: &[PlatformRow], arch: &ArchConfig, avg_compile_s: f64) -> Table {
    let mut table = Table::new(vec!["metric", "CPU", "GPU", "DPU-v2", "This work"]);
    let avg = |f: &dyn Fn(&PlatformRow) -> f64| {
        geomean(&rows.iter().map(f).filter(|&v| v > 0.0).collect::<Vec<_>>())
    };
    let cpu = avg(&|r: &PlatformRow| r.cpu_gops);
    let gpu = avg(&|r: &PlatformRow| r.gpu_gops);
    let dpu = avg(&|r: &PlatformRow| r.dpu_gops);
    let this = avg(&|r: &PlatformRow| r.this_gops);
    let fine_cfg = fine::FineConfig::default();
    let fine_peak = (fine_cfg.trees * ((1 << fine_cfg.depth) - 1)) as f64 * fine_cfg.clock_hz / 1e9;
    table.row(vec![
        "Peak throughput (GOPS)".to_string(),
        "(host)".to_string(),
        "13447.7 (model)".to_string(),
        format!("{fine_peak:.1}"),
        format!("{:.1}", arch.peak_gops()),
    ]);
    table.row(vec![
        "Avg. throughput (GOPS, geomean)".to_string(),
        format!("{cpu:.2}"),
        format!("{gpu:.2}"),
        format!("{dpu:.2}"),
        format!("{this:.2}"),
    ]);
    table.row(vec![
        "Speedup vs CPU".to_string(),
        "1.00x".to_string(),
        format!("{:.2}x", gpu / cpu),
        format!("{:.2}x", dpu / cpu),
        format!("{:.2}x", this / cpu),
    ]);
    let model = EnergyModel::paper_28nm();
    table.row(vec![
        "Power (W)".to_string(),
        ">50 (paper)".to_string(),
        ">50 (paper)".to_string(),
        "0.109 (paper)".to_string(),
        format!("{:.3} (peak model)", model.peak_power_w()),
    ]);
    table.row(vec![
        "Avg. energy eff. (GOPS/W)".to_string(),
        "<0.01".to_string(),
        "<0.01".to_string(),
        format!("{:.1}", dpu / 0.109),
        format!("{:.1}", this / model.peak_power_w()),
    ]);
    table.row(vec![
        "Avg. compile time (s)".to_string(),
        "-".to_string(),
        "~0.02 (paper)".to_string(),
        "103.4 (paper, O(nnz^2))".to_string(),
        format!("{avg_compile_s:.4}"),
    ]);
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::fig11::compare;
    use crate::bench_harness::workloads::suite_small;

    #[test]
    fn table2_runs() {
        let t = table2(&suite_small(1), &ArchConfig::default()).unwrap();
        assert_eq!(t.len(), 12); // 11 components + total
    }

    #[test]
    fn table3_runs() {
        let t = table3(&suite_small(3), &ArchConfig::default()).unwrap();
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn table4_runs() {
        let arch = ArchConfig::default();
        let (_, rows) = compare(&suite_small(3), &arch, 1).unwrap();
        let t = table4(&rows, &arch, 0.01);
        assert_eq!(t.len(), 6);
    }
}
