//! Synthetic sparse-triangular workload generators.
//!
//! The paper evaluates 245 SuiteSparse matrices; this offline image has no
//! network access, so we synthesize matrices whose *DAG shape* — level
//! structure, CDU-node fraction, in-degree distribution, bandwidth — spans
//! the same regimes (see DESIGN.md "Substitutions"). Every generator is
//! seeded and deterministic.
//!
//! Values are made diagonally dominant (diag = Σ|off-diag| + U[1,2)) so all
//! solves are well-conditioned and f32 comparisons are meaningful.

use super::CsrMatrix;
use crate::util::XorShift64;

/// Explicit seed newtype so call sites read `GenSeed(42)` rather than a bare
/// integer that could be confused with a size parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenSeed(pub u64);

/// Finish a pattern: assign off-diagonal values and a dominant diagonal.
fn realize(n: usize, pattern: Vec<Vec<u32>>, rng: &mut XorShift64) -> CsrMatrix {
    let mut triplets: Vec<(u32, u32, f32)> = Vec::new();
    for (i, cols) in pattern.iter().enumerate() {
        let mut mag = 0f32;
        for &c in cols {
            debug_assert!((c as usize) < i);
            let v = rng.f32_range(-1.0, -0.1);
            mag += v.abs();
            triplets.push((i as u32, c, v));
        }
        triplets.push((i as u32, i as u32, mag + rng.f32_range(1.0, 2.0)));
    }
    CsrMatrix::from_triplets(n, &triplets).expect("generator produced invalid pattern")
}

/// Deduplicate and sort a row's off-diagonal column list in place.
fn dedup_row(cols: &mut Vec<u32>) {
    cols.sort_unstable();
    cols.dedup();
}

/// Banded matrix: row `i` draws from the `bw` previous columns, each kept
/// with probability `fill`. Models the narrow-band structure of matrices
/// like `dw2048` / discretized 1-D operators: long dependence chains, small
/// levels, CDU-heavy.
pub fn banded(n: usize, bw: usize, fill: f64, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0xBA4D);
    let mut pattern = vec![Vec::new(); n];
    for (i, row) in pattern.iter_mut().enumerate().skip(1) {
        let lo = i.saturating_sub(bw);
        for c in lo..i {
            if rng.chance(fill) {
                row.push(c as u32);
            }
        }
        // Guarantee the chain structure (previous row) so the band does not
        // accidentally decouple into independent blocks.
        if row.is_empty() {
            row.push((i - 1) as u32);
        }
        dedup_row(row);
    }
    realize(n, pattern, &mut rng)
}

/// Pure bidiagonal chain: the fully sequential worst case (every level has
/// exactly one node).
pub fn chain(n: usize, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0xC4A1);
    let mut pattern = vec![Vec::new(); n];
    for (i, row) in pattern.iter_mut().enumerate().skip(1) {
        row.push((i - 1) as u32);
    }
    realize(n, pattern, &mut rng)
}

/// Circuit-simulation-like matrix (add20 / rajat / fpga_dcop analogs):
/// geometric in-degree with mean `avg_deg`, sources drawn mostly from a
/// local window (probability `locality`) and occasionally uniformly from all
/// previous rows, plus a few high-fanin "hub" rows (dense rows are what make
/// rajat04-style matrices load-imbalanced).
pub fn circuit(n: usize, avg_deg: usize, locality: f64, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0xC12C);
    let window = (n / 20).max(8);
    let mut pattern = vec![Vec::new(); n];
    for i in 1..n {
        let deg = rng.geometric(avg_deg as f64).min(i);
        let row = &mut pattern[i];
        for _ in 0..deg {
            let c = if rng.chance(locality) {
                rng.range(i.saturating_sub(window), i)
            } else {
                rng.range(0, i)
            };
            row.push(c as u32);
        }
        dedup_row(row);
    }
    // Hub rows: ~0.5% of rows get in-degree ≈ 10×avg (clipped).
    let hubs = (n / 200).max(1);
    for _ in 0..hubs {
        let i = rng.range(n / 2, n);
        let want = (avg_deg * 10).min(i);
        let extra = rng.sample_distinct(0, i, want);
        let row = &mut pattern[i];
        row.extend(extra.iter().map(|&c| c as u32));
        dedup_row(row);
    }
    realize(n, pattern, &mut rng)
}

/// 2-D grid stencil (power-network / mesh analog, ACTIVSg2000 / jagmesh):
/// node (r,c) depends on its left and upper neighbors (5-point lower part)
/// and, when `nine_point`, the diagonal neighbors too.
pub fn grid2d(rows: usize, cols: usize, nine_point: bool, seed: GenSeed) -> CsrMatrix {
    let n = rows * cols;
    let mut rng = XorShift64::new(seed.0 ^ 0x621D);
    let mut pattern = vec![Vec::new(); n];
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            let mut row = Vec::new();
            if c > 0 {
                row.push((i - 1) as u32);
            }
            if r > 0 {
                row.push((i - cols) as u32);
                if nine_point {
                    if c > 0 {
                        row.push((i - cols - 1) as u32);
                    }
                    if c + 1 < cols {
                        row.push((i - cols + 1) as u32);
                    }
                }
            }
            dedup_row(&mut row);
            pattern[i] = row;
        }
    }
    realize(n, pattern, &mut rng)
}

/// Mostly independent nodes with a shallow scattered dependency tree —
/// the `c-36` analog where the coarse dataflow performs well (few, huge
/// levels; tiny CDU fraction).
pub fn shallow(n: usize, dep_prob: f64, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0x54A7);
    let mut pattern = vec![Vec::new(); n];
    for (i, row) in pattern.iter_mut().enumerate().skip(1) {
        if rng.chance(dep_prob) {
            // Depend on 1-2 much earlier nodes: keeps the level count tiny.
            let deg = 1 + rng.below(2) as usize;
            for _ in 0..deg.min(i) {
                row.push(rng.range(0, (i / 4).max(1)) as u32);
            }
            dedup_row(row);
        }
    }
    realize(n, pattern, &mut rng)
}

/// Uniform random lower pattern with a target off-diagonal nnz. The
/// "unstructured" control case.
pub fn random_lower(n: usize, off_nnz: usize, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0x7A2D);
    let mut pattern = vec![Vec::new(); n];
    let mut placed = 0usize;
    let mut attempts = 0usize;
    while placed < off_nnz && attempts < off_nnz * 20 {
        attempts += 1;
        let i = rng.range(1, n);
        let c = rng.range(0, i) as u32;
        if !pattern[i].contains(&c) {
            pattern[i].push(c);
            placed += 1;
        }
    }
    for row in &mut pattern {
        dedup_row(row);
    }
    realize(n, pattern, &mut rng)
}

/// Power-law in-degree (few rows with very many inputs): the bp_200 /
/// west2021 analog whose load-balance degree is poor under coarse node
/// allocation.
pub fn power_law(n: usize, alpha: f64, max_deg: usize, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0xF0E1);
    let mut pattern = vec![Vec::new(); n];
    for i in 1..n {
        // Inverse-CDF sample of a zipf-ish degree in [1, max_deg].
        let u = rng.f64().max(1e-12);
        let deg = ((u.powf(-1.0 / alpha)).min(max_deg as f64) as usize).min(i);
        let cols = rng.sample_distinct(0, i, deg.max(1).min(i));
        pattern[i] = cols.into_iter().map(|c| c as u32).collect();
    }
    realize(n, pattern, &mut rng)
}

/// Triangular factor-like pattern: take a banded skeleton and add fill-in
/// fringes that decay with distance — resembles L factors from sparse LU of
/// circuit/FEM matrices (bayer07 / gemat12 analogs).
pub fn factor_like(n: usize, bw: usize, fringe: usize, seed: GenSeed) -> CsrMatrix {
    let mut rng = XorShift64::new(seed.0 ^ 0xFAC7);
    let mut pattern = vec![Vec::new(); n];
    for i in 1..n {
        let row = &mut pattern[i];
        let lo = i.saturating_sub(bw);
        for c in lo..i {
            if rng.chance(0.6) {
                row.push(c as u32);
            }
        }
        // Fill-in fringe: geometric decay with distance beyond the band.
        for _ in 0..fringe {
            let span = i.saturating_sub(bw);
            if span == 0 {
                break;
            }
            // Bias toward recent columns via squared uniform.
            let u = rng.f64();
            let c = (span as f64 * (1.0 - u * u)) as usize;
            if c < span {
                row.push(c as u32);
            }
        }
        if row.is_empty() {
            row.push((i - 1) as u32);
        }
        dedup_row(row);
    }
    realize(n, pattern, &mut rng)
}

/// One small matrix per generator family with fixed seeds — the shared
/// coverage suite used by the runtime/executor property tests (one
/// definition so "all generators" means the same thing everywhere).
/// The `power_law` entry's hubs exceed the default 32-edge budget, which
/// several tests rely on to exercise overflow/hub paths.
#[cfg(test)]
pub fn test_suite() -> Vec<(&'static str, CsrMatrix)> {
    vec![
        ("banded", banded(500, 6, 0.5, GenSeed(1))),
        ("chain", chain(120, GenSeed(2))),
        ("circuit", circuit(600, 5, 0.8, GenSeed(3))),
        ("grid2d", grid2d(20, 20, true, GenSeed(4))),
        ("shallow", shallow(900, 0.4, GenSeed(5))),
        ("random_lower", random_lower(400, 2000, GenSeed(6))),
        ("power_law", power_law(400, 1.1, 120, GenSeed(7))),
        ("factor_like", factor_like(500, 8, 4, GenSeed(8))),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::triangular::{max_relative_residual, solve_serial};

    fn check(m: &CsrMatrix) {
        m.validate().unwrap();
        let b: Vec<f32> = (0..m.n).map(|i| ((i * 13 % 11) as f32) - 5.0).collect();
        let x = solve_serial(m, &b);
        assert!(max_relative_residual(m, &x, &b) < 1e-4);
    }

    #[test]
    fn banded_valid_and_solvable() {
        check(&banded(500, 6, 0.5, GenSeed(1)));
    }

    #[test]
    fn chain_is_fully_sequential() {
        let m = chain(100, GenSeed(2));
        check(&m);
        assert_eq!(m.off_diag_nnz(), 99);
    }

    #[test]
    fn circuit_valid_and_has_hubs() {
        let m = circuit(1000, 5, 0.8, GenSeed(3));
        check(&m);
        assert!(m.max_in_degree() >= 20, "expected hub rows, max={}", m.max_in_degree());
    }

    #[test]
    fn grid2d_five_point_shape() {
        let m = grid2d(20, 30, false, GenSeed(4));
        check(&m);
        assert_eq!(m.n, 600);
        // Interior node depends on exactly 2 neighbors.
        assert_eq!(m.in_degree(20 * 30 - 1), 2);
    }

    #[test]
    fn grid2d_nine_point_has_more_edges() {
        let five = grid2d(15, 15, false, GenSeed(5));
        let nine = grid2d(15, 15, true, GenSeed(5));
        check(&nine);
        assert!(nine.off_diag_nnz() > five.off_diag_nnz());
    }

    #[test]
    fn shallow_has_few_levels() {
        let m = shallow(2000, 0.3, GenSeed(6));
        check(&m);
        let dag = crate::graph::Dag::from_csr(&m);
        let lv = crate::graph::levels::Levels::compute(&dag);
        assert!(lv.num_levels() <= 10, "levels={}", lv.num_levels());
    }

    #[test]
    fn random_lower_hits_target_nnz() {
        let m = random_lower(400, 2000, GenSeed(7));
        check(&m);
        assert!(m.off_diag_nnz() >= 1900, "nnz={}", m.off_diag_nnz());
    }

    #[test]
    fn power_law_has_skewed_degrees() {
        let m = power_law(1500, 1.2, 200, GenSeed(8));
        check(&m);
        assert!(m.max_in_degree() >= 30);
    }

    #[test]
    fn factor_like_valid() {
        check(&factor_like(800, 8, 4, GenSeed(9)));
    }

    #[test]
    fn generators_are_deterministic() {
        let a = circuit(300, 4, 0.7, GenSeed(11));
        let b = circuit(300, 4, 0.7, GenSeed(11));
        assert_eq!(a, b);
        let c = circuit(300, 4, 0.7, GenSeed(12));
        assert_ne!(a, c);
    }
}
