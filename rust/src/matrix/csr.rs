//! CSR storage for sparse lower-triangular matrices (diagonal-last rows).

use anyhow::{bail, ensure, Result};

/// A sparse lower-triangular matrix in CSR format.
///
/// Invariants (checked by [`CsrMatrix::validate`]):
/// - `rowptr.len() == n + 1`, monotonically non-decreasing, `rowptr[n] == nnz`.
/// - every row is non-empty and ends with its diagonal entry (`colidx == row`),
/// - off-diagonal columns in a row are strictly ascending and `< row`,
/// - no diagonal value is zero (the solve divides by it).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    /// Matrix order (number of rows == columns).
    pub n: usize,
    /// Row pointers, length `n + 1`.
    pub rowptr: Vec<usize>,
    /// Column indices, row-major, diagonal last in each row.
    pub colidx: Vec<u32>,
    /// Nonzero values, parallel to `colidx`.
    pub values: Vec<f32>,
}

impl CsrMatrix {
    /// Number of stored nonzeros (including the diagonal).
    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Number of off-diagonal nonzeros (== DAG edge count).
    pub fn off_diag_nnz(&self) -> usize {
        self.nnz() - self.n
    }

    /// Number of binary (fine) nodes of the equivalent binary DAG, which is
    /// also the number of floating-point operations of one solve:
    /// `2*nnz - n` (each off-diagonal is a MAC = 2 flops, each row does one
    /// subtract-and-scale = 2 flops, minus the n redundant adds-to-zero...
    /// the paper's count, Table III column "Binary nodes").
    pub fn binary_nodes(&self) -> usize {
        2 * self.nnz() - self.n
    }

    /// The off-diagonal part of row `i`: parallel `(colidx, value)` slices.
    pub fn row_off_diag(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.rowptr[i];
        let hi = self.rowptr[i + 1] - 1; // last slot is the diagonal
        (&self.colidx[lo..hi], &self.values[lo..hi])
    }

    /// The diagonal value of row `i`.
    pub fn diag(&self, i: usize) -> f32 {
        self.values[self.rowptr[i + 1] - 1]
    }

    /// In-degree (number of off-diagonal entries) of row `i`.
    pub fn in_degree(&self, i: usize) -> usize {
        self.rowptr[i + 1] - self.rowptr[i] - 1
    }

    /// Maximum in-degree over all rows (the paper's `d` in the compiler
    /// complexity bound `O(nnz · d)`).
    pub fn max_in_degree(&self) -> usize {
        (0..self.n).map(|i| self.in_degree(i)).max().unwrap_or(0)
    }

    /// Build from unordered triplets `(row, col, value)`.
    ///
    /// Entries above the diagonal are rejected; duplicate entries are
    /// rejected; missing diagonals are rejected. Rows are reordered to the
    /// diagonal-last convention.
    pub fn from_triplets(n: usize, triplets: &[(u32, u32, f32)]) -> Result<Self> {
        let mut counts = vec![0usize; n];
        for &(r, c, _) in triplets {
            ensure!((r as usize) < n && (c as usize) < n, "index out of range");
            ensure!(c <= r, "entry ({r},{c}) above the diagonal");
            counts[r as usize] += 1;
        }
        let mut rowptr = vec![0usize; n + 1];
        for i in 0..n {
            rowptr[i + 1] = rowptr[i] + counts[i];
        }
        let nnz = rowptr[n];
        let mut colidx = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut cursor = rowptr.clone();
        for &(r, c, v) in triplets {
            let k = cursor[r as usize];
            colidx[k] = c;
            values[k] = v;
            cursor[r as usize] += 1;
        }
        // Per-row: sort ascending, then rotate the diagonal to the end.
        for i in 0..n {
            let (lo, hi) = (rowptr[i], rowptr[i + 1]);
            ensure!(hi > lo, "row {i} is empty (missing diagonal)");
            let mut idx: Vec<usize> = (lo..hi).collect();
            idx.sort_by_key(|&k| colidx[k]);
            let mut cs: Vec<u32> = idx.iter().map(|&k| colidx[k]).collect();
            let mut vs: Vec<f32> = idx.iter().map(|&k| values[k]).collect();
            for w in cs.windows(2) {
                ensure!(w[0] != w[1], "duplicate entry in row {i}");
            }
            ensure!(
                *cs.last().unwrap() == i as u32,
                "row {i} missing diagonal entry"
            );
            // Diagonal is currently last after the ascending sort (it has the
            // largest column in a lower-triangular row), which is already the
            // required convention.
            let dv = *vs.last().unwrap();
            ensure!(dv != 0.0, "zero diagonal in row {i}");
            colidx[lo..hi].copy_from_slice(&cs);
            values[lo..hi].copy_from_slice(&vs);
            let _ = &mut cs;
            let _ = &mut vs;
        }
        let m = Self {
            n,
            rowptr,
            colidx,
            values,
        };
        m.validate()?;
        Ok(m)
    }

    /// Check all structural invariants; returns an error describing the first
    /// violation.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.rowptr.len() == self.n + 1, "rowptr length");
        ensure!(
            *self.rowptr.last().unwrap() == self.colidx.len(),
            "rowptr[n] != nnz"
        );
        ensure!(self.colidx.len() == self.values.len(), "colidx/values length");
        for i in 0..self.n {
            let (lo, hi) = (self.rowptr[i], self.rowptr[i + 1]);
            if hi <= lo {
                bail!("row {i} empty");
            }
            if self.colidx[hi - 1] as usize != i {
                bail!("row {i}: diagonal not last");
            }
            if self.values[hi - 1] == 0.0 {
                bail!("row {i}: zero diagonal");
            }
            for k in lo..hi - 1 {
                if self.colidx[k] as usize >= i {
                    bail!("row {i}: off-diagonal column {} not below diagonal", self.colidx[k]);
                }
                if k > lo && self.colidx[k] <= self.colidx[k - 1] {
                    bail!("row {i}: columns not strictly ascending");
                }
            }
        }
        Ok(())
    }

    /// Dense (n×n) expansion, for small-matrix tests.
    pub fn to_dense(&self) -> Vec<Vec<f32>> {
        let mut d = vec![vec![0.0; self.n]; self.n];
        for i in 0..self.n {
            for k in self.rowptr[i]..self.rowptr[i + 1] {
                d[i][self.colidx[k] as usize] = self.values[k];
            }
        }
        d
    }

    /// The paper's Fig. 1 example: 10×10 lower-triangular pattern with unit
    /// diagonal and -1 off-diagonals. Used by unit tests and the quickstart.
    pub fn paper_fig1() -> Self {
        // Off-diagonal structure from Fig. 1(a)/(c): edges src -> dst.
        // Level 1: {1, 2, 5}; level 2: {3, 7}; level 3: {4, 6, 8}; ...
        let edges: &[(u32, u32)] = &[
            (1, 3),
            (2, 3),
            (1, 4),
            (3, 4),
            (5, 6),
            (3, 6),
            (2, 7),
            (5, 7),
            (4, 8),
            (7, 8),
            (6, 9),
            (8, 9),
            (8, 10),
            (9, 10),
        ];
        let n = 10;
        let mut t: Vec<(u32, u32, f32)> = (0..n).map(|i| (i as u32, i as u32, 1.0)).collect();
        for &(s, d) in edges {
            t.push((d - 1, s - 1, -1.0));
        }
        Self::from_triplets(n, &t).expect("fig1 example is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrMatrix {
        // [ 2        ]
        // [-1  4     ]
        // [ 0 -2  8  ]
        CsrMatrix::from_triplets(
            3,
            &[
                (0, 0, 2.0),
                (1, 0, -1.0),
                (1, 1, 4.0),
                (2, 1, -2.0),
                (2, 2, 8.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn builds_diag_last() {
        let m = tiny();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.off_diag_nnz(), 2);
        assert_eq!(m.diag(0), 2.0);
        assert_eq!(m.diag(1), 4.0);
        assert_eq!(m.diag(2), 8.0);
        let (c, v) = m.row_off_diag(1);
        assert_eq!(c, &[0]);
        assert_eq!(v, &[-1.0]);
        m.validate().unwrap();
    }

    #[test]
    fn binary_nodes_matches_paper_formula() {
        let m = tiny();
        assert_eq!(m.binary_nodes(), 2 * 5 - 3);
    }

    #[test]
    fn rejects_upper_entries() {
        assert!(CsrMatrix::from_triplets(2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0)]).is_err());
    }

    #[test]
    fn rejects_missing_diagonal() {
        assert!(CsrMatrix::from_triplets(2, &[(0, 0, 1.0), (1, 0, 1.0)]).is_err());
    }

    #[test]
    fn rejects_zero_diagonal() {
        assert!(CsrMatrix::from_triplets(1, &[(0, 0, 0.0)]).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        assert!(
            CsrMatrix::from_triplets(2, &[(0, 0, 1.0), (1, 0, 1.0), (1, 0, 2.0), (1, 1, 1.0)])
                .is_err()
        );
    }

    #[test]
    fn fig1_example_valid() {
        let m = CsrMatrix::paper_fig1();
        assert_eq!(m.n, 10);
        m.validate().unwrap();
        // Node 3 (0-based 2) has in-edges from rows 1 and 2 per the paper text
        // ("column indexes of the off-diagonal non-zeros are 1 and 2").
        let (c, _) = m.row_off_diag(2);
        assert_eq!(c, &[0, 1]);
    }

    #[test]
    fn in_degree_and_max() {
        let m = tiny();
        assert_eq!(m.in_degree(0), 0);
        assert_eq!(m.in_degree(1), 1);
        assert_eq!(m.max_in_degree(), 1);
    }

    #[test]
    fn to_dense_roundtrip() {
        let m = tiny();
        let d = m.to_dense();
        assert_eq!(d[1][0], -1.0);
        assert_eq!(d[2][2], 8.0);
        assert_eq!(d[0][1], 0.0);
    }
}
