//! MatrixMarket IO.
//!
//! Supports the `matrix coordinate real {general|symmetric}` header used by
//! the SuiteSparse collection so users with the paper's real benchmarks can
//! feed them in. Reading extracts the lower triangle (dropping strictly-upper
//! entries of general matrices, mirroring symmetric ones is unnecessary for
//! the lower factor) and enforces the diagonal-last convention; rows missing
//! a diagonal get a unit diagonal, matching common SpTRSV benchmarking
//! practice on pattern-only collections.

use super::CsrMatrix;
use anyhow::{bail, ensure, Context, Result};
use std::io::Write;
use std::path::Path;

/// Parse MatrixMarket text into a lower-triangular [`CsrMatrix`].
pub fn read_matrix_market_str(text: &str) -> Result<CsrMatrix> {
    let mut lines = text.lines();
    let header = lines.next().context("empty file")?;
    let h: Vec<&str> = header.split_whitespace().collect();
    ensure!(
        h.len() >= 4 && h[0] == "%%MatrixMarket" && h[1] == "matrix" && h[2] == "coordinate",
        "unsupported MatrixMarket header: {header}"
    );
    let field = h[3];
    ensure!(
        field == "real" || field == "integer" || field == "pattern",
        "unsupported field type {field}"
    );
    let mut dims: Option<(usize, usize, usize)> = None;
    let mut entries: Vec<(u32, u32, f32)> = Vec::new();
    for line in lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        if dims.is_none() {
            let r: usize = it.next().context("rows")?.parse()?;
            let c: usize = it.next().context("cols")?.parse()?;
            let z: usize = it.next().context("nnz")?.parse()?;
            ensure!(r == c, "matrix must be square, got {r}x{c}");
            dims = Some((r, c, z));
            continue;
        }
        let r: usize = it.next().context("entry row")?.parse()?;
        let c: usize = it.next().context("entry col")?.parse()?;
        let v: f32 = match field {
            "pattern" => 1.0,
            _ => it.next().context("entry value")?.parse()?,
        };
        ensure!(r >= 1 && c >= 1, "1-based indices expected");
        if c > r {
            continue; // keep the lower triangle only
        }
        entries.push(((r - 1) as u32, (c - 1) as u32, v));
    }
    let (n, _, _) = dims.context("missing size line")?;
    // Ensure every row has a diagonal; insert unit diagonals where absent,
    // and replace zero diagonals (pattern files) with 1.0.
    let mut has_diag = vec![false; n];
    for e in entries.iter_mut() {
        if e.0 == e.1 {
            has_diag[e.0 as usize] = true;
            if e.2 == 0.0 {
                e.2 = 1.0;
            }
        }
    }
    for (i, present) in has_diag.iter().enumerate() {
        if !present {
            entries.push((i as u32, i as u32, 1.0));
        }
    }
    CsrMatrix::from_triplets(n, &entries)
}

/// Read a MatrixMarket file from disk.
pub fn read_matrix_market(path: &Path) -> Result<CsrMatrix> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("open {}", path.display()))?;
    read_matrix_market_str(&text)
}

/// Write a matrix as `coordinate real general` (1-based, lower triangle).
pub fn write_matrix_market(m: &CsrMatrix, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by mgd-sptrsv")?;
    writeln!(f, "{} {} {}", m.n, m.n, m.nnz())?;
    for i in 0..m.n {
        for k in m.rowptr[i]..m.rowptr[i + 1] {
            writeln!(f, "{} {} {}", i + 1, m.colidx[k] + 1, m.values[k])?;
        }
    }
    Ok(())
}

/// Guard against absurd inputs when loading user files.
pub fn sanity_check(m: &CsrMatrix, max_n: usize) -> Result<()> {
    if m.n > max_n {
        bail!("matrix order {} exceeds supported maximum {max_n}", m.n);
    }
    m.validate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    const SAMPLE: &str = "%%MatrixMarket matrix coordinate real general
% comment line
3 3 5
1 1 2.0
2 1 -1.0
2 2 4.0
3 2 -2.0
3 3 8.0
";

    #[test]
    fn parses_sample() {
        let m = read_matrix_market_str(SAMPLE).unwrap();
        assert_eq!(m.n, 3);
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.diag(2), 8.0);
    }

    #[test]
    fn drops_upper_entries() {
        let text = "%%MatrixMarket matrix coordinate real general
2 2 3
1 2 9.0
1 1 1.0
2 2 1.0
";
        let m = read_matrix_market_str(text).unwrap();
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn inserts_missing_diagonal() {
        let text = "%%MatrixMarket matrix coordinate real general
2 2 1
2 1 -1.0
";
        let m = read_matrix_market_str(text).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.diag(0), 1.0);
        assert_eq!(m.diag(1), 1.0);
    }

    #[test]
    fn pattern_field_gets_unit_values() {
        let text = "%%MatrixMarket matrix coordinate pattern general
2 2 2
1 1
2 1
";
        let m = read_matrix_market_str(text).unwrap();
        assert_eq!(m.diag(0), 1.0);
        let (c, v) = m.row_off_diag(1);
        assert_eq!(c, &[0]);
        assert_eq!(v, &[1.0]);
    }

    #[test]
    fn rejects_non_square() {
        let text = "%%MatrixMarket matrix coordinate real general
2 3 1
1 1 1.0
";
        assert!(read_matrix_market_str(text).is_err());
    }

    #[test]
    fn roundtrip_through_disk() {
        let m = gen::banded(50, 3, 0.6, GenSeed(5));
        let dir = std::env::temp_dir().join("mgd_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.mtx");
        write_matrix_market(&m, &path).unwrap();
        let m2 = read_matrix_market(&path).unwrap();
        assert_eq!(m.n, m2.n);
        assert_eq!(m.nnz(), m2.nnz());
        assert_eq!(m.colidx, m2.colidx);
        for (a, b) in m.values.iter().zip(&m2.values) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn sanity_check_rejects_huge() {
        let m = gen::chain(10, GenSeed(1));
        assert!(sanity_check(&m, 5).is_err());
        assert!(sanity_check(&m, 100).is_ok());
    }
}
