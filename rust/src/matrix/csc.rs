//! CSC view of a lower-triangular matrix.
//!
//! The compiler and the DAG builder need out-edges (who consumes `x_i`),
//! which is exactly the column structure; this module materializes it once.

use super::CsrMatrix;

/// Compressed-sparse-column view. Only the off-diagonal structure carries
/// meaning for the DAG (diagonals are self-updates, not edges), but the full
/// matrix is stored for completeness.
#[derive(Debug, Clone)]
pub struct CscMatrix {
    /// Matrix order.
    pub n: usize,
    /// Column pointers, length `n + 1`.
    pub colptr: Vec<usize>,
    /// Row indices, column-major, ascending within a column.
    pub rowidx: Vec<u32>,
    /// Values, parallel to `rowidx`.
    pub values: Vec<f32>,
}

impl CscMatrix {
    /// Transpose-copy a CSR matrix into CSC.
    pub fn from_csr(m: &CsrMatrix) -> Self {
        let n = m.n;
        let nnz = m.nnz();
        let mut counts = vec![0usize; n];
        for &c in &m.colidx {
            counts[c as usize] += 1;
        }
        let mut colptr = vec![0usize; n + 1];
        for j in 0..n {
            colptr[j + 1] = colptr[j] + counts[j];
        }
        let mut rowidx = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut cursor = colptr.clone();
        // Row-major traversal emits ascending rows per column automatically.
        for i in 0..n {
            for k in m.rowptr[i]..m.rowptr[i + 1] {
                let j = m.colidx[k] as usize;
                let p = cursor[j];
                rowidx[p] = i as u32;
                values[p] = m.values[k];
                cursor[j] += 1;
            }
        }
        Self {
            n,
            colptr,
            rowidx,
            values,
        }
    }

    /// Rows that *depend on* `x_j` (strictly below the diagonal), i.e. the
    /// out-neighbors of node `j` in the DAG.
    pub fn consumers(&self, j: usize) -> &[u32] {
        let lo = self.colptr[j];
        let hi = self.colptr[j + 1];
        // The diagonal (row == j) is the first entry of the column in a
        // lower-triangular matrix stored with ascending rows.
        debug_assert!(lo < hi && self.rowidx[lo] as usize == j);
        &self.rowidx[lo + 1..hi]
    }

    /// Out-degree of node `j` in the DAG.
    pub fn out_degree(&self, j: usize) -> usize {
        self.colptr[j + 1] - self.colptr[j] - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_csr_consumers() {
        let m = CsrMatrix::from_triplets(
            3,
            &[
                (0, 0, 2.0),
                (1, 0, -1.0),
                (1, 1, 4.0),
                (2, 0, -3.0),
                (2, 1, -2.0),
                (2, 2, 8.0),
            ],
        )
        .unwrap();
        let c = CscMatrix::from_csr(&m);
        assert_eq!(c.consumers(0), &[1, 2]);
        assert_eq!(c.consumers(1), &[2]);
        assert_eq!(c.consumers(2), &[] as &[u32]);
        assert_eq!(c.out_degree(0), 2);
        assert_eq!(c.out_degree(2), 0);
    }

    #[test]
    fn nnz_preserved() {
        let m = CsrMatrix::paper_fig1();
        let c = CscMatrix::from_csr(&m);
        assert_eq!(c.rowidx.len(), m.nnz());
        assert_eq!(c.colptr[c.n], m.nnz());
    }
}
