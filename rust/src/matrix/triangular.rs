//! Reference solvers — the golden numerics every other component is checked
//! against, and the CPU baseline's serial inner kernel (paper Algorithm 1).

use super::CsrMatrix;

/// Serial forward substitution, exactly the paper's Algorithm 1.
pub fn solve_serial(m: &CsrMatrix, b: &[f32]) -> Vec<f32> {
    assert_eq!(b.len(), m.n);
    let mut x = vec![0f32; m.n];
    for i in 0..m.n {
        let ie = m.rowptr[i + 1] - 1;
        let mut sum = 0f32;
        for j in m.rowptr[i]..ie {
            sum += m.values[j] * x[m.colidx[j] as usize];
        }
        x[i] = (b[i] - sum) / m.values[ie];
    }
    x
}

/// Serial forward substitution in f64 (for tolerance baselines in tests).
pub fn solve_serial_f64(m: &CsrMatrix, b: &[f32]) -> Vec<f64> {
    let mut x = vec![0f64; m.n];
    for i in 0..m.n {
        let ie = m.rowptr[i + 1] - 1;
        let mut sum = 0f64;
        for j in m.rowptr[i]..ie {
            sum += m.values[j] as f64 * x[m.colidx[j] as usize];
        }
        x[i] = (b[i] as f64 - sum) / m.values[ie] as f64;
    }
    x
}

/// Residual check: max_i |(L x)_i - b_i| / (|b_i| + 1).
pub fn max_relative_residual(m: &CsrMatrix, x: &[f32], b: &[f32]) -> f64 {
    let mut worst = 0f64;
    for i in 0..m.n {
        let mut acc = 0f64;
        for k in m.rowptr[i]..m.rowptr[i + 1] {
            acc += m.values[k] as f64 * x[m.colidx[k] as usize] as f64;
        }
        let r = (acc - b[i] as f64).abs() / (b[i].abs() as f64 + 1.0);
        worst = worst.max(r);
    }
    worst
}

/// Compare a solution against the serial reference with a mixed
/// absolute/relative f32 tolerance. Returns the worst row on failure.
pub fn assert_close_to_reference(m: &CsrMatrix, b: &[f32], x: &[f32], tol: f32) {
    let r = solve_serial(m, b);
    for i in 0..m.n {
        let denom = r[i].abs().max(1.0);
        assert!(
            (x[i] - r[i]).abs() <= tol * denom,
            "row {i}: got {} want {} (tol {tol})",
            x[i],
            r[i]
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    #[test]
    fn solves_identity() {
        let m = CsrMatrix::from_triplets(3, &[(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)]).unwrap();
        let x = solve_serial(&m, &[3.0, -1.0, 2.0]);
        assert_eq!(x, vec![3.0, -1.0, 2.0]);
    }

    #[test]
    fn solves_dense_lower_3x3() {
        // L = [2 0 0; 1 3 0; 4 5 6], b = L * [1,2,3]^T = [2, 7, 32]
        let m = CsrMatrix::from_triplets(
            3,
            &[
                (0, 0, 2.0),
                (1, 0, 1.0),
                (1, 1, 3.0),
                (2, 0, 4.0),
                (2, 1, 5.0),
                (2, 2, 6.0),
            ],
        )
        .unwrap();
        let x = solve_serial(&m, &[2.0, 7.0, 32.0]);
        assert!((x[0] - 1.0).abs() < 1e-6);
        assert!((x[1] - 2.0).abs() < 1e-6);
        assert!((x[2] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn fig1_unit_lower_solve() {
        // Unit diagonal, -1 off-diagonals: x_i = b_i + sum of solved deps.
        let m = CsrMatrix::paper_fig1();
        let b = vec![1.0f32; 10];
        let x = solve_serial(&m, &b);
        assert_eq!(x[0], 1.0); // source node
        assert_eq!(x[2], 3.0); // 1 + x1 + x2 = 3
        assert!(max_relative_residual(&m, &x, &b) < 1e-6);
    }

    #[test]
    fn residual_detects_garbage() {
        let m = CsrMatrix::paper_fig1();
        let b = vec![1.0f32; 10];
        let x = vec![0.0f32; 10];
        assert!(max_relative_residual(&m, &x, &b) > 0.1);
    }

    #[test]
    fn random_matrices_have_small_residual() {
        for seed in 0..5 {
            let m = gen::circuit(300, 5, 0.7, GenSeed(seed));
            let b: Vec<f32> = (0..m.n).map(|i| (i % 7) as f32 - 3.0).collect();
            let x = solve_serial(&m, &b);
            assert!(
                max_relative_residual(&m, &x, &b) < 1e-3,
                "seed {seed}"
            );
        }
    }

    #[test]
    fn f64_close_to_f32_on_well_conditioned() {
        let m = gen::banded(200, 4, 0.8, GenSeed(1));
        let b = vec![1.0f32; m.n];
        let x32 = solve_serial(&m, &b);
        let x64 = solve_serial_f64(&m, &b);
        for i in 0..m.n {
            assert!((x32[i] as f64 - x64[i]).abs() < 1e-3 * x64[i].abs().max(1.0));
        }
    }
}
