//! Sparse lower-triangular matrix substrate.
//!
//! Conventions follow the paper (Fig. 1 / Algorithm 1):
//!
//! - Matrices are **lower triangular** with a nonzero diagonal.
//! - Storage is CSR with, inside each row, the off-diagonal entries first in
//!   ascending column order and **the diagonal entry last** (the paper's
//!   `rowptr[i+1]-1` slot).
//! - Values are `f32` (the accelerator's PE is a 32-bit float adder+multiplier).

pub mod csc;
pub mod csr;
pub mod gen;
pub mod io;
pub mod triangular;

pub use csc::CscMatrix;
pub use csr::CsrMatrix;
