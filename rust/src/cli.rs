//! Command-line interface (hand-rolled — no clap in the offline image).
//!
//! ```text
//! mgd compile  <matrix.mtx | gen:<family>:<n>:<seed>>   — compile & report
//! mgd sim      <matrix>                                 — compile + simulate + verify
//! mgd check    <matrix> [--corrupt deps|cycle|ext-order|par-width]
//!                                                       — static MGD plan audit
//! mgd check-ir <matrix> [--corrupt oob|double-write|csr-order|dead-slot|zero-diag|deps]
//!                                                       — kernel-IR lowering audit
//! mgd solve    <matrix> [--rhs ones|ramp] [--backend native|pjrt|auto]
//!                        [--scheduler level|mgd|kir|auto] [--artifacts DIR]
//! mgd serve    --matrices <spec,spec,...> [--shards N] [--workers N]
//!                        [--requests N] [--swap-every N] [--backend ...]
//!                        [--scheduler ...] [--queue-cap N]
//!                        [--admission block|shed|by-class]
//!                        [--reserved-latency-workers N] [--session-depth N]
//!                        [--placement cost|round-robin] [--bulk-aging-ms N]
//! mgd bench    <fig9a|fig9bc|fig9def|fig10|fig11|fig12|table2|table3|table4|backends|schedulers|serving|concurrency|admission|streaming|skew|all>
//!                        [--scale small|full]
//! mgd stats    <matrix>                                 — Table III row for one matrix
//! ```

use crate::arch::ArchConfig;
use crate::bench_harness::report;
use crate::compiler::{compile, CompilerConfig};
use crate::coordinator::{
    Admission, AdmissionPolicy, PlacementPolicy, ServiceConfig, ShardedServiceConfig,
    ShardedSolveService, SolveService,
};
use crate::graph::{Dag, DagStats, Levels};
use crate::matrix::gen::{self, GenSeed};
use crate::matrix::{io, CsrMatrix};
use crate::runtime::{
    kir, mgd_exec, BackendConfig, BackendKind, MgdPlan, MgdPlanConfig, NativeConfig, SchedulerKind,
};
use crate::sim::Accelerator;
use crate::util::Table;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Parse a matrix argument: a MatrixMarket path or `gen:<family>:<n>:<seed>`.
pub fn load_matrix(spec: &str) -> Result<CsrMatrix> {
    if let Some(rest) = spec.strip_prefix("gen:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 3 {
            bail!("expected gen:<family>:<n>:<seed>");
        }
        let n: usize = parts[1].parse()?;
        let seed = GenSeed(parts[2].parse()?);
        return Ok(match parts[0] {
            "circuit" => gen::circuit(n, 5, 0.8, seed),
            "banded" => gen::banded(n, (n / 64).clamp(2, 24), 0.6, seed),
            "grid" => {
                let side = (n as f64).sqrt().ceil() as usize;
                gen::grid2d(side, side, true, seed)
            }
            "powerlaw" => gen::power_law(n, 1.2, (n / 8).clamp(4, 200), seed),
            "shallow" => gen::shallow(n, 0.4, seed),
            "chain" => gen::chain(n, seed),
            other => bail!("unknown family {other}"),
        });
    }
    io::read_matrix_market(&PathBuf::from(spec))
}

fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

/// Backend selection shared by `solve` and `serve`: `--backend`,
/// `--scheduler`, `--artifacts` and `--reserved-latency-workers` with
/// the same defaults.
fn backend_config(args: &[String]) -> Result<BackendConfig> {
    let artifacts = flag_value(args, "--artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let kind: BackendKind = flag_value(args, "--backend")
        .as_deref()
        .unwrap_or("auto")
        .parse()?;
    let scheduler: SchedulerKind = flag_value(args, "--scheduler")
        .as_deref()
        .unwrap_or("auto")
        .parse()?;
    let reserved_latency_workers: usize = flag_value(args, "--reserved-latency-workers")
        .as_deref()
        .unwrap_or("0")
        .parse()
        .context("--reserved-latency-workers")?;
    Ok(BackendConfig {
        kind,
        artifacts,
        native: NativeConfig {
            scheduler,
            reserved_latency_workers,
            ..NativeConfig::default()
        },
    })
}

/// Seed one in-memory corruption into a built plan (`mgd check
/// --corrupt <kind>`): a demonstration — and the CI smoke — of the
/// static verifier's rejection path. Each kind breaks exactly one
/// invariant family that [`MgdPlan::verify`] audits.
fn corrupt_plan(plan: &mut MgdPlan, kind: &str) -> Result<()> {
    let k = plan
        .nodes
        .iter()
        .position(|nd| nd.ext.len() >= 2 && !nd.succs.is_empty())
        .context("matrix too small to corrupt: no interior node with two external sources")?;
    match kind {
        // Readiness counter out of step with the real predecessor count.
        "deps" => plan.nodes[k].init_deps += 1,
        // A self-edge: the successor list stops mirroring the (acyclic)
        // recomputed dependency edges.
        "cycle" => plan.nodes[k].succs.insert(0, k as u32),
        // ICR gather list no longer ascending/deduplicated.
        "ext-order" => plan.nodes[k].ext.reverse(),
        // Advertised parallelism diverges from the node DAG's width.
        "par-width" => plan.par_width += 1,
        other => bail!("unknown corruption {other} (deps|cycle|ext-order|par-width)"),
    }
    Ok(())
}

/// Entry point used by `main`.
pub fn run() {
    if let Err(e) = run_inner() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run_inner() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    match cmd.as_str() {
        "compile" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let cfg = CompilerConfig::default();
            let p = compile(&m, &cfg)?;
            println!(
                "n={} nnz={} cycles={} predicted {:.2} GOPS, utilization {:.1}%, \
                 compile {:.1} ms, constraints={}, conflicts={}, spills={}",
                p.n,
                p.nnz,
                p.predicted.cycles,
                p.predicted_gops(),
                100.0 * p.predicted.utilization(p.num_cus()),
                p.compile.compile_seconds * 1e3,
                p.compile.constraints,
                p.predicted.conflicts,
                p.compile.spills,
            );
        }
        "sim" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let cfg = CompilerConfig::default();
            let p = compile(&m, &cfg)?;
            let mut acc = Accelerator::new(cfg.arch);
            let b = vec![1.0f32; m.n];
            let run = acc.run(&p, &b)?;
            run.stats.verify_against(&p.predicted)?;
            crate::matrix::triangular::assert_close_to_reference(&m, &b, &run.x, 1e-3);
            println!(
                "verified: {} cycles ({} exec, {} bnop, {} pnop, {} dnop, {} lnop), \
                 {:.2} GOPS, numerics OK, double-entry OK",
                run.stats.cycles,
                run.stats.exec,
                run.stats.bnop,
                run.stats.pnop,
                run.stats.dnop,
                run.stats.lnop,
                run.gops(&cfg.arch, p.flops()),
            );
        }
        "check" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let mut plan = MgdPlan::build(&m, MgdPlanConfig::default());
            if let Some(kind) = flag_value(&args, "--corrupt") {
                corrupt_plan(&mut plan, &kind)?;
                println!("seeded `{kind}` corruption into the built plan");
            }
            plan.verify().context("static plan audit")?;
            println!(
                "plan OK: n={} nodes={} dep_edges={} roots={} par_width={}",
                plan.n,
                plan.num_nodes(),
                plan.num_dep_edges(),
                plan.roots.len(),
                plan.par_width,
            );
        }
        "check-ir" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let plan = Arc::new(MgdPlan::build(&m, MgdPlanConfig::default()));
            let mut prog = kir::lower(&plan);
            if let Some(kind) = flag_value(&args, "--corrupt") {
                let kind: kir::CorruptKind = kind.parse()?;
                kir::corrupt_program(&mut prog, kind)?;
                println!("seeded `{kind}` corruption into the lowered program");
            }
            kir::verify(&prog, &plan).context("kernel-IR audit")?;
            // A clean audit also proves the gated tier end to end: run the
            // verified interpreter once and require bitwise equality with
            // the serial reference.
            let kernel = kir::VerifiedKernel::build(&plan)?;
            let b = vec![1.0f32; m.n];
            let (xs, _) = mgd_exec::execute_kernel(&kernel, &[b.clone()], 2)?;
            let x_ref = crate::matrix::triangular::solve_serial(&m, &b);
            if xs[0].iter().zip(&x_ref).any(|(a, r)| a.to_bits() != r.to_bits()) {
                bail!("verified interpreter diverged from the serial reference");
            }
            println!(
                "kir OK: n={} nodes={} ops={} gathers={} — verified interpreter \
                 bitwise-equal to the serial reference",
                plan.n,
                prog.nodes.len(),
                prog.num_ops(),
                prog.num_gathers(),
            );
        }
        "solve" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let cfg = ServiceConfig {
                backend: backend_config(&args)?,
                ..ServiceConfig::default()
            };
            let svc = SolveService::start(&m, cfg)?;
            let b: Vec<f32> = match flag_value(&args, "--rhs").as_deref() {
                Some("ramp") => (0..m.n).map(|i| i as f32 / m.n as f32).collect(),
                _ => vec![1.0f32; m.n],
            };
            let resp = svc.solve(b)?;
            println!(
                "backend {}; x[0..4] = {:?}; host {:.3} ms; accel {:.3} µs ({} cycles, {:.2} GOPS, {:.1} GOPS/W)",
                svc.backend_name(),
                &resp.x[..resp.x.len().min(4)],
                resp.host_seconds * 1e3,
                resp.metrics.accel_seconds * 1e6,
                resp.metrics.cycles,
                resp.metrics.gops,
                resp.metrics.gops_per_w,
            );
            svc.shutdown();
        }
        "serve" => {
            let specs = flag_value(&args, "--matrices")
                .context("serve needs --matrices <spec,spec,...> (each a path or gen:...)")?;
            let shards: usize = flag_value(&args, "--shards")
                .as_deref()
                .unwrap_or("2")
                .parse()
                .context("--shards")?;
            let workers: usize = flag_value(&args, "--workers")
                .as_deref()
                .unwrap_or("2")
                .parse()
                .context("--workers")?;
            let requests: usize = flag_value(&args, "--requests")
                .as_deref()
                .unwrap_or("32")
                .parse()
                .context("--requests")?;
            let swap_every: usize = flag_value(&args, "--swap-every")
                .as_deref()
                .unwrap_or("0")
                .parse()
                .context("--swap-every")?;
            let queue_cap: usize = flag_value(&args, "--queue-cap")
                .as_deref()
                .unwrap_or("0")
                .parse()
                .context("--queue-cap")?;
            let admission: AdmissionPolicy = flag_value(&args, "--admission")
                .as_deref()
                .unwrap_or("block")
                .parse()?;
            let session_depth: usize = flag_value(&args, "--session-depth")
                .as_deref()
                .unwrap_or("1")
                .parse()
                .context("--session-depth")?;
            let placement: PlacementPolicy = flag_value(&args, "--placement")
                .as_deref()
                .unwrap_or("cost")
                .parse()?;
            let bulk_aging_ms: u64 = flag_value(&args, "--bulk-aging-ms")
                .as_deref()
                .unwrap_or("0")
                .parse()
                .context("--bulk-aging-ms")?;
            let cfg = ShardedServiceConfig {
                shards,
                workers_per_shard: workers,
                backend: backend_config(&args)?,
                queue_cap,
                admission,
                placement,
                bulk_aging_ms,
                ..ShardedServiceConfig::default()
            };
            let svc = ShardedSolveService::start(cfg)?;
            let mut keys: Vec<(String, usize)> = Vec::new();
            for spec in specs.split(',').filter(|s| !s.is_empty()) {
                let m = load_matrix(spec)?;
                let entry = svc.register(spec, &m)?;
                let sched = entry
                    .scheduler_choice()
                    .map(|k| k.to_string())
                    .unwrap_or_else(|| "backend-default".into());
                println!(
                    "registered {spec:?} (n={}, nnz={}, cost weight {}) on shard {} \
                     ({placement} placement, scheduler {sched})",
                    m.n,
                    m.nnz(),
                    entry.cost().weight(),
                    entry.shard(),
                );
                keys.push((spec.to_string(), m.n));
            }
            if keys.is_empty() {
                bail!("--matrices listed no matrix specs");
            }
            // Synthetic request stream, round-robin across the registered
            // matrices; every reply is awaited (and its error surfaced).
            // With --swap-every N, every Nth request triggers a live hot
            // swap of the next matrix (reloaded from its spec) while the
            // stream keeps flowing — the requests straddling the swap are
            // served by whichever fully-formed entry they resolve.
            let maybe_swap = |i: usize, swaps: &mut usize| -> Result<()> {
                if swap_every > 0 && i > 0 && i % swap_every == 0 {
                    let (key, _) = &keys[*swaps % keys.len()];
                    let m = load_matrix(key)?;
                    let entry = svc.swap(key, &m)?;
                    println!(
                        "hot-swapped {key:?} mid-stream (shard {}, {} served on this key so far)",
                        entry.shard(),
                        entry.served(),
                    );
                    *swaps += 1;
                }
                Ok(())
            };
            let mut swaps = 0usize;
            if session_depth > 1 {
                // Streaming mode: one pipelined `SolveSession` per key.
                // Admission is checked per submit against the session's
                // pinned class, up to --session-depth replies stay in
                // flight per key, and a hot swap surfaces as an epoch
                // boundary inside the session rather than an error.
                let mut sessions = Vec::with_capacity(keys.len());
                for (key, _) in &keys {
                    sessions.push(svc.open_session(key, session_depth)?);
                }
                let mut replies = 0usize;
                for i in 0..requests {
                    maybe_swap(i, &mut swaps)?;
                    let idx = i % keys.len();
                    let n = keys[idx].1;
                    sessions[idx].submit(vec![1.0f32; n])?;
                    // Opportunistic harvest keeps per-session backlogs at
                    // the configured depth instead of buffering the whole
                    // stream.
                    for s in &mut sessions {
                        while let Some(reply) = s.try_next() {
                            reply?;
                            replies += 1;
                        }
                    }
                }
                let mut epochs = 0u64;
                for s in &mut sessions {
                    for reply in s.drain() {
                        reply?;
                        replies += 1;
                    }
                    epochs += s.epoch();
                }
                println!(
                    "streamed {replies} replies through {} sessions (depth {session_depth}); \
                     {epochs} epoch boundaries observed",
                    keys.len(),
                );
            } else {
                let mut rxs = Vec::with_capacity(requests);
                for i in 0..requests {
                    maybe_swap(i, &mut swaps)?;
                    let (key, n) = &keys[i % keys.len()];
                    // `try_route` so a shed is a structured verdict at submit
                    // time (expected under overload with --admission
                    // shed|by-class) rather than something to fish out of an
                    // error message; admitted replies are awaited strictly.
                    match svc.try_route(key, vec![1.0f32; *n], None)? {
                        Admission::Admitted(handle) => rxs.push(handle),
                        Admission::Shed(_) => {}
                    }
                }
                for rx in rxs {
                    rx.wait()?;
                }
            }
            let mut t = Table::new(vec!["shard", "served", "errors", "rounds", "solve ms"]);
            for s in svc.shard_stats() {
                t.row(vec![
                    s.shard.to_string(),
                    s.served.to_string(),
                    s.errors.to_string(),
                    s.batched_rounds.to_string(),
                    format!("{:.3}", s.solve_seconds * 1e3),
                ]);
            }
            println!("{}", t.render());
            let agg = svc.stats();
            println!(
                "backend {}; {} matrices on {} shards; {} served, {} errors, {} rounds, \
                 {:.3} ms in backend; peak pool-session concurrency {}",
                svc.backend_name(),
                svc.registry().len(),
                svc.num_shards(),
                agg.served,
                agg.errors,
                agg.batched_rounds,
                agg.solve_seconds * 1e3,
                agg.peak_concurrency,
            );
            println!(
                "admission {admission} (queue cap {queue_cap}, bulk aging {bulk_aging_ms} ms): \
                 {} latency + {} bulk admitted, {} latency + {} bulk shed, \
                 {} bulk aged past latency, peak queue depth {}",
                agg.admitted_latency,
                agg.admitted_bulk,
                agg.shed_latency,
                agg.shed_bulk,
                agg.aged_bulk,
                agg.peak_queue_depth,
            );
            svc.shutdown();
        }
        "bench" => {
            let id = args.get(1).context("experiment id")?;
            let scale = flag_value(&args, "--scale").unwrap_or_else(|| "small".into());
            if id == "all" {
                for id in report::ALL_EXPERIMENTS {
                    println!("==== {id} ====");
                    println!("{}", report::run_experiment(id, &scale)?);
                }
            } else {
                println!("{}", report::run_experiment(id, &scale)?);
            }
        }
        "stats" => {
            let m = load_matrix(args.get(1).context("matrix argument")?)?;
            let g = Dag::from_csr(&m);
            let lv = Levels::compute(&g);
            let st = DagStats::compute(&g, &lv, ArchConfig::default().num_cus());
            println!("{st:#?}");
        }
        "help" | "--help" | "-h" => print_usage(),
        other => {
            print_usage();
            bail!("unknown command {other}");
        }
    }
    Ok(())
}

fn print_usage() {
    println!(
        "mgd — medium-granularity-dataflow SpTRSV accelerator\n\
         usage:\n\
         \x20 mgd compile <matrix>             compile & report schedule stats\n\
         \x20 mgd sim     <matrix>             compile + cycle-accurate sim + verify\n\
         \x20 mgd check   <matrix> [--corrupt deps|cycle|ext-order|par-width]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 static MGD plan audit without executing (the same\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 verifier debug builds run at register/swap); --corrupt\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 seeds one defect to demonstrate the rejection path\n\
         \x20 mgd check-ir <matrix> [--corrupt oob|double-write|csr-order|\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 dead-slot|zero-diag|deps]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 lower the MGD plan to kernel-IR bytecode, run the\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 abstract-interpretation verifier, and (when clean)\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 check the unchecked interpreter against the serial\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 reference bitwise; --corrupt seeds one bytecode defect\n\
         \x20 mgd solve   <matrix> [--rhs ramp] [--backend native|pjrt|auto]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--scheduler level|mgd|kir|auto] [--artifacts DIR]\n\
         \x20 mgd serve   --matrices <spec,spec,...> [--shards N] [--workers N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--requests N] [--swap-every N] [--backend ...]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--scheduler ...] [--queue-cap N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--admission block|shed|by-class]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--reserved-latency-workers N] [--session-depth N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 [--placement cost|round-robin] [--bulk-aging-ms N]\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 sharded multi-matrix service demo + per-shard stats;\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 --swap-every N hot-swaps a matrix every N requests;\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 --queue-cap bounds each shard's queue lanes and\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 --admission picks the full-lane policy (block parks,\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 shed rejects with a reason reply, by-class sheds bulk\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 only); --reserved-latency-workers keeps pool workers\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 for latency-class solves; --session-depth > 1 drives\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 the stream through pipelined solve sessions (one per\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 key, that many replies in flight each); --placement\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 picks cost-model least-loaded (default) or legacy\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 round-robin shard assignment; --bulk-aging-ms bounds\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 how long by-class can hold a bulk job behind latency\n\
         \x20\x20\x20\x20\x20\x20\x20\x20\x20\x20\x20 work before promoting it (0 = never promote)\n\
         \x20 mgd bench   <experiment|all> [--scale small|full]\n\
         \x20 mgd stats   <matrix>             Table III characteristics\n\
         matrix: path to MatrixMarket file or gen:<family>:<n>:<seed>\n\
         families: circuit banded grid powerlaw shallow chain\n\
         backend: native (default serve path), pjrt (needs --features pjrt + artifacts), auto\n\
         scheduler (native backend): level (barriered reference), mgd (barrier-free\n\
         \x20 medium-granularity dataflow), kir (mgd with statically verified kernel-IR\n\
         \x20 node bodies; falls back to mgd if verification fails), auto (per-matrix\n\
         \x20 cost model: barriered vs barrier-free cycle comparison over the\n\
         \x20 level-width profile; never picks kir — the unchecked tier is opt-in)\n\
         experiments: fig9a fig9bc fig9def fig10 fig11 fig12 table2 table3 table4\n\
         \x20 backends schedulers serving concurrency admission streaming skew"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_matrix_gen_specs() {
        for spec in [
            "gen:circuit:100:1",
            "gen:banded:100:2",
            "gen:grid:100:3",
            "gen:powerlaw:100:4",
            "gen:shallow:100:5",
            "gen:chain:50:6",
        ] {
            let m = load_matrix(spec).unwrap();
            assert!(m.n >= 50);
            m.validate().unwrap();
        }
    }

    #[test]
    fn load_matrix_rejects_bad_specs() {
        assert!(load_matrix("gen:nosuch:10:1").is_err());
        assert!(load_matrix("gen:circuit:10").is_err());
        assert!(load_matrix("/nonexistent/file.mtx").is_err());
    }

    #[test]
    fn scheduler_flag_parses_like_the_solve_command() {
        let args: Vec<String> = ["solve", "gen:chain:10:1", "--scheduler", "mgd"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let scheduler: SchedulerKind = flag_value(&args, "--scheduler")
            .as_deref()
            .unwrap_or("auto")
            .parse()
            .unwrap();
        assert_eq!(scheduler, SchedulerKind::Mgd);
        let none: Vec<String> = vec!["solve".into()];
        let scheduler: SchedulerKind = flag_value(&none, "--scheduler")
            .as_deref()
            .unwrap_or("auto")
            .parse()
            .unwrap();
        assert_eq!(scheduler, SchedulerKind::Auto);
        assert!("coarse".parse::<SchedulerKind>().is_err());
    }

    #[test]
    fn serve_flags_parse_with_defaults() {
        let args: Vec<String> = [
            "serve",
            "--matrices",
            "gen:chain:50:1,gen:banded:100:2",
            "--shards",
            "3",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(
            flag_value(&args, "--matrices").unwrap(),
            "gen:chain:50:1,gen:banded:100:2"
        );
        let shards: usize = flag_value(&args, "--shards")
            .as_deref()
            .unwrap_or("2")
            .parse()
            .unwrap();
        assert_eq!(shards, 3);
        // Unset flags fall back to the documented defaults.
        let workers: usize = flag_value(&args, "--workers")
            .as_deref()
            .unwrap_or("2")
            .parse()
            .unwrap();
        assert_eq!(workers, 2);
        let cfg = backend_config(&args).unwrap();
        assert_eq!(cfg.kind, BackendKind::Auto);
        assert_eq!(cfg.native.scheduler, SchedulerKind::Auto);
    }

    #[test]
    fn swap_every_flag_parses_with_zero_default() {
        let args: Vec<String> = ["serve", "--matrices", "gen:chain:50:1", "--swap-every", "8"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let every: usize = flag_value(&args, "--swap-every")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(every, 8);
        // Unset means never swap.
        let none: Vec<String> = vec!["serve".into()];
        let every: usize = flag_value(&none, "--swap-every")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(every, 0);
    }

    #[test]
    fn session_depth_flag_parses_with_one_default() {
        let args: Vec<String> = ["serve", "--matrices", "gen:chain:50:1", "--session-depth", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let depth: usize = flag_value(&args, "--session-depth")
            .as_deref()
            .unwrap_or("1")
            .parse()
            .unwrap();
        assert_eq!(depth, 4);
        // Unset means the call-per-solve demo path (no sessions).
        let none: Vec<String> = vec!["serve".into()];
        let depth: usize = flag_value(&none, "--session-depth")
            .as_deref()
            .unwrap_or("1")
            .parse()
            .unwrap();
        assert_eq!(depth, 1);
    }

    #[test]
    fn admission_flags_parse_with_defaults() {
        let args: Vec<String> = [
            "serve",
            "--matrices",
            "gen:chain:50:1",
            "--queue-cap",
            "32",
            "--admission",
            "by-class",
            "--reserved-latency-workers",
            "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cap: usize = flag_value(&args, "--queue-cap")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(cap, 32);
        let policy: AdmissionPolicy = flag_value(&args, "--admission")
            .as_deref()
            .unwrap_or("block")
            .parse()
            .unwrap();
        assert_eq!(policy, AdmissionPolicy::ByClass);
        let cfg = backend_config(&args).unwrap();
        assert_eq!(cfg.native.reserved_latency_workers, 2);
        // Unset flags fall back to the documented defaults (unbounded
        // first-come, nothing reserved).
        let none: Vec<String> = vec!["serve".into()];
        let cap: usize = flag_value(&none, "--queue-cap")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(cap, 0);
        let policy: AdmissionPolicy = flag_value(&none, "--admission")
            .as_deref()
            .unwrap_or("block")
            .parse()
            .unwrap();
        assert_eq!(policy, AdmissionPolicy::Block);
        assert_eq!(backend_config(&none).unwrap().native.reserved_latency_workers, 0);
        // Unknown policies error with the accepted set.
        assert!("drop".parse::<AdmissionPolicy>().is_err());
    }

    #[test]
    fn placement_flag_parses_with_cost_default() {
        let args: Vec<String> = [
            "serve",
            "--matrices",
            "gen:chain:50:1",
            "--placement",
            "round-robin",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let placement: PlacementPolicy = flag_value(&args, "--placement")
            .as_deref()
            .unwrap_or("cost")
            .parse()
            .unwrap();
        assert_eq!(placement, PlacementPolicy::RoundRobin);
        // Unset means cost-model least-loaded placement.
        let none: Vec<String> = vec!["serve".into()];
        let placement: PlacementPolicy = flag_value(&none, "--placement")
            .as_deref()
            .unwrap_or("cost")
            .parse()
            .unwrap();
        assert_eq!(placement, PlacementPolicy::Cost);
        assert!("hash".parse::<PlacementPolicy>().is_err());
    }

    #[test]
    fn bulk_aging_flag_parses_with_zero_default() {
        let args: Vec<String> = ["serve", "--matrices", "gen:chain:50:1", "--bulk-aging-ms", "25"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let aging: u64 = flag_value(&args, "--bulk-aging-ms")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(aging, 25);
        // Unset means strict latency-before-bulk draining (no promotion).
        let none: Vec<String> = vec!["serve".into()];
        let aging: u64 = flag_value(&none, "--bulk-aging-ms")
            .as_deref()
            .unwrap_or("0")
            .parse()
            .unwrap();
        assert_eq!(aging, 0);
    }

    #[test]
    fn backend_flag_parses_like_the_solve_command() {
        let args: Vec<String> = ["solve", "gen:chain:10:1", "--backend", "native"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let kind: BackendKind = flag_value(&args, "--backend")
            .as_deref()
            .unwrap_or("auto")
            .parse()
            .unwrap();
        assert_eq!(kind, BackendKind::Native);
        let none: Vec<String> = vec!["solve".into()];
        let kind: BackendKind = flag_value(&none, "--backend")
            .as_deref()
            .unwrap_or("auto")
            .parse()
            .unwrap();
        assert_eq!(kind, BackendKind::Auto);
        assert!("gpu".parse::<BackendKind>().is_err());
    }

    #[test]
    fn check_corruption_kinds_are_all_rejected_by_verify() {
        let m = gen::banded(200, 4, 0.7, GenSeed(5));
        for kind in ["deps", "cycle", "ext-order", "par-width"] {
            let mut plan = MgdPlan::build(&m, MgdPlanConfig::default());
            plan.verify().expect("freshly built plan verifies");
            corrupt_plan(&mut plan, kind).unwrap();
            assert!(plan.verify().is_err(), "{kind} corruption must be rejected");
        }
        let mut plan = MgdPlan::build(&m, MgdPlanConfig::default());
        assert!(corrupt_plan(&mut plan, "nope").is_err(), "unknown kind errors");
    }

    #[test]
    fn check_ir_corruption_kinds_are_all_rejected() {
        let m = gen::banded(200, 4, 0.7, GenSeed(5));
        let plan = Arc::new(MgdPlan::build(&m, MgdPlanConfig::default()));
        for kind in ["oob", "double-write", "csr-order", "dead-slot", "zero-diag", "deps"] {
            let mut prog = kir::lower(&plan);
            kir::verify(&prog, &plan).expect("freshly lowered program verifies");
            kir::corrupt_program(&mut prog, kind.parse().unwrap()).unwrap();
            assert!(kir::verify(&prog, &plan).is_err(), "{kind} corruption must be rejected");
        }
        assert!("nope".parse::<kir::CorruptKind>().is_err(), "unknown kind errors");
    }
}
