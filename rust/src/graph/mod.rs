//! DAG view of a sparse triangular matrix.
//!
//! Nodes are matrix rows; a directed edge `j → i` exists for every
//! off-diagonal nonzero `L[i][j]` and carries one multiply-accumulate.

pub mod dag;
pub mod levels;
pub mod stats;

pub use dag::Dag;
pub use levels::Levels;
pub use stats::{DagStats, CDU_THRESHOLD_FRACTION};
