//! DAG characterization metrics from the paper's Table III:
//! binary (fine) node count, CDU-node statistics, level structure,
//! load-balance degree, and the peak-throughput model (equation 3).

use super::{Dag, Levels};
use crate::util::coefficient_of_variation;

/// The paper sets the CDU threshold at 20% of the architecture's maximum
/// parallelism (number of CUs): a node is *coarse-dataflow-unfriendly* when
/// its level holds fewer nodes than that threshold.
pub const CDU_THRESHOLD_FRACTION: f64 = 0.2;

/// Table III-style characterization of one benchmark DAG.
#[derive(Debug, Clone)]
pub struct DagStats {
    /// Matrix order.
    pub n: usize,
    /// Stored nonzeros (incl. diagonal).
    pub nnz: usize,
    /// Fine-node count of the equivalent binary DAG = FLOPs per solve.
    pub binary_nodes: usize,
    /// Number of levels (coarse critical path).
    pub num_levels: usize,
    /// Maximum level width.
    pub max_width: usize,
    /// Maximum in-degree (`d` in the compiler complexity bound).
    pub max_in_degree: usize,
    /// % of coarse nodes that are CDU (level width < threshold).
    pub cdu_nodes_pct: f64,
    /// % of edges whose destination is a CDU node.
    pub cdu_edges_pct: f64,
    /// % of levels that contain at least one CDU node (equivalently, whose
    /// width is below the threshold).
    pub cdu_levels_pct: f64,
    /// Average in-degree over CDU nodes (Table III "Edges per node").
    pub cdu_avg_edges_per_node: f64,
}

impl DagStats {
    /// Compute the statistics for `g` on an architecture with `num_cus`
    /// compute units (threshold = `CDU_THRESHOLD_FRACTION * num_cus`).
    pub fn compute(g: &Dag, lv: &Levels, num_cus: usize) -> Self {
        let nnz = g.num_edges() + g.n;
        let threshold = ((num_cus as f64) * CDU_THRESHOLD_FRACTION).ceil() as usize;
        let mut cdu_nodes = 0usize;
        let mut cdu_edges = 0usize;
        let mut cdu_levels = 0usize;
        for l in 0..lv.num_levels() {
            let w = lv.width(l);
            if w < threshold {
                cdu_levels += 1;
                for &i in lv.level(l) {
                    cdu_nodes += 1;
                    cdu_edges += g.in_degree(i as usize);
                }
            }
        }
        let pct = |num: usize, den: usize| {
            if den == 0 {
                0.0
            } else {
                100.0 * num as f64 / den as f64
            }
        };
        Self {
            n: g.n,
            nnz,
            binary_nodes: 2 * nnz - g.n,
            num_levels: lv.num_levels(),
            max_width: lv.max_width(),
            max_in_degree: g.max_in_degree(),
            cdu_nodes_pct: pct(cdu_nodes, g.n),
            cdu_edges_pct: pct(cdu_edges, g.num_edges()),
            cdu_levels_pct: pct(cdu_levels, lv.num_levels()),
            cdu_avg_edges_per_node: if cdu_nodes == 0 {
                0.0
            } else {
                cdu_edges as f64 / cdu_nodes as f64
            },
        }
    }
}

/// Load-balance degree (Table III column 10): coefficient of variation, in
/// percent, of the number of input edges assigned to each CU.
pub fn load_balance_degree(edges_per_cu: &[usize]) -> f64 {
    let xs: Vec<f64> = edges_per_cu.iter().map(|&e| e as f64).collect();
    coefficient_of_variation(&xs)
}

/// Peak throughput in GOPS (paper equation 3).
///
/// `p` = number of CUs, `clock_hz` = accelerator clock, `n`/`nnz` from the
/// matrix. Each CU retires 2 flops per cycle, but the `N` division-ish ops
/// are charged once per row: peak = (2·nnz − n) / (nnz/p · C).
pub fn peak_throughput_gops(n: usize, nnz: usize, p: usize, clock_hz: f64) -> f64 {
    let ops = (2 * nnz - n) as f64;
    let cycles = nnz as f64 / p as f64;
    let time = cycles / clock_hz;
    ops / time / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Dag, Levels};
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    fn stats_for(m: &CsrMatrix, cus: usize) -> DagStats {
        let g = Dag::from_csr(m);
        let lv = Levels::compute(&g);
        DagStats::compute(&g, &lv, cus)
    }

    #[test]
    fn chain_is_entirely_cdu() {
        let m = gen::chain(100, GenSeed(1));
        let s = stats_for(&m, 64);
        assert_eq!(s.cdu_nodes_pct, 100.0);
        assert_eq!(s.cdu_levels_pct, 100.0);
        assert_eq!(s.num_levels, 100);
    }

    #[test]
    fn wide_shallow_has_no_cdu() {
        let m = gen::shallow(5000, 0.2, GenSeed(2));
        let s = stats_for(&m, 64);
        // Huge levels => no CDU levels (c-36 row of Table III shows 0.0).
        assert!(s.cdu_nodes_pct < 5.0, "{}", s.cdu_nodes_pct);
    }

    #[test]
    fn percentages_in_range() {
        for seed in 0..5 {
            let m = gen::circuit(800, 5, 0.8, GenSeed(seed));
            let s = stats_for(&m, 64);
            for v in [s.cdu_nodes_pct, s.cdu_edges_pct, s.cdu_levels_pct] {
                assert!((0.0..=100.0).contains(&v));
            }
            assert_eq!(s.binary_nodes, 2 * s.nnz - s.n);
        }
    }

    #[test]
    fn peak_throughput_formula() {
        // 64 CUs @150 MHz: architecture peak = 2*64*150e6 = 19.2 GOPS;
        // eq. 3 scales it by (1 - n/(2 nnz)).
        let gops = peak_throughput_gops(2048, 31909, 64, 150e6);
        let arch_peak = 2.0 * 64.0 * 150e6 / 1e9;
        let expect = arch_peak * (1.0 - 2048.0 / (2.0 * 31909.0));
        assert!((gops - expect).abs() < 1e-9);
        // dw2048's Table III value is 18.6 GOPS.
        assert!((gops - 18.6).abs() < 0.1, "{gops}");
    }

    #[test]
    fn load_balance_zero_when_equal() {
        assert_eq!(load_balance_degree(&[10, 10, 10]), 0.0);
        assert!(load_balance_degree(&[1, 100]) > 50.0);
    }
}
