//! Level scheduling (paper Fig. 1(c)): partition nodes by their longest-path
//! depth from the sources. Nodes within a level are mutually independent.

use super::Dag;

/// Level decomposition of a DAG.
#[derive(Debug, Clone)]
pub struct Levels {
    /// Level index of each node (0 = source level).
    pub level_of: Vec<u32>,
    /// Nodes grouped by level: `nodes[level_ptr[l]..level_ptr[l+1]]`.
    pub level_ptr: Vec<usize>,
    /// Node ids ordered by (level, node id).
    pub nodes: Vec<u32>,
}

impl Levels {
    /// Compute levels by a forward sweep (node ids are topological for
    /// triangular matrices, so one pass suffices).
    pub fn compute(g: &Dag) -> Self {
        let mut level_of = vec![0u32; g.n];
        let mut max_level = 0u32;
        for i in 0..g.n {
            let mut lvl = 0u32;
            for &s in g.preds(i) {
                lvl = lvl.max(level_of[s as usize] + 1);
            }
            level_of[i] = lvl;
            max_level = max_level.max(lvl);
        }
        let nlv = (max_level + 1) as usize;
        let mut counts = vec![0usize; nlv];
        for &l in &level_of {
            counts[l as usize] += 1;
        }
        let mut level_ptr = vec![0usize; nlv + 1];
        for l in 0..nlv {
            level_ptr[l + 1] = level_ptr[l] + counts[l];
        }
        let mut nodes = vec![0u32; g.n];
        let mut cursor = level_ptr.clone();
        for i in 0..g.n {
            let l = level_of[i] as usize;
            nodes[cursor[l]] = i as u32;
            cursor[l] += 1;
        }
        Self {
            level_of,
            level_ptr,
            nodes,
        }
    }

    /// Number of levels (critical path length in coarse nodes).
    pub fn num_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }

    /// Nodes of level `l`, ascending ids.
    pub fn level(&self, l: usize) -> &[u32] {
        &self.nodes[self.level_ptr[l]..self.level_ptr[l + 1]]
    }

    /// Width (node count) of level `l`.
    pub fn width(&self, l: usize) -> usize {
        self.level_ptr[l + 1] - self.level_ptr[l]
    }

    /// Maximum level width (upper bound on coarse-dataflow parallelism).
    pub fn max_width(&self) -> usize {
        (0..self.num_levels()).map(|l| self.width(l)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Dag;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    #[test]
    fn fig1_levels() {
        let g = Dag::from_csr(&CsrMatrix::paper_fig1());
        let lv = Levels::compute(&g);
        // Sources: nodes 1, 2, 5 (0-based 0, 1, 4).
        assert_eq!(lv.level(0), &[0, 1, 4]);
        assert_eq!(lv.level_of[2], 1); // node 3 right after its sources
        assert!(lv.num_levels() >= 4);
    }

    #[test]
    fn chain_has_n_levels() {
        let m = gen::chain(50, GenSeed(1));
        let lv = Levels::compute(&Dag::from_csr(&m));
        assert_eq!(lv.num_levels(), 50);
        assert_eq!(lv.max_width(), 1);
    }

    #[test]
    fn level_partition_is_complete_and_disjoint() {
        let m = gen::circuit(500, 5, 0.8, GenSeed(2));
        let g = Dag::from_csr(&m);
        let lv = Levels::compute(&g);
        let mut seen = vec![false; g.n];
        for l in 0..lv.num_levels() {
            for &i in lv.level(l) {
                assert!(!seen[i as usize]);
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn levels_respect_dependencies() {
        let m = gen::factor_like(400, 6, 3, GenSeed(3));
        let g = Dag::from_csr(&m);
        let lv = Levels::compute(&g);
        for i in 0..g.n {
            for &s in g.preds(i) {
                assert!(lv.level_of[s as usize] < lv.level_of[i]);
            }
        }
    }

    #[test]
    fn level_is_longest_path() {
        let m = CsrMatrix::paper_fig1();
        let g = Dag::from_csr(&m);
        let lv = Levels::compute(&g);
        for i in 0..g.n {
            if g.in_degree(i) > 0 {
                let want = 1 + g
                    .preds(i)
                    .iter()
                    .map(|&s| lv.level_of[s as usize])
                    .max()
                    .unwrap();
                assert_eq!(lv.level_of[i], want);
            }
        }
    }
}
