//! Flattened adjacency structure of the SpTRSV DAG.

use crate::matrix::CsrMatrix;

/// The DAG of a lower-triangular matrix, with both directions flattened into
/// CSR-like arrays for cache-friendly traversal.
///
/// In-edges of node `i` are the off-diagonal nonzeros of row `i`; each edge
/// remembers the nonzero's index into `CsrMatrix::values` so schedulers can
/// refer to the exact `L_ij` operand it streams.
#[derive(Debug, Clone)]
pub struct Dag {
    /// Number of nodes (matrix order).
    pub n: usize,
    /// In-edge pointers, length `n + 1`.
    pub in_ptr: Vec<usize>,
    /// Source node of each in-edge, grouped by destination.
    pub in_src: Vec<u32>,
    /// Index into the matrix `values`/`colidx` arrays for each in-edge.
    pub in_nz: Vec<u32>,
    /// Out-edge pointers, length `n + 1`.
    pub out_ptr: Vec<usize>,
    /// Destination node of each out-edge, grouped by source, ascending.
    pub out_dst: Vec<u32>,
    /// Nonzero index of each out-edge (parallel to `out_dst`).
    pub out_nz: Vec<u32>,
}

impl Dag {
    /// Build the DAG from a validated CSR matrix.
    pub fn from_csr(m: &CsrMatrix) -> Self {
        let n = m.n;
        let mut in_ptr = vec![0usize; n + 1];
        for i in 0..n {
            in_ptr[i + 1] = in_ptr[i] + m.in_degree(i);
        }
        let ne = in_ptr[n];
        let mut in_src = vec![0u32; ne];
        let mut in_nz = vec![0u32; ne];
        let mut out_count = vec![0usize; n];
        {
            let mut k = 0usize;
            for i in 0..n {
                let (cols, _) = m.row_off_diag(i);
                for (off, &c) in cols.iter().enumerate() {
                    in_src[k] = c;
                    in_nz[k] = (m.rowptr[i] + off) as u32;
                    out_count[c as usize] += 1;
                    k += 1;
                }
            }
        }
        let mut out_ptr = vec![0usize; n + 1];
        for j in 0..n {
            out_ptr[j + 1] = out_ptr[j] + out_count[j];
        }
        let mut out_dst = vec![0u32; ne];
        let mut out_nz = vec![0u32; ne];
        let mut cursor = out_ptr.clone();
        for i in 0..n {
            let (cols, _) = m.row_off_diag(i);
            for (off, &c) in cols.iter().enumerate() {
                let p = cursor[c as usize];
                out_dst[p] = i as u32;
                out_nz[p] = (m.rowptr[i] + off) as u32;
                cursor[c as usize] += 1;
            }
        }
        Self {
            n,
            in_ptr,
            in_src,
            in_nz,
            out_ptr,
            out_dst,
            out_nz,
        }
    }

    /// Total number of edges (off-diagonal nonzeros).
    pub fn num_edges(&self) -> usize {
        self.in_src.len()
    }

    /// In-degree of node `i`.
    #[inline]
    pub fn in_degree(&self, i: usize) -> usize {
        self.in_ptr[i + 1] - self.in_ptr[i]
    }

    /// Out-degree of node `i`.
    #[inline]
    pub fn out_degree(&self, i: usize) -> usize {
        self.out_ptr[i + 1] - self.out_ptr[i]
    }

    /// Sources of node `i`'s in-edges.
    #[inline]
    pub fn preds(&self, i: usize) -> &[u32] {
        &self.in_src[self.in_ptr[i]..self.in_ptr[i + 1]]
    }

    /// Nonzero indices parallel to [`Dag::preds`].
    #[inline]
    pub fn pred_nz(&self, i: usize) -> &[u32] {
        &self.in_nz[self.in_ptr[i]..self.in_ptr[i + 1]]
    }

    /// Consumers of node `i`'s solution.
    #[inline]
    pub fn succs(&self, i: usize) -> &[u32] {
        &self.out_dst[self.out_ptr[i]..self.out_ptr[i + 1]]
    }

    /// Maximum in-degree (paper's `d`).
    pub fn max_in_degree(&self) -> usize {
        (0..self.n).map(|i| self.in_degree(i)).max().unwrap_or(0)
    }

    /// A topological order (node ids ascending already *is* one for a lower
    /// triangular matrix — every edge goes from a lower id to a higher id —
    /// but this method is kept for clarity and for reordered DAG variants).
    pub fn topo_order(&self) -> Vec<u32> {
        (0..self.n as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    #[test]
    fn fig1_adjacency() {
        let m = CsrMatrix::paper_fig1();
        let g = Dag::from_csr(&m);
        assert_eq!(g.n, 10);
        assert_eq!(g.num_edges(), m.off_diag_nnz());
        // Node 3 (0-based 2) depends on nodes 1,2 (0-based 0,1).
        assert_eq!(g.preds(2), &[0, 1]);
        // Node 1 (0-based 0) feeds nodes 3 and 4 (0-based 2,3).
        assert_eq!(g.succs(0), &[2, 3]);
        assert_eq!(g.in_degree(0), 0);
    }

    #[test]
    fn edges_point_forward() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(3));
        let g = Dag::from_csr(&m);
        for i in 0..g.n {
            for &s in g.preds(i) {
                assert!((s as usize) < i);
            }
            for &d in g.succs(i) {
                assert!((d as usize) > i);
            }
        }
    }

    #[test]
    fn degree_sums_match() {
        let m = gen::banded(300, 5, 0.5, GenSeed(4));
        let g = Dag::from_csr(&m);
        let total_in: usize = (0..g.n).map(|i| g.in_degree(i)).sum();
        let total_out: usize = (0..g.n).map(|i| g.out_degree(i)).sum();
        assert_eq!(total_in, total_out);
        assert_eq!(total_in, g.num_edges());
    }

    #[test]
    fn pred_nz_points_at_correct_values() {
        let m = gen::circuit(200, 4, 0.7, GenSeed(5));
        let g = Dag::from_csr(&m);
        for i in 0..g.n {
            for (&s, &nz) in g.preds(i).iter().zip(g.pred_nz(i)) {
                assert_eq!(m.colidx[nz as usize], s);
            }
        }
    }
}
