//! Medium-node splitting (paper §V.E, future work / extension).
//!
//! "A medium node is a node that performs the same basic operations as a
//! coarse node but has fewer input edges. Converting a coarse node into
//! multiple fine or medium nodes ... improves load balance."
//!
//! A row `i` with more than `threshold` off-diagonal entries is rewritten
//! as a cascade of medium rows: each intermediate row `t_m` accumulates a
//! chunk of `i`'s edges with a unit diagonal and zero RHS, producing
//! `t_m = −Σ_{j∈G_m} L_ij·x_j`; the original row keeps its last chunk and
//! gains `−1`-weighted edges from the intermediates, so its solution is
//! unchanged. This trades extra (intermediate) nodes for load balance —
//! exactly the trade-off the paper describes.

use crate::matrix::CsrMatrix;
use anyhow::{ensure, Result};

/// Result of splitting: the enlarged matrix plus the row mapping.
#[derive(Debug, Clone)]
pub struct SplitResult {
    /// The rewritten matrix (order ≥ original).
    pub matrix: CsrMatrix,
    /// For each new row: `Some(orig)` if it is an original row, `None` for
    /// intermediates.
    pub orig_of: Vec<Option<u32>>,
    /// For each original row: its index in the new matrix.
    pub new_of: Vec<u32>,
    /// Number of intermediate (medium) nodes created.
    pub intermediates: usize,
}

impl SplitResult {
    /// Expand a RHS for the split system (zeros at intermediates).
    pub fn expand_b(&self, b: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; self.orig_of.len()];
        for (new, orig) in self.orig_of.iter().enumerate() {
            if let Some(o) = orig {
                out[new] = b[*o as usize];
            }
        }
        out
    }

    /// Extract the original solution from the split system's solution.
    pub fn extract_x(&self, x_split: &[f32]) -> Vec<f32> {
        self.new_of
            .iter()
            .map(|&ni| x_split[ni as usize])
            .collect()
    }
}

/// Split every row with more than `threshold` off-diagonal entries.
/// `threshold` must be ≥ 2 (each medium node needs at least two inputs to
/// be worth existing).
pub fn split_heavy_nodes(m: &CsrMatrix, threshold: usize) -> Result<SplitResult> {
    ensure!(threshold >= 2, "split threshold must be ≥ 2");
    let n = m.n;
    // First pass: decide the new index of every original row, reserving
    // space for intermediates *before* their consumer row.
    let mut new_of = vec![0u32; n];
    let mut next = 0u32;
    let mut chunks_of = vec![0usize; n];
    for i in 0..n {
        let deg = m.in_degree(i);
        // ceil(deg/threshold) chunks; the last chunk stays in row i, the
        // rest become intermediates placed just before i.
        let chunks = if deg > threshold {
            deg.div_ceil(threshold)
        } else {
            1
        };
        chunks_of[i] = chunks;
        next += (chunks - 1) as u32;
        new_of[i] = next;
        next += 1;
    }
    let new_n = next as usize;
    let mut orig_of: Vec<Option<u32>> = vec![None; new_n];
    let mut triplets: Vec<(u32, u32, f32)> = Vec::with_capacity(m.nnz() * 2);
    let mut intermediates = 0usize;
    for i in 0..n {
        let (cols, vals) = m.row_off_diag(i);
        let ni = new_of[i];
        orig_of[ni as usize] = Some(i as u32);
        let chunks = chunks_of[i];
        if chunks == 1 {
            for (&c, &v) in cols.iter().zip(vals) {
                triplets.push((ni, new_of[c as usize], v));
            }
            triplets.push((ni, ni, m.diag(i)));
            continue;
        }
        // Intermediate rows take the first (chunks-1) chunks.
        let chunk_size = cols.len().div_ceil(chunks);
        let base = ni - (chunks as u32 - 1);
        let mut k = 0usize;
        for c_idx in 0..chunks - 1 {
            let t_row = base + c_idx as u32;
            intermediates += 1;
            for _ in 0..chunk_size {
                if k < cols.len() {
                    triplets.push((t_row, new_of[cols[k] as usize], vals[k]));
                    k += 1;
                }
            }
            triplets.push((t_row, t_row, 1.0)); // unit diagonal, b = 0
            triplets.push((ni, t_row, -1.0)); // consumer edge
        }
        // The final chunk stays in the original row.
        while k < cols.len() {
            triplets.push((ni, new_of[cols[k] as usize], vals[k]));
            k += 1;
        }
        triplets.push((ni, ni, m.diag(i)));
    }
    let matrix = CsrMatrix::from_triplets(new_n, &triplets)?;
    Ok(SplitResult {
        matrix,
        orig_of,
        new_of,
        intermediates,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::solve_serial;

    #[test]
    fn no_heavy_rows_is_identity_shaped() {
        let m = gen::banded(100, 3, 0.8, GenSeed(1));
        let s = split_heavy_nodes(&m, 16).unwrap();
        assert_eq!(s.matrix.n, m.n);
        assert_eq!(s.intermediates, 0);
    }

    #[test]
    fn split_preserves_solution() {
        let m = gen::power_law(300, 1.1, 120, GenSeed(2));
        let s = split_heavy_nodes(&m, 8).unwrap();
        assert!(s.intermediates > 0);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let x_ref = solve_serial(&m, &b);
        let xb = s.expand_b(&b);
        let x_split = solve_serial(&s.matrix, &xb);
        let x = s.extract_x(&x_split);
        for i in 0..m.n {
            assert!(
                (x[i] - x_ref[i]).abs() <= 2e-3 * x_ref[i].abs().max(1.0),
                "row {i}: {} vs {}",
                x[i],
                x_ref[i]
            );
        }
    }

    #[test]
    fn split_bounds_in_degree() {
        let m = gen::power_law(500, 1.2, 200, GenSeed(3));
        let th = 10;
        let s = split_heavy_nodes(&m, th).unwrap();
        for i in 0..s.matrix.n {
            // Intermediates may add consumer edges to the original rows, but
            // each row's raw chunk is ≤ threshold; consumer edges add at
            // most (chunks-1) ≈ deg/threshold more.
            let deg = s.matrix.in_degree(i);
            assert!(
                deg <= th + th, // chunk + consumer edges bound for our sizes
                "row {i} has degree {deg}"
            );
        }
    }

    #[test]
    fn rejects_tiny_threshold() {
        let m = gen::chain(10, GenSeed(4));
        assert!(split_heavy_nodes(&m, 1).is_err());
    }

    #[test]
    fn mapping_roundtrip() {
        let m = gen::power_law(200, 1.3, 64, GenSeed(5));
        let s = split_heavy_nodes(&m, 8).unwrap();
        for (orig, &new) in s.new_of.iter().enumerate() {
            assert_eq!(s.orig_of[new as usize], Some(orig as u32));
        }
    }
}
