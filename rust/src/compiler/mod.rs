//! The paper's custom compiler (§III.A / §IV).
//!
//! Pipeline (Fig. 4(a)):
//! 1. Build the DAG and allocate coarse nodes to CUs in topological order
//!    ([`allocation`]).
//! 2. Idealized medium-granularity scheduling pass — coarse node
//!    allocation, fine edge computation, partial-sum caching, ICR — with
//!    unlimited register-bank ports; collects bank constraints
//!    ([`dataflow`], [`icr`]).
//! 3. Greedy graph coloring assigns each value's home bank ([`coloring`]).
//! 4. Port-accurate scheduling pass; residual constraint violations appear
//!    as bank-conflict nops ([`dataflow`]).
//! 5. Emission: live-range releases, spill evictions, stream reordering and
//!    bit-accurate instruction words ([`program`], [`isa`]).

pub mod allocation;
pub mod coloring;
pub mod dataflow;
pub mod icr;
pub mod isa;
pub mod program;
pub mod split;

pub use allocation::AllocationPolicy;
pub use dataflow::{SchedConfig, SchedStats, Schedule};
pub use program::{CompileStats, Program};

use crate::arch::ArchConfig;
use crate::graph::Dag;
use crate::matrix::CsrMatrix;
use anyhow::Result;

/// Compiler options. Defaults reproduce the paper's configuration
/// (64 CUs, 8-word psum RF, ICR on, coloring on, forwarding on).
#[derive(Debug, Clone)]
pub struct CompilerConfig {
    /// Target architecture.
    pub arch: ArchConfig,
    /// Node → CU allocation policy.
    pub allocation: AllocationPolicy,
    /// Use the ICR algorithm (§IV.C); off = ascending source order.
    pub use_icr: bool,
    /// Run the greedy bank-coloring step; off = home bank is the owner CU.
    pub use_coloring: bool,
    /// Allow producer→consumer operand forwarding across the interconnect.
    pub forwarding: bool,
}

impl Default for CompilerConfig {
    fn default() -> Self {
        Self {
            arch: ArchConfig::default(),
            allocation: AllocationPolicy::RoundRobin,
            use_icr: true,
            use_coloring: true,
            forwarding: true,
        }
    }
}

/// Compile a sparse lower-triangular matrix into an accelerator program.
pub fn compile(m: &CsrMatrix, cfg: &CompilerConfig) -> Result<Program> {
    let t0 = std::time::Instant::now();
    let g = Dag::from_csr(m);
    let num_cus = cfg.arch.num_cus();
    let alloc = allocation::allocate(&g, num_cus, cfg.allocation);

    // Pass 1: idealized, collect constraints.
    let ideal_cfg = SchedConfig {
        psum_words: cfg.arch.psum_words,
        use_icr: cfg.use_icr,
        forwarding: cfg.forwarding,
        enforce_ports: false,
        collect_constraints: true,
    };
    let ideal = dataflow::schedule(&g, &alloc, &alloc.cu_of, &ideal_cfg)?;

    // Coloring.
    let (bank_of, violations) = if cfg.use_coloring {
        let ba = coloring::color(g.n, &ideal.constraints, &alloc.cu_of, num_cus);
        (ba.bank_of, ba.violations)
    } else {
        (alloc.cu_of.clone(), 0)
    };

    // Pass 2: port-accurate.
    let final_cfg = SchedConfig {
        enforce_ports: true,
        collect_constraints: false,
        ..ideal_cfg
    };
    let fin = dataflow::schedule(&g, &alloc, &bank_of, &final_cfg)?;

    let stats = CompileStats {
        constraints: ideal.stats.constraints,
        coloring_violations: violations,
        ideal_cycles: ideal.stats.cycles,
        edges_per_cu: alloc.edges_per_cu.clone(),
        load_balance_degree: 0.0, // filled by emit
        spills: 0,
        dm_redirected_reads: 0,
        compile_seconds: 0.0,
    };
    let mut prog = program::emit(m, &g, &fin, &alloc.cu_of, &bank_of, &cfg.arch, stats)?;
    prog.compile.compile_seconds = t0.elapsed().as_secs_f64();
    Ok(prog)
}

/// Run only the scheduling passes (no emission) — used by the dataflow
/// comparison figures where instruction streams are not needed.
pub fn schedule_only(m: &CsrMatrix, cfg: &CompilerConfig) -> Result<Schedule> {
    let g = Dag::from_csr(m);
    let num_cus = cfg.arch.num_cus();
    let alloc = allocation::allocate(&g, num_cus, cfg.allocation);
    let ideal_cfg = SchedConfig {
        psum_words: cfg.arch.psum_words,
        use_icr: cfg.use_icr,
        forwarding: cfg.forwarding,
        enforce_ports: false,
        collect_constraints: true,
    };
    let ideal = dataflow::schedule(&g, &alloc, &alloc.cu_of, &ideal_cfg)?;
    let bank_of = if cfg.use_coloring {
        coloring::color(g.n, &ideal.constraints, &alloc.cu_of, num_cus).bank_of
    } else {
        alloc.cu_of.clone()
    };
    let final_cfg = SchedConfig {
        enforce_ports: true,
        collect_constraints: false,
        ..ideal_cfg
    };
    dataflow::schedule(&g, &alloc, &bank_of, &final_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    #[test]
    fn compiles_fig1() {
        let m = CsrMatrix::paper_fig1();
        let p = compile(&m, &CompilerConfig::default()).unwrap();
        assert_eq!(p.n, 10);
        assert_eq!(p.predicted.finals as usize, 10);
        assert_eq!(p.stream_words(), m.nnz());
        assert!(p.predicted_gops() > 0.0);
    }

    #[test]
    fn compiles_suite_of_generators() {
        let cases: Vec<CsrMatrix> = vec![
            gen::chain(50, GenSeed(1)),
            gen::banded(300, 6, 0.5, GenSeed(2)),
            gen::circuit(500, 5, 0.8, GenSeed(3)),
            gen::grid2d(18, 18, true, GenSeed(4)),
            gen::shallow(800, 0.3, GenSeed(5)),
            gen::power_law(400, 1.2, 80, GenSeed(6)),
            gen::factor_like(300, 8, 4, GenSeed(7)),
        ];
        for m in &cases {
            let p = compile(m, &CompilerConfig::default()).unwrap();
            assert_eq!(
                p.predicted.macs as usize + p.predicted.finals as usize,
                m.nnz()
            );
            let total: usize = p.solve_order.iter().map(Vec::len).sum();
            assert_eq!(total, m.n);
        }
    }

    #[test]
    fn small_xi_rf_forces_spills() {
        let arch = ArchConfig {
            log2_cus: 2,
            log2_xi_words: 2, // 4 words per bank — tiny
            ..ArchConfig::default()
        };
        let m = gen::circuit(400, 6, 0.5, GenSeed(8));
        let cfg = CompilerConfig {
            arch,
            ..CompilerConfig::default()
        };
        let p = compile(&m, &cfg).unwrap();
        assert!(p.compile.spills > 0, "expected spill pressure");
        assert!(p.compile.dm_redirected_reads > 0);
    }

    #[test]
    fn coloring_reduces_conflicts() {
        let m = gen::circuit(800, 6, 0.8, GenSeed(9));
        let base = CompilerConfig {
            arch: ArchConfig {
                log2_cus: 4,
                ..ArchConfig::default()
            },
            ..CompilerConfig::default()
        };
        let with = compile(&m, &base).unwrap();
        let mut no_cfg = base.clone();
        no_cfg.use_coloring = false;
        let without = compile(&m, &no_cfg).unwrap();
        assert!(
            with.predicted.conflicts <= without.predicted.conflicts,
            "{} vs {}",
            with.predicted.conflicts,
            without.predicted.conflicts
        );
    }

    #[test]
    fn instruction_streams_are_uniform_length() {
        let m = gen::banded(200, 5, 0.6, GenSeed(10));
        let p = compile(&m, &CompilerConfig::default()).unwrap();
        let len = p.instrs[0].len();
        assert!(p.instrs.iter().all(|row| row.len() == len));
        assert_eq!(len as u64, p.predicted.cycles);
    }

    #[test]
    fn compile_time_scales_roughly_linearly() {
        // §V.G: O(nnz · d). Check super-linear blowup is absent:
        // 4× the edges should cost well under ~40× the time (slack for
        // timer noise on small inputs).
        let small = gen::banded(1000, 8, 0.5, GenSeed(11));
        let large = gen::banded(4000, 8, 0.5, GenSeed(11));
        let cfg = CompilerConfig::default();
        let t0 = std::time::Instant::now();
        compile(&small, &cfg).unwrap();
        let ts = t0.elapsed();
        let t1 = std::time::Instant::now();
        compile(&large, &cfg).unwrap();
        let tl = t1.elapsed();
        assert!(
            tl.as_secs_f64() < ts.as_secs_f64() * 40.0 + 0.5,
            "small={ts:?} large={tl:?}"
        );
    }
}
