//! The medium granularity dataflow scheduler (paper §IV.A/§IV.B).
//!
//! A coarse node is the minimal *load allocating* unit (pinned to one CU by
//! [`crate::compiler::allocation`]) while an edge is the minimal *task
//! scheduling* unit: a CU computes any ready edge of its chosen node each
//! cycle, parking partial sums in the psum register file when the node
//! blocks (§IV.B), and choosing which ready edge to compute via ICR
//! (§IV.C / Algorithm 2) or ascending source order.
//!
//! The scheduler is cycle-exact: because the VLIW contract makes the
//! hardware fully predictable, this loop *is* the paper's compiler
//! "determining the behavior of PEs or CUs in each cycle". It runs in two
//! modes:
//!
//! - **idealized** (`enforce_ports = false`): unlimited register-bank ports;
//!   collects the bank *constraints* consumed by the graph-coloring step
//!   (pairs of values that must not share a bank), Fig. 9(d).
//! - **port-accurate** (`enforce_ports = true`, given a bank assignment):
//!   one read + one write port per bank per cycle; denied CUs take `Bnop`
//!   cycles, counted as bank conflicts, Fig. 9(e).

use crate::compiler::allocation::Allocation;
use crate::compiler::icr::{self, CuCandidates};
use crate::compiler::isa::NopKind;
use crate::graph::Dag;
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// psum-path control for one scheduled op (paper §IV.B's five cases).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PsumCtl {
    /// Start of a fresh node (previous solved or none): psum input = 0.
    Zero,
    /// Continue the same node: psum from the feedback DFF.
    Feedback,
    /// Resume a parked node, previous node solved: read psum RF, release.
    ReadRf,
    /// Previous node unfinished, switch to a fresh node: park previous
    /// (write psum RF), psum input = 0. Capacity-checked.
    ParkThenZero,
    /// Previous node unfinished, resume a parked node: park previous and
    /// read the parked sum (read-before-write; no capacity check).
    ParkThenRead,
}

/// One abstract scheduled operation for one CU in one cycle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedOp {
    /// Multiply-accumulate of one edge (`ct = 1`).
    Mac {
        /// Destination node (the row being accumulated).
        node: u32,
        /// Source node (the consumed `x_j`).
        src: u32,
        /// Index of the `L_ij` nonzero in the matrix arrays.
        nz: u32,
        /// Operand arrives by forwarding (source solved last cycle).
        fwd: bool,
        /// psum-path control.
        psum: PsumCtl,
    },
    /// Final self-update `(b_i − psum) · L_ii⁻¹` (`ct = 0`).
    Final {
        /// The node being solved.
        node: u32,
        /// psum-path control (`Zero`, `Feedback`, or `ParkThenZero`).
        psum: PsumCtl,
    },
    /// Blocked cycle.
    Nop(NopKind),
}

/// Aggregate statistics of one schedule (feeds Figs. 9/10 and Table IV).
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Total cycles until every node is solved.
    pub cycles: u64,
    /// Executed op slots (MACs + finals).
    pub exec: u64,
    /// Bank-conflict nops.
    pub bnop: u64,
    /// psum-capacity nops.
    pub pnop: u64,
    /// Dependency nops (tasks remain, all blocked).
    pub dnop: u64,
    /// Load-imbalance nops (CU finished, others have not).
    pub lnop: u64,
    /// MAC ops (== number of edges).
    pub macs: u64,
    /// Final ops (== number of nodes).
    pub finals: u64,
    /// Operand consumptions served by producer forwarding.
    pub forwards: u64,
    /// Bank readouts saved by same-cycle same-source broadcast.
    pub broadcast_saved: u64,
    /// Distinct register-bank readouts performed.
    pub bank_reads: u64,
    /// Partial sums parked into the psum RF.
    pub psum_parks: u64,
    /// Partial sums resumed from the psum RF.
    pub psum_resumes: u64,
    /// Number of coloring constraints collected (idealized pass only).
    pub constraints: u64,
    /// Bank-conflict events (port-accurate pass only): denied CU-cycles.
    pub conflicts: u64,
}

impl SchedStats {
    /// PE utilization = executed slots / (cycles × CUs).
    pub fn utilization(&self, num_cus: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.exec as f64 / (self.cycles as f64 * num_cus as f64)
    }

    /// Data-reuse fraction: operand consumptions that did not need a
    /// dedicated bank readout (forwards + broadcast shares) over all
    /// consumptions (Fig. 9(f) metric).
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.macs;
        if total == 0 {
            return 0.0;
        }
        (self.forwards + self.broadcast_saved) as f64 / total as f64
    }
}

/// Scheduler knobs (subset of [`crate::compiler::CompilerConfig`]).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// psum register file capacity per CU (0 = caching disabled).
    pub psum_words: u32,
    /// Use ICR (Algorithm 2); otherwise ascending source order.
    pub use_icr: bool,
    /// Allow operand forwarding from a producer that solved last cycle.
    pub forwarding: bool,
    /// Enforce one read + one write port per bank per cycle using
    /// `bank_of`; `None` = idealized pass that collects constraints.
    pub enforce_ports: bool,
    /// Collect coloring constraints (meaningful in the idealized pass).
    pub collect_constraints: bool,
}

/// A complete cycle-exact schedule.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// `ops[cu][cycle]`; all rows have length `stats.cycles`.
    pub ops: Vec<Vec<SchedOp>>,
    /// Solve cycle of each node.
    pub solved_at: Vec<u32>,
    /// Statistics.
    pub stats: SchedStats,
    /// Deduplicated bank-assignment constraints (pairs of node ids that were
    /// accessed in the same cycle), when collected.
    pub constraints: Vec<(u32, u32)>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeState {
    Unstarted,
    Current,
    Parked,
    Done,
}

struct CuState {
    tasks: Vec<u32>,
    /// Index into `tasks` of the first node still `Unstarted`.
    first_unstarted: usize,
    /// Node whose partial sum sits in the feedback register (if unfinished)
    /// or that produced last cycle's output.
    cur: Option<u32>,
    /// Nodes parked in the psum RF, in park order.
    parked: Vec<u32>,
    /// Unstarted nodes that have become computable (ready edge or no MACs).
    /// Ascending node id == task-list order (task lists are topological).
    ready_unstarted: BTreeSet<u32>,
    done_count: usize,
    /// Caching disabled (psum_words == 0): starts are in-order only.
    psum_disabled: bool,
}

/// Cap on candidate edges a CU offers to ICR per cycle. A CU computes one
/// edge per cycle, so a bounded window only affects grouping quality, not
/// correctness; unbounded windows made hub rows (hundreds of ready edges)
/// quadratic in practice (§Perf in EXPERIMENTS.md: 10×+ compile speedup).
const CAND_WINDOW: usize = 24;

/// Bounded copy of a ready-edge list for the per-cycle candidate set.
fn window(edges: &[(u32, u32)]) -> Vec<(u32, u32)> {
    edges[..edges.len().min(CAND_WINDOW)].to_vec()
}

/// What a CU intends to do this cycle, before port arbitration.
enum Intent {
    /// Compute one of `cand` edges of `node`.
    Edges {
        node: u32,
        psum: PsumCtl,
        cand: Vec<(u32, u32)>,
    },
    /// Execute the final op of `node`.
    Final { node: u32, psum: PsumCtl },
    Blocked(NopKind),
}

/// Run the scheduler. `bank_of[i]` gives the home register bank of node
/// `i`'s solution (used when `cfg.enforce_ports`).
pub fn schedule(
    g: &Dag,
    alloc: &Allocation,
    bank_of: &[u32],
    cfg: &SchedConfig,
) -> Result<Schedule> {
    let num_cus = alloc.tasks.len();
    let n = g.n;
    let mut state = vec![NodeState::Unstarted; n];
    let mut macs_left: Vec<u32> = (0..n).map(|i| g.in_degree(i) as u32).collect();
    let mut ready_edges: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
    let mut solved_at = vec![u32::MAX; n];
    let mut cus: Vec<CuState> = alloc
        .tasks
        .iter()
        .map(|tasks| CuState {
            tasks: tasks.clone(),
            first_unstarted: 0,
            cur: None,
            parked: Vec::new(),
            ready_unstarted: BTreeSet::new(),
            done_count: 0,
            psum_disabled: cfg.psum_words == 0,
        })
        .collect();
    // Zero-in-degree nodes are computable from cycle 0.
    for i in 0..n {
        if g.in_degree(i) == 0 {
            cus[alloc.cu_of[i] as usize].ready_unstarted.insert(i as u32);
        }
    }
    let mut ops: Vec<Vec<SchedOp>> = vec![Vec::new(); num_cus];
    let mut stats = SchedStats::default();
    let mut constraint_set: crate::util::fasthash::IntSet<u64> = Default::default();
    let mut done_nodes = 0usize;
    let mut cycle: u32 = 0;
    // Reusable per-cycle buffers.
    let mut intents: Vec<Intent> = Vec::with_capacity(num_cus);

    while done_nodes < n {
        if cycle as u64 > 4 * (g.num_edges() as u64 + n as u64) + 16 {
            bail!("scheduler did not converge (cycle budget exceeded) — deadlock?");
        }
        intents.clear();
        // ---- Phase 1: per-CU node choice (psum rules, §IV.B) ----
        for cu in cus.iter_mut() {
            intents.push(decide(cu, &state, &macs_left, &ready_edges, cfg));
        }
        // ---- Phase 2: port arbitration ----
        // Write ports for finals (CU index order), then read ports for MACs
        // (ICR or ascending). A bank supports 1R + 1W per cycle.
        let mut write_claims: crate::util::fasthash::IntSet<u32> = Default::default();
        let read_claims: std::cell::RefCell<crate::util::fasthash::IntSet<u32>> = Default::default();
        let src_selected: std::cell::RefCell<crate::util::fasthash::IntSet<u32>> = Default::default();
        let mut committed: Vec<SchedOp> = Vec::with_capacity(num_cus);
        let mut cand_sets: Vec<CuCandidates> = Vec::new();
        let mut cand_psum: Vec<(u32, u32, PsumCtl)> = Vec::new(); // (cu, node, psum)
        for (cu_idx, intent) in intents.iter().enumerate() {
            match intent {
                Intent::Blocked(kind) => committed.push(SchedOp::Nop(*kind)),
                Intent::Final { node, psum } => {
                    let needs_write = g.out_degree(*node as usize) > 0;
                    let bank = bank_of[*node as usize];
                    if cfg.enforce_ports && needs_write && write_claims.contains(&bank) {
                        committed.push(SchedOp::Nop(NopKind::Bnop));
                        stats.conflicts += 1;
                    } else {
                        if needs_write {
                            write_claims.insert(bank);
                        }
                        committed.push(SchedOp::Final {
                            node: *node,
                            psum: *psum,
                        });
                    }
                }
                Intent::Edges { node, psum, cand } => {
                    committed.push(SchedOp::Nop(NopKind::Bnop)); // placeholder
                    cand_sets.push((cu_idx as u32, cand.clone()));
                    cand_psum.push((cu_idx as u32, *node, *psum));
                }
            }
        }
        // Edge selection across CUs.
        let fwd_ok = |src: u32| cfg.forwarding && solved_at[src as usize] == cycle.wrapping_sub(1);
        let selection = {
            let available = |src: u32| {
                if !cfg.enforce_ports || fwd_ok(src) || src_selected.borrow().contains(&src) {
                    true
                } else {
                    !read_claims.borrow().contains(&bank_of[src as usize])
                }
            };
            let claim = |src: u32| {
                src_selected.borrow_mut().insert(src);
                if !fwd_ok(src) {
                    read_claims.borrow_mut().insert(bank_of[src as usize]);
                }
            };
            if cfg.use_icr {
                icr::icr_select(&cand_sets, available, claim)
            } else {
                icr::ascending_select(&cand_sets, available, claim)
            }
        };
        for &(cu, src, nz) in &selection.chosen {
            let (_, node, psum) = cand_psum.iter().find(|&&(c, _, _)| c == cu).unwrap();
            committed[cu as usize] = SchedOp::Mac {
                node: *node,
                src,
                nz,
                fwd: fwd_ok(src),
                psum: *psum,
            };
        }
        for &cu in &selection.blocked {
            stats.conflicts += 1;
            debug_assert!(matches!(committed[cu as usize], SchedOp::Nop(_)));
        }
        // ---- Phase 3: commit state updates ----
        let mut solved_this_cycle: Vec<u32> = Vec::new();
        let mut bank_read_srcs: Vec<u32> = Vec::new();
        let mut exec_any = false;
        for (cu_idx, op) in committed.iter().enumerate() {
            let cu = &mut cus[cu_idx];
            match *op {
                SchedOp::Nop(kind) => {
                    match kind {
                        NopKind::Bnop => stats.bnop += 1,
                        NopKind::Pnop => stats.pnop += 1,
                        NopKind::Dnop => stats.dnop += 1,
                        NopKind::Lnop => stats.lnop += 1,
                    }
                    ops[cu_idx].push(*op);
                }
                SchedOp::Mac {
                    node,
                    src,
                    nz,
                    fwd,
                    psum,
                } => {
                    exec_any = true;
                    stats.exec += 1;
                    stats.macs += 1;
                    if fwd {
                        stats.forwards += 1;
                    } else {
                        bank_read_srcs.push(src);
                    }
                    apply_psum_transition(cu, &mut state, node, psum, &mut stats);
                    // Consume the edge.
                    let list = &mut ready_edges[node as usize];
                    let pos = list
                        .iter()
                        .position(|&(s, z)| s == src && z == nz)
                        .expect("selected edge must be ready");
                    list.swap_remove(pos);
                    macs_left[node as usize] -= 1;
                    ops[cu_idx].push(*op);
                }
                SchedOp::Final { node, psum } => {
                    exec_any = true;
                    stats.exec += 1;
                    stats.finals += 1;
                    apply_psum_transition(cu, &mut state, node, psum, &mut stats);
                    state[node as usize] = NodeState::Done;
                    solved_at[node as usize] = cycle;
                    cu.cur = None;
                    cu.done_count += 1;
                    done_nodes += 1;
                    solved_this_cycle.push(node);
                    ops[cu_idx].push(*op);
                }
            }
        }
        // Reuse accounting: distinct bank reads vs total non-forwarded reads.
        if !bank_read_srcs.is_empty() {
            bank_read_srcs.sort_unstable();
            let mut distinct = 0u64;
            let mut prev = u32::MAX;
            for &s in &bank_read_srcs {
                if s != prev {
                    distinct += 1;
                    prev = s;
                }
            }
            stats.bank_reads += distinct;
            stats.broadcast_saved += bank_read_srcs.len() as u64 - distinct;
            // Constraint collection: distinct co-read sources must land in
            // different banks.
            if cfg.collect_constraints {
                bank_read_srcs.dedup();
                for a in 0..bank_read_srcs.len() {
                    for b in a + 1..bank_read_srcs.len() {
                        let key =
                            (bank_read_srcs[a] as u64) << 32 | bank_read_srcs[b] as u64;
                        constraint_set.insert(key);
                    }
                }
            }
        }
        if cfg.collect_constraints && solved_this_cycle.len() > 1 {
            let writers: Vec<u32> = solved_this_cycle
                .iter()
                .copied()
                .filter(|&v| g.out_degree(v as usize) > 0)
                .collect();
            for a in 0..writers.len() {
                for b in a + 1..writers.len() {
                    let (x, y) = if writers[a] < writers[b] {
                        (writers[a], writers[b])
                    } else {
                        (writers[b], writers[a])
                    };
                    constraint_set.insert((x as u64) << 32 | y as u64);
                }
            }
        }
        // ---- Phase 4: readiness propagation (visible next cycle) ----
        for &j in &solved_this_cycle {
            let (lo, hi) = (g.out_ptr[j as usize], g.out_ptr[j as usize + 1]);
            for k in lo..hi {
                let dst = g.out_dst[k];
                let nz = g.out_nz[k];
                ready_edges[dst as usize].push((j, nz));
                if state[dst as usize] == NodeState::Unstarted {
                    cus[alloc.cu_of[dst as usize] as usize]
                        .ready_unstarted
                        .insert(dst);
                }
            }
        }
        if !exec_any && solved_this_cycle.is_empty() {
            let mut diag = String::new();
            for (ci, cu) in cus.iter().enumerate().take(32) {
                if cu.done_count == cu.tasks.len() {
                    continue;
                }
                diag.push_str(&format!(
                    "\n  cu{ci}: cur={:?} parked={:?} ready_unstarted={:?} done={}/{} free={}",
                    cu.cur,
                    cu.parked,
                    cu.ready_unstarted.iter().take(4).collect::<Vec<_>>(),
                    cu.done_count,
                    cu.tasks.len(),
                    cfg.psum_words as usize - cu.parked.len(),
                ));
            }
            if let Some(v) = (0..n).find(|&i| state[i] != NodeState::Done) {
                let unsolved_preds: Vec<u32> = g
                    .preds(v)
                    .iter()
                    .copied()
                    .filter(|&p| state[p as usize] != NodeState::Done)
                    .collect();
                diag.push_str(&format!(
                    "\n  min unsolved: node {v} state={:?} macs_left={} ready_edges={:?} unsolved_preds={:?} cu={}",
                    state[v],
                    macs_left[v],
                    ready_edges[v],
                    unsolved_preds,
                    alloc.cu_of[v],
                ));
            }
            bail!("scheduler deadlock at cycle {cycle}: no CU made progress{diag}");
        }
        cycle += 1;
    }
    stats.cycles = cycle as u64;
    stats.constraints = constraint_set.len() as u64;
    let mut constraints: Vec<(u32, u32)> = constraint_set
        .into_iter()
        .map(|k| ((k >> 32) as u32, k as u32))
        .collect();
    constraints.sort_unstable();
    Ok(Schedule {
        ops,
        solved_at,
        stats,
        constraints,
    })
}

/// Apply the psum RF bookkeeping of a committed op to the CU state.
fn apply_psum_transition(
    cu: &mut CuState,
    state: &mut [NodeState],
    node: u32,
    psum: PsumCtl,
    stats: &mut SchedStats,
) {
    match psum {
        PsumCtl::Feedback => {
            debug_assert_eq!(cu.cur, Some(node));
        }
        PsumCtl::Zero => {
            debug_assert!(cu.cur.is_none() || state[cu.cur.unwrap() as usize] == NodeState::Done);
            start_node(cu, state, node);
        }
        PsumCtl::ReadRf => {
            stats.psum_resumes += 1;
            unpark(cu, node);
            state[node as usize] = NodeState::Current;
            cu.cur = Some(node);
        }
        PsumCtl::ParkThenZero => {
            let prev = cu.cur.expect("park requires a current node");
            stats.psum_parks += 1;
            cu.parked.push(prev);
            state[prev as usize] = NodeState::Parked;
            start_node(cu, state, node);
        }
        PsumCtl::ParkThenRead => {
            let prev = cu.cur.expect("park requires a current node");
            stats.psum_parks += 1;
            stats.psum_resumes += 1;
            unpark(cu, node);
            cu.parked.push(prev);
            state[prev as usize] = NodeState::Parked;
            state[node as usize] = NodeState::Current;
            cu.cur = Some(node);
        }
    }
}

fn start_node(cu: &mut CuState, state: &mut [NodeState], node: u32) {
    debug_assert_eq!(state[node as usize], NodeState::Unstarted);
    state[node as usize] = NodeState::Current;
    cu.cur = Some(node);
    cu.ready_unstarted.remove(&node);
    // Advance the first-unstarted pointer past started nodes.
    while cu.first_unstarted < cu.tasks.len()
        && state[cu.tasks[cu.first_unstarted] as usize] != NodeState::Unstarted
    {
        cu.first_unstarted += 1;
    }
}

fn unpark(cu: &mut CuState, node: u32) {
    let pos = cu
        .parked
        .iter()
        .position(|&p| p == node)
        .expect("resumed node must be parked");
    cu.parked.remove(pos);
}

/// Node-choice per the partial-sum caching rules (§IV.B).
fn decide(
    cu: &mut CuState,
    state: &[NodeState],
    macs_left: &[u32],
    ready_edges: &[Vec<(u32, u32)>],
    cfg: &SchedConfig,
) -> Intent {
    if cu.done_count == cu.tasks.len() {
        return Intent::Blocked(NopKind::Lnop);
    }
    let cur_unfinished = cu
        .cur
        .filter(|&c| state[c as usize] == NodeState::Current);
    // Rule 0 (deadlock avoidance): a ready parked node preempts everything.
    // "Ready" includes a parked node whose MACs are all done and only the
    // final self-update remains (it can be preempted right before its
    // final op).
    if let Some(&p) = cu
        .parked
        .iter()
        .find(|&&p| !ready_edges[p as usize].is_empty() || macs_left[p as usize] == 0)
    {
        let psum = if cur_unfinished.is_some() {
            PsumCtl::ParkThenRead
        } else {
            PsumCtl::ReadRf
        };
        if macs_left[p as usize] == 0 {
            return Intent::Final { node: p, psum };
        }
        return Intent::Edges {
            node: p,
            psum,
            cand: window(&ready_edges[p as usize]),
        };
    }
    // Rule 1: continue the current node if it can make progress.
    if let Some(c) = cur_unfinished {
        if !ready_edges[c as usize].is_empty() {
            return Intent::Edges {
                node: c,
                psum: PsumCtl::Feedback,
                cand: window(&ready_edges[c as usize]),
            };
        }
        if macs_left[c as usize] == 0 {
            return Intent::Final {
                node: c,
                psum: PsumCtl::Feedback,
            };
        }
        // Current node blocked: try switching to a fresh ready node.
        //
        // Capacity rule (liveness-strengthened — see DESIGN.md §7): parking
        // requires two free psum addresses, or one when the candidate is
        // *fully ready* (all of its remaining MACs are computable, so it
        // runs to completion and never parks). The paper's "first new node
        // in the task list" exception is insufficient to guarantee
        // progress in our reading (a CU can strand its own task list with a
        // full psum RF); the fully-ready condition provably cannot
        // deadlock: the globally-minimum unsolved node is always fully
        // ready and always admissible.
        let free = cfg.psum_words as usize - cu.parked.len();
        if let Some(u) = pick_startable(cu, macs_left, ready_edges, free.saturating_sub(1)) {
            if free >= 1 {
                return if macs_left[u as usize] == 0 {
                    Intent::Final {
                        node: u,
                        psum: PsumCtl::ParkThenZero,
                    }
                } else {
                    Intent::Edges {
                        node: u,
                        psum: PsumCtl::ParkThenZero,
                        cand: window(&ready_edges[u as usize]),
                    }
                };
            }
            return Intent::Blocked(NopKind::Pnop);
        }
        return Intent::Blocked(if cu.ready_unstarted.is_empty() {
            NopKind::Dnop
        } else {
            NopKind::Pnop
        });
    }
    // Rule 2: no current node — start the first admissible unstarted node
    // (no parking needed; with an exhausted psum RF only fully-ready nodes
    // may start, preserving the liveness invariant).
    let free = cfg.psum_words as usize - cu.parked.len();
    if let Some(u) = pick_startable(cu, macs_left, ready_edges, free) {
        return if macs_left[u as usize] == 0 {
            Intent::Final {
                node: u,
                psum: PsumCtl::Zero,
            }
        } else {
            Intent::Edges {
                node: u,
                psum: PsumCtl::Zero,
                cand: window(&ready_edges[u as usize]),
            }
        };
    }
    Intent::Blocked(if cu.ready_unstarted.is_empty() {
        NopKind::Dnop
    } else {
        NopKind::Pnop
    })
}

/// First admissible ready-unstarted node.
///
/// Liveness regimes (DESIGN.md §7):
/// - **Caching disabled** (`psum_words == 0`): starts are strictly
///   *in task-list order* (a CU never skips ahead). The globally-minimum
///   unsolved node is then always its CU's next task and always runnable,
///   so the schedule cannot deadlock even though blocked nodes cannot be
///   parked.
/// - **Caching enabled**: out-of-order starts are allowed. With `budget`
///   (free psum slots that would remain) ≥ 1, any ready node may start;
///   at 0 only *fully ready* nodes (all remaining MACs computable — such a
///   node runs to completion and never parks) are admissible.
fn pick_startable(
    cu: &CuState,
    macs_left: &[u32],
    ready_edges: &[Vec<(u32, u32)>],
    budget: usize,
) -> Option<u32> {
    if cu.psum_disabled {
        // In-order starts only.
        let next = *cu.tasks.get(cu.first_unstarted)?;
        return cu.ready_unstarted.contains(&next).then_some(next);
    }
    let fully_ready =
        |u: u32| ready_edges[u as usize].len() as u32 == macs_left[u as usize];
    if budget >= 1 {
        cu.ready_unstarted.iter().next().copied()
    } else {
        cu.ready_unstarted.iter().copied().find(|&u| fully_ready(u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::allocation::{allocate, AllocationPolicy};
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    fn cfg(psum: u32) -> SchedConfig {
        SchedConfig {
            psum_words: psum,
            use_icr: true,
            forwarding: true,
            enforce_ports: false,
            collect_constraints: true,
        }
    }

    fn run(m: &CsrMatrix, num_cus: usize, c: &SchedConfig) -> Schedule {
        let g = Dag::from_csr(m);
        let alloc = allocate(&g, num_cus, AllocationPolicy::RoundRobin);
        let bank_of = alloc.cu_of.clone();
        schedule(&g, &alloc, &bank_of, c).unwrap()
    }

    /// Every edge scheduled after its source solves; every node solved after
    /// all its MACs; op counts match the matrix.
    fn check_legal(m: &CsrMatrix, s: &Schedule) {
        let g = Dag::from_csr(m);
        assert_eq!(s.stats.macs as usize, g.num_edges());
        assert_eq!(s.stats.finals as usize, g.n);
        for i in 0..g.n {
            assert_ne!(s.solved_at[i], u32::MAX, "node {i} unsolved");
        }
        let mut mac_cycle: Vec<Vec<u32>> = vec![Vec::new(); g.n];
        for (_, row) in s.ops.iter().enumerate() {
            for (t, op) in row.iter().enumerate() {
                if let SchedOp::Mac { node, src, fwd, .. } = op {
                    assert!(
                        s.solved_at[*src as usize] < t as u32,
                        "edge consumed before source solved"
                    );
                    if *fwd {
                        assert_eq!(s.solved_at[*src as usize], t as u32 - 1);
                    }
                    mac_cycle[*node as usize].push(t as u32);
                }
            }
        }
        for i in 0..g.n {
            assert_eq!(mac_cycle[i].len(), g.in_degree(i));
            for &t in &mac_cycle[i] {
                assert!(t < s.solved_at[i], "MAC after solve of node {i}");
            }
        }
    }

    #[test]
    fn fig1_schedules_legally() {
        let m = CsrMatrix::paper_fig1();
        let s = run(&m, 4, &cfg(4));
        check_legal(&m, &s);
        assert!(s.stats.cycles >= 5); // critical path of the fig1 DAG
    }

    #[test]
    fn chain_takes_two_cycles_per_node() {
        // A bidiagonal chain is fully sequential: each node needs its MAC
        // (ready the cycle after the pred solves) and a final. First node:
        // 1 cycle (final only). So cycles = 1 + 2(n-1).
        let m = gen::chain(20, GenSeed(1));
        let s = run(&m, 4, &cfg(4));
        check_legal(&m, &s);
        assert_eq!(s.stats.cycles, 1 + 2 * 19);
    }

    #[test]
    fn single_cu_serializes_everything() {
        let m = gen::banded(60, 3, 0.7, GenSeed(2));
        let s = run(&m, 1, &cfg(8));
        check_legal(&m, &s);
        // One op per cycle at best; blocking can only add.
        assert!(s.stats.cycles >= m.nnz() as u64);
    }

    #[test]
    fn more_cus_never_slower() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(3));
        let s1 = run(&m, 8, &cfg(8));
        let s2 = run(&m, 64, &cfg(8));
        check_legal(&m, &s1);
        check_legal(&m, &s2);
        assert!(s2.stats.cycles <= s1.stats.cycles * 2); // soft sanity
    }

    #[test]
    fn psum_capacity_zero_still_correct() {
        let m = gen::circuit(300, 5, 0.8, GenSeed(4));
        let s = run(&m, 16, &cfg(0));
        check_legal(&m, &s);
        assert_eq!(s.stats.psum_parks, 0);
    }

    #[test]
    fn psum_caching_reduces_blocking() {
        let m = gen::circuit(600, 6, 0.8, GenSeed(5));
        let without = run(&m, 64, &cfg(0));
        let with = run(&m, 64, &cfg(8));
        check_legal(&m, &with);
        // Fig. 9(b)/(c): caching reduces blocking cycles and total cycles.
        let blocked_wo = without.stats.pnop + without.stats.dnop;
        let blocked_w = with.stats.pnop + with.stats.dnop;
        assert!(blocked_w <= blocked_wo, "{blocked_w} vs {blocked_wo}");
        assert!(with.stats.cycles <= without.stats.cycles);
    }

    #[test]
    fn parked_never_exceeds_capacity() {
        // Indirectly verified by psum_parks bookkeeping asserts; run a
        // stress config with tiny psum RF.
        let m = gen::power_law(500, 1.2, 60, GenSeed(6));
        for words in [1, 2, 4] {
            let s = run(&m, 8, &cfg(words));
            check_legal(&m, &s);
        }
    }

    #[test]
    fn icr_improves_reuse() {
        let m = gen::grid2d(20, 20, true, GenSeed(7));
        let mut with = cfg(8);
        with.use_icr = true;
        let mut without = cfg(8);
        without.use_icr = false;
        let a = run(&m, 16, &with);
        let b = run(&m, 16, &without);
        check_legal(&m, &a);
        check_legal(&m, &b);
        assert!(
            a.stats.reuse_fraction() >= b.stats.reuse_fraction(),
            "{} vs {}",
            a.stats.reuse_fraction(),
            b.stats.reuse_fraction()
        );
    }

    #[test]
    fn icr_reduces_constraints() {
        let m = gen::circuit(500, 6, 0.8, GenSeed(8));
        let mut with = cfg(8);
        with.use_icr = true;
        let mut without = cfg(8);
        without.use_icr = false;
        let a = run(&m, 32, &with);
        let b = run(&m, 32, &without);
        assert!(
            a.stats.constraints <= b.stats.constraints,
            "{} vs {}",
            a.stats.constraints,
            b.stats.constraints
        );
    }

    #[test]
    fn port_enforcement_adds_only_bnops() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(9));
        let mut ideal = cfg(8);
        ideal.collect_constraints = false;
        let mut ports = ideal.clone();
        ports.enforce_ports = true;
        let a = run(&m, 16, &ideal);
        let b = run(&m, 16, &ports);
        check_legal(&m, &b);
        assert!(b.stats.cycles >= a.stats.cycles);
        assert_eq!(a.stats.macs, b.stats.macs);
    }

    #[test]
    fn nop_accounting_sums_to_cycles() {
        let m = gen::factor_like(300, 6, 3, GenSeed(10));
        let s = run(&m, 16, &cfg(8));
        let total = s.stats.exec + s.stats.bnop + s.stats.pnop + s.stats.dnop + s.stats.lnop;
        assert_eq!(total, s.stats.cycles * 16);
        for row in &s.ops {
            assert_eq!(row.len() as u64, s.stats.cycles);
        }
    }

    #[test]
    fn utilization_bounded() {
        let m = gen::grid2d(30, 30, false, GenSeed(11));
        let s = run(&m, 64, &cfg(8));
        let u = s.stats.utilization(64);
        assert!(u > 0.0 && u <= 1.0);
    }
}
