//! Intra-node edges computation reordering — ICR (paper §IV.C, Algorithm 2).
//!
//! In each cycle every active CU has a set of *computable* edges for its
//! chosen node. Which edge each CU computes does not change the result, but
//! edges with the same source node scheduled in the same cycle share one
//! register-bank readout (the input crossbar broadcasts), improving data
//! reuse and relaxing bank constraints. ICR greedily groups such "similar
//! edges".
//!
//! This module is pure scheduling logic: bank availability is injected by
//! the caller (`available`/`claim`), so the same code serves the idealized
//! pass (everything available) and the port-accurate pass.

/// One CU's candidates for this cycle: `(cu, edges)`, each edge `(src, nz)`.
pub type CuCandidates = (u32, Vec<(u32, u32)>);

/// Outcome of edge selection for one cycle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    /// Chosen edge per CU: `(cu, src, nz)`.
    pub chosen: Vec<(u32, u32, u32)>,
    /// CUs whose every candidate was unavailable (bank-blocked → Bnop).
    pub blocked: Vec<u32>,
}

/// Algorithm 2. `available(src)` must return whether the source's bank can
/// be read this cycle (callers should return `true` for already-claimed
/// sources — broadcast — and for forwardable ones); `claim(src)` records a
/// new bank-port claim.
pub fn icr_select(
    cands: &[CuCandidates],
    mut available: impl FnMut(u32) -> bool,
    mut claim: impl FnMut(u32),
) -> Selection {
    // Line 1: classify edges in C by source. Counts are maintained
    // *incrementally* as sub-containers are removed (the per-round recount
    // of the naive transcription was the compiler's top profile entry —
    // EXPERIMENTS.md §Perf). Since C == D initially, the R-value equals
    // the initial count of each category.
    let mut slot_of: crate::util::fasthash::IntMap<u32, usize> = Default::default();
    let mut srcs: Vec<u32> = Vec::new(); // dense category ids
    let mut count: Vec<u32> = Vec::new(); // live count in D
    let mut r_value: Vec<u32> = Vec::new(); // |category in C| (static)
    for (_, edges) in cands {
        for &(src, _) in edges {
            let slot = *slot_of.entry(src).or_insert_with(|| {
                srcs.push(src);
                count.push(0);
                r_value.push(0);
                srcs.len() - 1
            });
            count[slot] += 1;
            r_value[slot] += 1;
        }
    }
    let mut chosen = Vec::with_capacity(cands.len());
    let mut blocked = Vec::new();
    // D: remaining sub-containers (indices into cands).
    let mut remaining: Vec<usize> = (0..cands.len()).collect();
    while !remaining.is_empty() {
        // get_max_category with min-R tie-break (then min src for
        // determinism), scanning the dense category table.
        let mut best: Option<(u32, u32, u32, usize)> = None;
        for slot in 0..srcs.len() {
            let c = count[slot];
            if c == 0 || !available(srcs[slot]) {
                continue;
            }
            let r = r_value[slot];
            let src = srcs[slot];
            let better = match best {
                None => true,
                Some((bc, br, bsrc, _)) => {
                    c > bc || (c == bc && (r < br || (r == br && src < bsrc)))
                }
            };
            if better {
                best = Some((c, r, src, slot));
            }
        }
        let Some((_, _, src, _)) = best else {
            // Every remaining category is bank-blocked.
            blocked.extend(remaining.iter().map(|&ci| cands[ci].0));
            break;
        };
        claim(src);
        // Assign this category's edge to every remaining CU that has one,
        // decrementing the counts of the removed sub-containers' edges.
        remaining.retain(|&ci| {
            let (cu, edges) = &cands[ci];
            if let Some(&(s, nz)) = edges.iter().find(|&&(s, _)| s == src) {
                chosen.push((*cu, s, nz));
                for &(es, _) in edges {
                    count[slot_of[&es]] -= 1;
                }
                false
            } else {
                true
            }
        });
    }
    chosen.sort_unstable();
    blocked.sort_unstable();
    Selection { chosen, blocked }
}

/// The traditional baseline (paper §IV.C): each CU independently picks its
/// computable edge with the smallest source id; no deliberate grouping.
/// Bank availability still applies (a denied CU is blocked).
pub fn ascending_select(
    cands: &[CuCandidates],
    mut available: impl FnMut(u32) -> bool,
    mut claim: impl FnMut(u32),
) -> Selection {
    let mut chosen = Vec::with_capacity(cands.len());
    let mut blocked = Vec::new();
    for (cu, edges) in cands {
        // Edges sorted by source id; take the first available.
        let mut sorted: Vec<&(u32, u32)> = edges.iter().collect();
        sorted.sort_unstable();
        match sorted.iter().find(|&&&(s, _)| available(s)) {
            Some(&&(s, nz)) => {
                claim(s);
                chosen.push((*cu, s, nz));
            }
            None => blocked.push(*cu),
        }
    }
    chosen.sort_unstable();
    blocked.sort_unstable();
    Selection { chosen, blocked }
}

/// Count, for a cycle's selection, how many register-bank readouts were
/// saved by same-source grouping: `Σ (group size − 1)`.
pub fn broadcast_savings(chosen: &[(u32, u32, u32)]) -> usize {
    let mut per_src: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
    for &(_, src, _) in chosen {
        *per_src.entry(src).or_insert(0) += 1;
    }
    per_src.values().map(|&c| c - 1).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_available(_: u32) -> bool {
        true
    }

    #[test]
    fn groups_similar_edges() {
        // Three CUs, all can compute an edge from source 7; ICR must pick
        // the shared source for all of them in one round.
        let cands = vec![
            (0u32, vec![(7u32, 100u32), (1, 101)]),
            (1, vec![(7, 102), (2, 103)]),
            (2, vec![(7, 104)]),
        ];
        let sel = icr_select(&cands, all_available, |_| {});
        assert_eq!(sel.blocked, Vec::<u32>::new());
        assert_eq!(
            sel.chosen,
            vec![(0, 7, 100), (1, 7, 102), (2, 7, 104)]
        );
        assert_eq!(broadcast_savings(&sel.chosen), 2);
    }

    #[test]
    fn tie_breaks_by_min_r_value() {
        // Sources 3 and 4 both appear in two candidate lists (count tie in
        // D), but source 3 appears 3 times in C overall (R=3) vs 2 for
        // source 4 → choose 4 first (min R), keeping 3 groupable later.
        let cands = vec![
            (0u32, vec![(3u32, 1u32), (4, 2)]),
            (1, vec![(3, 3), (4, 4)]),
            (2, vec![(3, 5)]),
        ];
        // Count in D: src 3 → 3, src 4 → 2. Max is src 3 (no tie) → chosen
        // first here. Build a real tie instead:
        let cands_tie = vec![
            (0u32, vec![(3u32, 1u32), (4, 2)]),
            (1, vec![(3, 3), (4, 4)]),
            (2, vec![(4, 5), (9, 6)]),
            (3, vec![(3, 7), (9, 8)]),
        ];
        // In C: R(3)=3, R(4)=3, R(9)=2. In D: count(3)=3, count(4)=3 (tie),
        // count(9)=2 → pick min R among {3,4}: equal (3) → min src = 3.
        let sel = icr_select(&cands_tie, all_available, |_| {});
        let srcs: Vec<u32> = sel.chosen.iter().map(|&(_, s, _)| s).collect();
        // CUs 0,1,3 take src 3; CU 2 then takes src 4 or 9 (count 1 each,
        // R(9)=2 < R(4)=3 → but count(4)=1=count(9), tie → min R → 9).
        assert_eq!(srcs, vec![3, 3, 9, 3]);
        let _ = cands;
    }

    #[test]
    fn every_cu_gets_one_edge() {
        let cands = vec![
            (0u32, vec![(1u32, 0u32), (2, 1)]),
            (1, vec![(3, 2)]),
            (2, vec![(2, 3), (3, 4)]),
            (5, vec![(9, 5)]),
        ];
        let sel = icr_select(&cands, all_available, |_| {});
        assert_eq!(sel.chosen.len(), 4);
        let cus: Vec<u32> = sel.chosen.iter().map(|&(c, _, _)| c).collect();
        assert_eq!(cus, vec![0, 1, 2, 5]);
    }

    #[test]
    fn bank_blocking_produces_blocked_cus() {
        let cands = vec![(0u32, vec![(1u32, 0u32)]), (1, vec![(1, 1), (2, 2)])];
        // Source 1 unavailable; source 2 fine.
        let sel = icr_select(&cands, |s| s != 1, |_| {});
        assert_eq!(sel.blocked, vec![0]);
        assert_eq!(sel.chosen, vec![(1, 2, 2)]);
    }

    #[test]
    fn claim_called_once_per_group() {
        let cands = vec![
            (0u32, vec![(5u32, 0u32)]),
            (1, vec![(5, 1)]),
            (2, vec![(6, 2)]),
        ];
        let mut claims = Vec::new();
        let sel = icr_select(&cands, all_available, |s| claims.push(s));
        assert_eq!(sel.chosen.len(), 3);
        claims.sort_unstable();
        assert_eq!(claims, vec![5, 6]);
    }

    #[test]
    fn ascending_picks_min_src() {
        let cands = vec![(0u32, vec![(9u32, 0u32), (2, 1), (5, 2)])];
        let sel = ascending_select(&cands, all_available, |_| {});
        assert_eq!(sel.chosen, vec![(0, 2, 1)]);
    }

    #[test]
    fn ascending_blocks_when_all_unavailable() {
        let cands = vec![(3u32, vec![(1u32, 0u32), (2, 1)])];
        let sel = ascending_select(&cands, |_| false, |_| {});
        assert_eq!(sel.blocked, vec![3]);
    }

    #[test]
    fn icr_beats_ascending_on_fig8_like_case() {
        // Fig. 8: without reordering each PE reads a different source each
        // cycle; with ICR the shared source is read once. Construct two CUs
        // over two virtual cycles and compare total bank claims.
        let cycle1 = vec![
            (0u32, vec![(7u32, 0u32), (8, 1)]),
            (1, vec![(8, 2), (3, 3)]),
        ];
        let mut claims_icr = 0usize;
        let sel = icr_select(&cycle1, all_available, |_| claims_icr += 1);
        assert_eq!(sel.chosen.len(), 2);
        let mut claims_asc = 0usize;
        let mut seen = std::collections::HashSet::new();
        let _ = ascending_select(
            &cycle1,
            |_| true,
            |s| {
                if seen.insert(s) {
                    claims_asc += 1;
                }
            },
        );
        // ICR groups on source 8 (count 2) → 1 claim vs ascending's 2
        // (src 7 for CU0, src 3 for CU1... ascending picks min: 7 and 3).
        assert!(claims_icr < claims_asc, "{claims_icr} vs {claims_asc}");
    }

    #[test]
    fn empty_input() {
        let sel = icr_select(&[], all_available, |_| {});
        assert!(sel.chosen.is_empty() && sel.blocked.is_empty());
    }
}
