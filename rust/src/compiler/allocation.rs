//! Coarse-node → CU allocation (compiler step 1, §III.A).
//!
//! The medium granularity dataflow keeps the coarse node as the *minimal
//! load allocating unit*: every node is pinned to exactly one CU, and the
//! CU's task list preserves topological (row) order, which the partial-sum
//! rules in §IV.B rely on ("the first new node in the task list").

use crate::graph::Dag;

/// Allocation policy. The paper allocates "according to the topological
/// order of the graph"; the exact tie-breaking is not specified, so both
/// natural choices are provided (and compared by the ablation bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocationPolicy {
    /// Node `i` goes to CU `i mod P` — pure topological round-robin.
    RoundRobin,
    /// Each node goes to the CU with the least total input edges so far —
    /// reduces the load-balance degree (Table III col. 10) on skewed DAGs.
    LeastLoaded,
}

/// Result of the allocation step.
#[derive(Debug, Clone)]
pub struct Allocation {
    /// CU of each node.
    pub cu_of: Vec<u32>,
    /// Per-CU task lists in topological order.
    pub tasks: Vec<Vec<u32>>,
    /// Total input edges assigned to each CU (load balance input).
    pub edges_per_cu: Vec<usize>,
}

/// Allocate all nodes of `g` to `num_cus` CUs.
pub fn allocate(g: &Dag, num_cus: usize, policy: AllocationPolicy) -> Allocation {
    assert!(num_cus > 0);
    let mut cu_of = vec![0u32; g.n];
    let mut tasks = vec![Vec::new(); num_cus];
    let mut edges_per_cu = vec![0usize; num_cus];
    // Node load: its input edges plus the final self-update op.
    match policy {
        AllocationPolicy::RoundRobin => {
            for i in 0..g.n {
                let cu = i % num_cus;
                cu_of[i] = cu as u32;
                tasks[cu].push(i as u32);
                edges_per_cu[cu] += g.in_degree(i);
            }
        }
        AllocationPolicy::LeastLoaded => {
            // Load counted in op-slots (edges + 1 final op), which is what a
            // CU actually spends cycles on.
            let mut load = vec![0usize; num_cus];
            for i in 0..g.n {
                let cu = (0..num_cus).min_by_key(|&c| (load[c], c)).unwrap();
                cu_of[i] = cu as u32;
                tasks[cu].push(i as u32);
                load[cu] += g.in_degree(i) + 1;
                edges_per_cu[cu] += g.in_degree(i);
            }
        }
    }
    Allocation {
        cu_of,
        tasks,
        edges_per_cu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats::load_balance_degree;
    use crate::matrix::gen::{self, GenSeed};

    fn dag(n: usize, seed: u64) -> Dag {
        Dag::from_csr(&gen::circuit(n, 5, 0.8, GenSeed(seed)))
    }

    #[test]
    fn round_robin_is_modular() {
        let g = dag(100, 1);
        let a = allocate(&g, 8, AllocationPolicy::RoundRobin);
        for i in 0..g.n {
            assert_eq!(a.cu_of[i] as usize, i % 8);
        }
    }

    #[test]
    fn task_lists_partition_nodes_in_order() {
        let g = dag(257, 2);
        for policy in [AllocationPolicy::RoundRobin, AllocationPolicy::LeastLoaded] {
            let a = allocate(&g, 16, policy);
            let mut seen = vec![false; g.n];
            for (cu, list) in a.tasks.iter().enumerate() {
                for w in list.windows(2) {
                    assert!(w[0] < w[1], "task list of CU {cu} not in topo order");
                }
                for &t in list {
                    assert!(!seen[t as usize]);
                    seen[t as usize] = true;
                    assert_eq!(a.cu_of[t as usize] as usize, cu);
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn least_loaded_improves_balance_on_skewed_dag() {
        let m = gen::power_law(2000, 1.1, 300, GenSeed(3));
        let g = Dag::from_csr(&m);
        let rr = allocate(&g, 64, AllocationPolicy::RoundRobin);
        let ll = allocate(&g, 64, AllocationPolicy::LeastLoaded);
        let cv_rr = load_balance_degree(&rr.edges_per_cu);
        let cv_ll = load_balance_degree(&ll.edges_per_cu);
        assert!(
            cv_ll <= cv_rr,
            "least-loaded should not be worse: {cv_ll} vs {cv_rr}"
        );
    }

    #[test]
    fn edges_per_cu_sums_to_total() {
        let g = dag(500, 4);
        let a = allocate(&g, 32, AllocationPolicy::LeastLoaded);
        assert_eq!(a.edges_per_cu.iter().sum::<usize>(), g.num_edges());
    }

    #[test]
    fn single_cu_gets_everything() {
        let g = dag(50, 5);
        let a = allocate(&g, 1, AllocationPolicy::RoundRobin);
        assert_eq!(a.tasks[0].len(), 50);
    }
}
