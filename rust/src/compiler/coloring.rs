//! Bank assignment by greedy graph coloring (compiler step 4, §III.A).
//!
//! Every solved `x_i` lives in exactly one bank of the global (banked)
//! `x_i` register file. Two values accessed in the same cycle from
//! different banks proceed in parallel; in the same bank they conflict.
//! The idealized scheduling pass collects *constraints* — pairs of values
//! co-accessed in some cycle — and this module colors the constraint graph
//! with at most `2^N` colors (banks), greedily, in descending-degree order.
//!
//! When a node's neighbors exhaust every color, the color violating the
//! fewest constraints is chosen; the remaining violations surface as bank
//! conflicts (Bnops) in the port-accurate pass, exactly the residual the
//! paper measures in Fig. 9(e).

/// Result of the coloring step.
#[derive(Debug, Clone)]
pub struct BankAssignment {
    /// Bank of each node's solution.
    pub bank_of: Vec<u32>,
    /// Constraint edges that could not be satisfied (same color).
    pub violations: usize,
    /// Total constraint edges considered.
    pub constraints: usize,
}

/// Greedy coloring. `fallback[i]` provides the initial/default bank for
/// unconstrained nodes (the owner CU, giving locality); `num_banks` is the
/// number of register-file banks (== CUs).
pub fn color(
    n: usize,
    constraints: &[(u32, u32)],
    fallback: &[u32],
    num_banks: usize,
) -> BankAssignment {
    assert_eq!(fallback.len(), n);
    // Adjacency in CSR form.
    let mut degree = vec![0usize; n];
    for &(a, b) in constraints {
        degree[a as usize] += 1;
        degree[b as usize] += 1;
    }
    let mut adj_ptr = vec![0usize; n + 1];
    for i in 0..n {
        adj_ptr[i + 1] = adj_ptr[i] + degree[i];
    }
    let mut adj = vec![0u32; constraints.len() * 2];
    let mut cursor = adj_ptr.clone();
    for &(a, b) in constraints {
        adj[cursor[a as usize]] = b;
        cursor[a as usize] += 1;
        adj[cursor[b as usize]] = a;
        cursor[b as usize] += 1;
    }
    // Color in descending constraint degree (ties by id for determinism).
    let mut order: Vec<u32> = (0..n as u32).filter(|&i| degree[i as usize] > 0).collect();
    order.sort_unstable_by_key(|&i| (std::cmp::Reverse(degree[i as usize]), i));
    let mut bank_of: Vec<u32> = fallback.to_vec();
    let mut colored = vec![false; n];
    let mut neighbor_count = vec![0u32; num_banks];
    let mut violations = 0usize;
    for &i in &order {
        let iu = i as usize;
        neighbor_count.iter_mut().for_each(|c| *c = 0);
        for &j in &adj[adj_ptr[iu]..adj_ptr[iu + 1]] {
            let ju = j as usize;
            if colored[ju] {
                neighbor_count[bank_of[ju] as usize] += 1;
            }
        }
        // Prefer the fallback bank if clean, else the cleanest bank,
        // breaking ties toward the fallback (locality) then lowest id.
        let fb = fallback[iu] as usize;
        let mut best = fb;
        if neighbor_count[fb] > 0 {
            best = (0..num_banks)
                .min_by_key(|&c| (neighbor_count[c], if c == fb { 0 } else { 1 }, c))
                .unwrap();
        }
        violations += neighbor_count[best] as usize;
        bank_of[iu] = best as u32;
        colored[iu] = true;
    }
    BankAssignment {
        bank_of,
        violations,
        constraints: constraints.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift64;

    #[test]
    fn triangle_needs_three_colors() {
        let a = color(3, &[(0, 1), (1, 2), (0, 2)], &[0, 0, 0], 4);
        assert_eq!(a.violations, 0);
        assert_ne!(a.bank_of[0], a.bank_of[1]);
        assert_ne!(a.bank_of[1], a.bank_of[2]);
        assert_ne!(a.bank_of[0], a.bank_of[2]);
    }

    #[test]
    fn unconstrained_nodes_keep_fallback() {
        let a = color(4, &[(0, 1)], &[3, 3, 2, 1], 4);
        assert_eq!(a.bank_of[2], 2);
        assert_eq!(a.bank_of[3], 1);
    }

    #[test]
    fn overconstrained_counts_violations() {
        // K4 with only 2 banks: at least 2 violating edges remain.
        let cons = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)];
        let a = color(4, &cons, &[0; 4], 2);
        assert!(a.violations >= 2, "violations={}", a.violations);
        assert!(a.bank_of.iter().all(|&b| b < 2));
    }

    #[test]
    fn random_graph_zero_violations_with_enough_banks() {
        let mut rng = XorShift64::new(5);
        let n = 200;
        let mut cons = Vec::new();
        for _ in 0..600 {
            let a = rng.range(0, n) as u32;
            let b = rng.range(0, n) as u32;
            if a != b {
                cons.push((a.min(b), a.max(b)));
            }
        }
        cons.sort_unstable();
        cons.dedup();
        let fallback: Vec<u32> = (0..n as u32).map(|i| i % 64).collect();
        let a = color(n, &cons, &fallback, 64);
        // Max degree ≪ 64 here, so greedy must find a proper coloring.
        assert_eq!(a.violations, 0);
        // Verify no constraint is violated.
        for &(x, y) in &cons {
            assert_ne!(a.bank_of[x as usize], a.bank_of[y as usize]);
        }
    }

    #[test]
    fn deterministic() {
        let cons = [(0, 1), (1, 2), (2, 3), (3, 0)];
        let a = color(4, &cons, &[0; 4], 8);
        let b = color(4, &cons, &[0; 4], 8);
        assert_eq!(a.bank_of, b.bank_of);
    }
}
