//! Instruction emission (compiler step 5, §III.A: "address the potential
//! spilling issues of the register files and generate the instructions").
//!
//! Emission walks the final (port-accurate) schedule cycle by cycle while
//! mirroring the exact hardware state the instructions will induce:
//!
//! - the per-bank `x_i` register files with their priority-encoder write
//!   addresses, read-address releases (`R_vs`), and spill evictions,
//! - the per-CU `psum` register files (read-before-write),
//! - the per-CU data-memory append logs,
//! - the per-CU operand streams (`L` values and reciprocal diagonals).
//!
//! Within a cycle the hardware ordering contract (mirrored by the
//! simulator) is: **reads see start-of-cycle state → read releases apply →
//! evictions apply → writes land at the priority encoder's lowest free
//! address**.
//!
//! Because every solved `x` is written to the data memory at solve time,
//! spilling follows the paper's cheap path: "the address will be directly
//! released if the data memory already holds the same data" — an evicted
//! value is simply re-read from the data memory by later consumers. The
//! eviction victim is chosen with full lookahead (the compiler knows the
//! schedule): the resident value whose next bank read is farthest away
//! (Belady).

use super::dataflow::{PsumCtl, SchedOp, SchedStats, Schedule};
use super::isa::{Instr, NopKind, PsumSrc, XiSrc};
use crate::arch::ArchConfig;
use crate::graph::stats::load_balance_degree;
use crate::graph::Dag;
use crate::matrix::CsrMatrix;
use anyhow::{ensure, Result};

/// Compile-time statistics (feeds Table III / Fig. 9(d)(e) rows).
#[derive(Debug, Clone, Default)]
pub struct CompileStats {
    /// Constraints collected by the idealized pass.
    pub constraints: u64,
    /// Constraint edges the greedy coloring could not satisfy.
    pub coloring_violations: usize,
    /// Cycles of the idealized (infinite-port) schedule.
    pub ideal_cycles: u64,
    /// Input edges per CU (load-balance input).
    pub edges_per_cu: Vec<usize>,
    /// Coefficient of variation of `edges_per_cu`, percent (Table III).
    pub load_balance_degree: f64,
    /// Values evicted from the x_i register files (spills).
    pub spills: u64,
    /// Operand reads redirected to the data memory after a spill.
    pub dm_redirected_reads: u64,
    /// Wall-clock compile time in seconds (filled by `compile`).
    pub compile_seconds: f64,
}

/// A fully compiled program: everything the accelerator (simulator) needs.
/// The simulator never sees the matrix — operand values live in the
/// reordered streams, positions in the instructions (§III.B: "positional
/// information is hidden in the instructions").
#[derive(Debug, Clone)]
pub struct Program {
    /// Architecture it was compiled for.
    pub arch: ArchConfig,
    /// Matrix order.
    pub n: usize,
    /// Matrix nonzeros (incl. diagonal).
    pub nnz: usize,
    /// Owner CU of each node.
    pub cu_of: Vec<u32>,
    /// Home register bank of each node's solution.
    pub bank_of: Vec<u32>,
    /// Decoded instruction streams, `instrs[cu][cycle]`.
    pub instrs: Vec<Vec<Instr>>,
    /// Per-CU operand streams: `L_ij` per MAC, `1/L_ii` per final, in issue
    /// order (the stream-memory contents, already reordered — §III.B).
    pub l_stream: Vec<Vec<f32>>,
    /// Per-CU node solve order: the k-th final op of CU `c` solves node
    /// `solve_order[c][k]`. Drives RHS gathering and solution scatter.
    pub solve_order: Vec<Vec<u32>>,
    /// Predicted solve cycle of each node.
    pub solved_at: Vec<u32>,
    /// Predicted schedule statistics (the simulator must reproduce
    /// `predicted.cycles` exactly — the double-entry check).
    pub predicted: SchedStats,
    /// Compiler-side statistics.
    pub compile: CompileStats,
}

impl Program {
    /// Number of CUs.
    pub fn num_cus(&self) -> usize {
        self.instrs.len()
    }

    /// FLOPs of one solve (= binary nodes, Table III).
    pub fn flops(&self) -> u64 {
        2 * self.nnz as u64 - self.n as u64
    }

    /// Predicted solve latency in seconds.
    pub fn predicted_seconds(&self) -> f64 {
        self.predicted.cycles as f64 * self.arch.clock_period()
    }

    /// Predicted throughput in GOPS (paper's metric: binary nodes / time).
    pub fn predicted_gops(&self) -> f64 {
        self.flops() as f64 / self.predicted_seconds() / 1e9
    }

    /// Encode all instruction streams into 90-bit words.
    pub fn encode(&self) -> Vec<Vec<u128>> {
        self.instrs
            .iter()
            .map(|row| row.iter().map(Instr::encode).collect())
            .collect()
    }

    /// Total VLIW words (instruction-memory occupancy, one word per CU per
    /// cycle as in the paper's Fig. 5 accounting).
    pub fn instr_words(&self) -> usize {
        self.instrs.iter().map(Vec::len).sum()
    }

    /// Stream-memory occupancy in words.
    pub fn stream_words(&self) -> usize {
        self.l_stream.iter().map(Vec::len).sum()
    }
}

/// Per-bank `x_i` register-file mirror with priority-encoder semantics.
struct BankMirror {
    /// `slots[a] = Some(node)` when address `a` holds that node's solution.
    slots: Vec<Option<u32>>,
}

impl BankMirror {
    fn new(words: usize) -> Self {
        Self {
            slots: vec![None; words],
        }
    }
    /// Priority encoder: lowest free address.
    fn lowest_free(&self) -> Option<u16> {
        self.slots.iter().position(Option::is_none).map(|p| p as u16)
    }
}

/// Emit a program from the final schedule.
pub fn emit(
    m: &CsrMatrix,
    g: &Dag,
    schedule: &Schedule,
    cu_of: &[u32],
    bank_of: &[u32],
    arch: &ArchConfig,
    mut compile_stats: CompileStats,
) -> Result<Program> {
    let num_cus = schedule.ops.len();
    let cycles = schedule.stats.cycles as usize;
    let n = m.n;

    // --- Per-node bank-read cycles (unique, ascending). ---
    let mut read_cycles: Vec<Vec<u32>> = vec![Vec::new(); n];
    for row in &schedule.ops {
        for (t, op) in row.iter().enumerate() {
            if let SchedOp::Mac { src, fwd: false, .. } = op {
                read_cycles[*src as usize].push(t as u32);
            }
        }
    }
    for rc in read_cycles.iter_mut() {
        rc.sort_unstable();
        rc.dedup();
    }

    // --- Data-memory local indices (per-CU append order). ---
    let mut dm_local = vec![u32::MAX; n];
    let mut solve_order: Vec<Vec<u32>> = vec![Vec::new(); num_cus];
    for (cu, row) in schedule.ops.iter().enumerate() {
        for op in row {
            if let SchedOp::Final { node, .. } = op {
                dm_local[*node as usize] = solve_order[cu].len() as u32;
                solve_order[cu].push(*node);
            }
        }
    }

    // --- Mirrors. ---
    let mut banks: Vec<BankMirror> = (0..num_cus)
        .map(|_| BankMirror::new(arch.xi_words()))
        .collect();
    let mut slot_of = vec![u16::MAX; n];
    let mut evicted = vec![false; n];
    let mut psum_slots: Vec<Vec<Option<u32>>> =
        vec![vec![None; arch.psum_words as usize]; num_cus];
    // Node sitting in each CU's feedback register (last executed, unfinished).
    let mut feedback: Vec<Option<u32>> = vec![None; num_cus];
    let mut l_stream: Vec<Vec<f32>> = vec![Vec::new(); num_cus];
    let mut instrs: Vec<Vec<Instr>> = vec![Vec::with_capacity(cycles); num_cus];
    let mut next_read_idx = vec![0usize; n];

    for t in 0..cycles {
        let mut pending_releases: Vec<(usize, u16)> = Vec::new();
        let mut pending_writes: Vec<(usize, usize, u32)> = Vec::new(); // (cu, bank, node)
        for cu in 0..num_cus {
            let op = schedule.ops[cu][t];
            let ins = match op {
                SchedOp::Nop(kind) => Instr::nop(kind),
                SchedOp::Mac {
                    node,
                    src,
                    nz,
                    fwd,
                    psum,
                } => {
                    let mut ins = Instr::nop(NopKind::Dnop);
                    ins.block = false;
                    ins.exec = true;
                    ins.ct = true;
                    emit_psum(&mut ins, &mut psum_slots[cu], feedback[cu], node, psum)?;
                    feedback[cu] = Some(node);
                    l_stream[cu].push(m.values[nz as usize]);
                    let s = src as usize;
                    if fwd {
                        ins.xi_src = XiSrc::Forward;
                        ins.in_sel = cu_of[s] as u8;
                    } else if evicted[s] {
                        ins.xi_src = XiSrc::Dm;
                        ins.dm_read = true;
                        ins.dm_owner = cu_of[s] as u8;
                        ins.dm_raddr = dm_local[s];
                        compile_stats.dm_redirected_reads += 1;
                    } else {
                        ins.xi_src = XiSrc::Bank;
                        ins.xi_read = true;
                        ins.in_sel = bank_of[s] as u8;
                        ensure!(slot_of[s] != u16::MAX, "read of unwritten node {s}");
                        ins.xi_raddr = slot_of[s];
                        // Release on the value's last bank read.
                        let rc = &read_cycles[s];
                        while next_read_idx[s] < rc.len() && rc[next_read_idx[s]] < t as u32 {
                            next_read_idx[s] += 1;
                        }
                        debug_assert!(
                            next_read_idx[s] < rc.len() && rc[next_read_idx[s]] == t as u32
                        );
                        if next_read_idx[s] + 1 == rc.len() {
                            ins.xi_release = true;
                            pending_releases.push((bank_of[s] as usize, slot_of[s]));
                        }
                    }
                    ins
                }
                SchedOp::Final { node, psum } => {
                    let mut ins = Instr::nop(NopKind::Dnop);
                    ins.block = false;
                    ins.exec = true;
                    ins.ct = false;
                    emit_psum(&mut ins, &mut psum_slots[cu], feedback[cu], node, psum)?;
                    feedback[cu] = None;
                    let i = node as usize;
                    l_stream[cu].push(1.0 / m.diag(i));
                    ins.dm_write = true;
                    if g.out_degree(i) > 0 {
                        ins.xi_write = true;
                        ins.out_sel = bank_of[i] as u8;
                        pending_writes.push((cu, bank_of[i] as usize, node));
                    }
                    ins
                }
            };
            instrs[cu].push(ins);
        }
        // Releases apply before writes (same-cycle free slots are reusable).
        for (b, addr) in pending_releases {
            if let Some(node) = banks[b].slots[addr as usize] {
                banks[b].slots[addr as usize] = None;
                slot_of[node as usize] = u16::MAX;
            }
        }
        // Writes: priority encoder; evict on overflow.
        for (cu, b, node) in pending_writes {
            let addr = match banks[b].lowest_free() {
                Some(a) => a,
                None => {
                    let victim_addr = choose_victim(&banks[b], &read_cycles, &next_read_idx, t)?;
                    let victim = banks[b].slots[victim_addr as usize].unwrap();
                    banks[b].slots[victim_addr as usize] = None;
                    evicted[victim as usize] = true;
                    slot_of[victim as usize] = u16::MAX;
                    compile_stats.spills += 1;
                    let ins = &mut instrs[cu][t];
                    ins.evict = true;
                    ins.evict_addr = victim_addr;
                    victim_addr
                }
            };
            banks[b].slots[addr as usize] = Some(node);
            slot_of[node as usize] = addr as u16;
        }
    }

    let total_ops: usize = l_stream.iter().map(Vec::len).sum();
    ensure!(
        total_ops == m.nnz(),
        "stream ops {total_ops} != nnz {}",
        m.nnz()
    );
    compile_stats.load_balance_degree = load_balance_degree(&compile_stats.edges_per_cu);

    Ok(Program {
        arch: *arch,
        n,
        nnz: m.nnz(),
        cu_of: cu_of.to_vec(),
        bank_of: bank_of.to_vec(),
        instrs,
        l_stream,
        solve_order,
        solved_at: schedule.solved_at.clone(),
        predicted: schedule.stats.clone(),
        compile: compile_stats,
    })
}

/// Belady victim: resident value with the farthest next bank read (or one
/// never read again). Values read this very cycle are not evictable.
fn choose_victim(
    bank: &BankMirror,
    read_cycles: &[Vec<u32>],
    next_read_idx: &[usize],
    t: usize,
) -> Result<u16> {
    let mut best: Option<(u64, u16)> = None;
    for (addr, slot) in bank.slots.iter().enumerate() {
        let Some(node) = *slot else { continue };
        let nu = node as usize;
        let rc = &read_cycles[nu];
        let mut idx = next_read_idx[nu];
        while idx < rc.len() && (rc[idx] as usize) <= t {
            if rc[idx] as usize == t {
                break;
            }
            idx += 1;
        }
        if idx < rc.len() && rc[idx] as usize == t {
            continue; // read this cycle — not evictable
        }
        let key = if idx >= rc.len() {
            u64::MAX
        } else {
            rc[idx] as u64
        };
        if best.is_none_or(|(bk, _)| key > bk) {
            best = Some((key, addr as u16));
        }
    }
    best.map(|(_, a)| a)
        .ok_or_else(|| anyhow::anyhow!("no evictable slot in full bank at cycle {t}"))
}

/// Fill the psum-path fields of an instruction and mirror the psum RF.
/// `prev` is the node in the CU's feedback register (parked on Park*).
fn emit_psum(
    ins: &mut Instr,
    slots: &mut [Option<u32>],
    prev: Option<u32>,
    node: u32,
    psum: PsumCtl,
) -> Result<()> {
    // Read (and release) first — the RF supports read-before-write.
    match psum {
        PsumCtl::Feedback => ins.psum_src = PsumSrc::Feedback,
        PsumCtl::Zero | PsumCtl::ParkThenZero => ins.psum_src = PsumSrc::Zero,
        PsumCtl::ReadRf | PsumCtl::ParkThenRead => {
            let addr = slots
                .iter()
                .position(|&s| s == Some(node))
                .ok_or_else(|| anyhow::anyhow!("resume of unparked node {node}"))?;
            ins.psum_src = PsumSrc::ReadRf;
            ins.psum_read = true;
            ins.psum_raddr = addr as u16;
            slots[addr] = None;
        }
    }
    if matches!(psum, PsumCtl::ParkThenZero | PsumCtl::ParkThenRead) {
        let prev = prev.ok_or_else(|| anyhow::anyhow!("park without a previous node"))?;
        let addr = slots
            .iter()
            .position(Option::is_none)
            .ok_or_else(|| anyhow::anyhow!("psum RF overflow while parking"))?;
        ins.psum_write = true;
        slots[addr] = Some(prev);
    }
    Ok(())
}
