//! `mgd` — the leader binary: CLI over the compiler, simulator, solve
//! service and benchmark harness.

fn main() {
    mgd_sptrsv::cli::run();
}
