//! Timing helpers for the bench harness (criterion is not available in the
//! offline image; benches use `harness = false` with these utilities).

use std::time::{Duration, Instant};

/// Run `f` once and return (result, elapsed).
pub fn time_it<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Repeatedly run `f` until `min_time` has elapsed (at least `min_iters`
/// iterations), returning the best (minimum) per-iteration time — the usual
/// low-noise point estimate for microbenchmarks.
pub fn bench_best<T, F: FnMut() -> T>(mut f: F, min_iters: usize, min_time: Duration) -> Duration {
    let mut best = Duration::MAX;
    let start = Instant::now();
    let mut iters = 0usize;
    while iters < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed();
        std::hint::black_box(&out);
        if dt < best {
            best = dt;
        }
        iters += 1;
        if iters > 1_000_000 {
            break;
        }
    }
    best
}

/// Format a duration human-readably (ns/µs/ms/s).
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (v, d) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // smoke
    }

    #[test]
    fn bench_best_runs_min_iters() {
        let mut count = 0;
        let _ = bench_best(|| count += 1, 5, Duration::from_millis(0));
        assert!(count >= 5);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(Duration::from_nanos(12)).contains("ns"));
        assert!(fmt_duration(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains(" s"));
    }
}
