//! Fast non-cryptographic hasher for integer keys (the scheduler's
//! constraint sets hash millions of u64 pairs; SipHash showed up at >10%
//! in the compile profile — EXPERIMENTS.md §Perf).

use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xorshift hasher for integer keys (fibonacci hashing).
#[derive(Default)]
pub struct IntHasher(u64);

impl Hasher for IntHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // Fallback for non-integer keys: FNV-ish fold.
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.write_u64(v as u64);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        let mut x = self.0 ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 29;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 32;
        self.0 = x;
    }
    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }
}

/// `BuildHasher` for [`IntHasher`].
pub type BuildIntHasher = BuildHasherDefault<IntHasher>;

/// HashSet with the fast integer hasher.
pub type IntSet<K> = std::collections::HashSet<K, BuildIntHasher>;

/// HashMap with the fast integer hasher.
pub type IntMap<K, V> = std::collections::HashMap<K, V, BuildIntHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_behaves() {
        let mut s: IntSet<u64> = IntSet::default();
        for i in 0..1000u64 {
            assert!(s.insert(i * 7));
        }
        for i in 0..1000u64 {
            assert!(s.contains(&(i * 7)));
            assert!(!s.insert(i * 7));
        }
        assert_eq!(s.len(), 1000);
    }

    #[test]
    fn map_behaves() {
        let mut m: IntMap<u32, u32> = IntMap::default();
        m.insert(5, 1);
        *m.entry(5).or_insert(0) += 1;
        assert_eq!(m[&5], 2);
    }
}
