//! Deterministic xorshift64* PRNG.
//!
//! The offline image vendors no `rand` crate, and the benchmark generators
//! must be reproducible across runs anyway (the bench harness regenerates the
//! paper's figures from *named* workloads), so we use a tiny, well-known
//! generator with an explicit seed everywhere.

/// xorshift64* generator. Not cryptographic; statistically fine for workload
/// synthesis and property-style tests.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator. A zero seed is remapped (xorshift has a zero fixed
    /// point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift reduction; bias is negligible for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric-ish positive integer with mean roughly `mean` (≥ 1).
    pub fn geometric(&mut self, mean: f64) -> usize {
        debug_assert!(mean >= 1.0);
        if mean <= 1.0 {
            return 1;
        }
        let p = 1.0 / mean;
        let mut k = 1usize;
        // Cap to keep generation O(1) in expectation and bounded worst case.
        while !self.chance(p) && k < (mean * 20.0) as usize + 8 {
            k += 1;
        }
        k
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `[lo, hi)` (k must be ≤ hi-lo).
    /// O(k) expected when k ≪ range; falls back to shuffle for dense picks.
    pub fn sample_distinct(&mut self, lo: usize, hi: usize, k: usize) -> Vec<usize> {
        let range = hi - lo;
        assert!(k <= range);
        if k * 3 >= range {
            let mut all: Vec<usize> = (lo..hi).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut picked = std::collections::BTreeSet::new();
        while picked.len() < k {
            picked.insert(self.range(lo, hi));
        }
        picked.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut a = XorShift64::new(0);
        assert_ne!(a.next_u64(), 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift64::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift64::new(11);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = XorShift64::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_sorted() {
        let mut r = XorShift64::new(9);
        for _ in 0..100 {
            let v = r.sample_distinct(10, 50, 12);
            assert_eq!(v.len(), 12);
            for w in v.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(v.iter().all(|&x| (10..50).contains(&x)));
        }
    }

    #[test]
    fn sample_distinct_full_range() {
        let mut r = XorShift64::new(2);
        let v = r.sample_distinct(0, 5, 5);
        assert_eq!(v, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn geometric_mean_roughly_matches() {
        let mut r = XorShift64::new(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.geometric(6.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 6.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift64::new(21);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
