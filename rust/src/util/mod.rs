//! Small utilities shared across the crate: a deterministic PRNG (the image
//! has no `rand` crate), summary statistics, timing, and table formatting.

pub mod fasthash;
pub mod prng;
pub mod stats;
pub mod table;
pub mod timing;

pub use prng::XorShift64;
pub use stats::{coefficient_of_variation, mean, stddev};
pub use table::Table;
pub use timing::time_it;
