//! Minimal fixed-width table printer for the bench harness (the paper's
//! tables/figures are regenerated as aligned text rows).

/// A simple left-padded text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; shorter rows are padded with empty cells.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        r.resize(self.header.len(), String::new());
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "n"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["long-name", "22"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("long-name"));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["x"]);
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        assert!(t.render().contains('x'));
    }
}
