//! Summary statistics used by the benchmark harness and the paper's
//! load-balance metric (coefficient of variation, Table III column 10).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0 for slices of length < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation in percent (paper's "load balance degree").
/// Returns 0 when the mean is 0.
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    100.0 * stddev(xs) / m
}

/// Geometric mean of strictly-positive values; 0 if any value is ≤ 0 or the
/// slice is empty. Used for the paper-style "average speedup" aggregation.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() || xs.iter().any(|&x| x <= 0.0) {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median (of a copy); 0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let k = v.len() / 2;
    if v.len() % 2 == 1 {
        v[k]
    } else {
        0.5 * (v[k - 1] + v[k])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn stddev_basic() {
        assert_eq!(stddev(&[5.0]), 0.0);
        let s = stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_zero_mean() {
        assert_eq!(coefficient_of_variation(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn cv_uniform_is_zero() {
        assert_eq!(coefficient_of_variation(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[1.0, -1.0]), 0.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }
}
