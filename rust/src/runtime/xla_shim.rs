//! API-surface shim for the `xla` crate (PJRT bindings), used to compile
//! the `pjrt` feature on machines without the XLA toolchain.
//!
//! The signatures mirror the subset of `xla-rs` that [`super::client`]
//! calls. Every constructor fails with [`Error::Unavailable`], so a
//! `pjrt`-feature build still links and runs — backend selection simply
//! falls back to the native executor when [`PjRtClient::cpu`] errors.
//!
//! On a machine with the real toolchain, replace this module with the
//! actual `xla` crate (add the dependency and drop the
//! `use crate::runtime::xla_shim as xla;` alias in `client.rs`); no other
//! code changes are needed.

#![allow(dead_code)] // stub types are placeholders for the real crate's ABI

use std::fmt;

/// Error type matching the `xla` crate's role in `Result` signatures.
#[derive(Debug)]
pub enum Error {
    /// The XLA/PJRT toolchain is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: XLA/PJRT toolchain not linked (stub `xla_shim` build; \
                 swap in the real `xla` crate to enable the pjrt backend)"
            ),
        }
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// A PJRT device client (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    /// The CPU client. Always fails in the shim build.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// A compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (stub: never constructed).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal(());

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_v: &[f32]) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("Literal::reshape"))
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// An HLO module parsed from text.
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO-text file.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation wrapping an HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
