//! Medium-granularity dataflow plan for the runtime (paper §IV, brought to
//! the serve path).
//!
//! [`MgdPlan`] is the preprocessing stage of the barrier-free native
//! scheduler: it clusters rows into *medium-granularity nodes* — the same
//! aggregation trade-off the compiler makes between fine (edge) and coarse
//! (level) granularity — and precomputes everything the executor needs so
//! the hot path touches only flat arrays:
//!
//! - **Clustering.** Rows are grouped into contiguous id ranges capped by a
//!   row and an edge budget (mirroring [`crate::compiler::split`]'s
//!   edge-budget heuristic). Contiguity keeps every intra-node dependency
//!   pointing at an *earlier row of the same node* (lower-triangular ids
//!   are topological), so a node executes its rows in ascending order with
//!   no internal scheduling. Deep chains collapse into few large
//!   sequential nodes; wide levels fall into many mutually independent
//!   nodes. The auto sizing derives the caps from the DAG's level-width
//!   statistics and the worker count (see [`MgdPlanConfig::auto`]).
//! - **Dependency counters.** Each node stores its distinct-predecessor
//!   count (the executor's atomic readiness counter seed) and the distinct
//!   successor list it must decrement on completion. Level barriers are
//!   gone: a node runs the moment its own counter hits zero.
//! - **Structure-of-arrays gather layout.** Per node, every off-diagonal
//!   `(col, val)` is packed contiguously (`edge_slot`/`edge_val`, row-major
//!   in CSR order) together with the per-row diagonals, so execution
//!   streams one dense slab instead of chasing `rowptr` indirections.
//! - **ICR-ordered external gather.** All *external* operand sources of a
//!   node (rows owned by other nodes) are deduplicated into one ascending
//!   [`MgdNode::ext`] list — the runtime analog of the compiler's
//!   intra-node computation reordering (§IV.C): edges that consume the
//!   same source share a single readout of the shared `x` array
//!   (broadcast), and the gather walks memory in ascending address order.
//!   Intra-node sources are not gathered at all; they resolve against the
//!   node-local partial-result buffer (the forwarding/psum path, §IV.B).
//!
//! The packed edge order inside each row is exactly the CSR (ascending
//! column) order, and the executor keeps one `f32` accumulator per row, so
//! solutions are **bitwise identical** to
//! [`crate::matrix::triangular::solve_serial`] regardless of node sizing,
//! thread count, or steal order. Reordering here affects *loads*, never
//! the floating-point reduction order.
//!
//! # Example
//!
//! A 4-row factor clustered into two 2-row medium nodes. Rows 2 and 3
//! both read row 0 — an *external* source, deduplicated into a single
//! ICR gather entry — while row 3's read of row 2 is *intra-node* and
//! resolves from the node-local psum buffer instead (tagged with
//! [`LOCAL_BIT`], never gathered):
//!
//! ```
//! use mgd_sptrsv::matrix::CsrMatrix;
//! use mgd_sptrsv::runtime::{MgdPlan, MgdPlanConfig};
//! use mgd_sptrsv::runtime::mgd_plan::LOCAL_BIT;
//!
//! // Lower-triangular (row, col, value) triplets; diagonal last per row.
//! let m = CsrMatrix::from_triplets(
//!     4,
//!     &[
//!         (0, 0, 2.0),
//!         (1, 1, 3.0),
//!         (2, 0, 1.0), (2, 2, 1.0),
//!         (3, 0, 1.0), (3, 2, 1.0), (3, 3, 1.0),
//!     ],
//! )
//! .unwrap();
//! let plan = MgdPlan::build(
//!     &m,
//!     MgdPlanConfig { max_node_rows: 2, max_node_edges: usize::MAX },
//! );
//! assert_eq!(plan.num_nodes(), 2); // rows {0,1} and rows {2,3}
//!
//! let node = &plan.nodes[1];
//! // Row 0 is read twice but gathered once (the ICR dedup)...
//! assert_eq!(node.ext, vec![0]);
//! // ...and row 3 → row 2 stays node-local (one LOCAL_BIT-tagged slot).
//! let locals = node.edge_slot.iter().filter(|&&s| s & LOCAL_BIT != 0).count();
//! assert_eq!(locals, 1);
//! // One distinct predecessor node seeds the readiness counter.
//! assert_eq!(node.init_deps, 1);
//! assert_eq!(plan.nodes[0].succs, vec![1]);
//! ```

use crate::matrix::CsrMatrix;
use anyhow::{ensure, Result};

/// Tag bit marking an edge operand as node-local (resolved from the
/// node's own solved-rows buffer instead of the external gather scratch).
pub const LOCAL_BIT: u32 = 1 << 31;

/// Node sizing knobs for [`MgdPlan::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MgdPlanConfig {
    /// Max rows per medium node.
    pub max_node_rows: usize,
    /// Max packed off-diagonal edges per medium node (a single row may
    /// exceed this on its own; hub rows become single-row nodes).
    pub max_node_edges: usize,
}

impl MgdPlanConfig {
    /// Derive node sizing from the DAG shape and the worker count.
    ///
    /// The row cap balances two pressures: enough nodes to keep `threads`
    /// workers busy (`n / (4·threads)` nodes minimum when the DAG allows
    /// it) against per-node scheduling overhead (counter updates, deque
    /// traffic), which favors larger nodes on deep/narrow DAGs where
    /// parallelism is capped by the dependency structure anyway.
    pub fn auto(n: usize, num_levels: usize, threads: usize) -> Self {
        let avg_width = (n / num_levels.max(1)).max(1);
        let par_rows = n / (4 * threads.max(1)).max(1);
        // Narrow DAGs (avg level width ≈ 1-2) have no row parallelism to
        // preserve — take the large amortization cap directly.
        let max_node_rows = if avg_width <= 2 {
            128
        } else {
            par_rows.clamp(8, 128)
        };
        Self {
            max_node_rows,
            max_node_edges: max_node_rows * 16,
        }
    }
}

impl Default for MgdPlanConfig {
    fn default() -> Self {
        Self {
            max_node_rows: 64,
            max_node_edges: 1024,
        }
    }
}

/// One medium-granularity node: a contiguous row range with its packed
/// gather layout and dependency links.
#[derive(Debug, Clone)]
pub struct MgdNode {
    /// First row id of the contiguous range.
    pub first_row: u32,
    /// Row count of the range.
    pub rows: u32,
    /// Per-row offsets into `edge_slot`/`edge_val`, length `rows + 1`.
    pub edge_ptr: Vec<u32>,
    /// Operand slot per edge, in CSR (ascending column) order within each
    /// row: `LOCAL_BIT | (col - first_row)` for intra-node sources, else an
    /// index into [`MgdNode::ext`].
    pub edge_slot: Vec<u32>,
    /// `L_ij` values parallel to `edge_slot`.
    pub edge_val: Vec<f32>,
    /// Distinct external source rows (global ids), ascending — the
    /// ICR-ordered gather list; duplicates across edges share one entry.
    pub ext: Vec<u32>,
    /// Per-row diagonal values.
    pub diag: Vec<f32>,
    /// Distinct successor node ids (ascending) whose counters this node
    /// decrements on completion.
    pub succs: Vec<u32>,
    /// Distinct predecessor node count (readiness counter seed).
    pub init_deps: u32,
}

impl MgdNode {
    /// Total packed off-diagonal edges of this node.
    pub fn num_edges(&self) -> usize {
        self.edge_slot.len()
    }
}

/// The preprocessed medium-granularity dataflow plan of one matrix.
#[derive(Debug, Clone)]
pub struct MgdPlan {
    /// Matrix order.
    pub n: usize,
    /// Nodes in ascending row order (node ids are topological: every
    /// dependency points at a lower node id).
    pub nodes: Vec<MgdNode>,
    /// Owning node of each row.
    pub node_of: Vec<u32>,
    /// Nodes with no predecessors (ready at time zero).
    pub roots: Vec<u32>,
    /// Maximum width of the node DAG's level decomposition — a cheap
    /// upper-bound-flavored estimate of useful worker parallelism (the
    /// true maximum antichain can be somewhat larger; the executor uses
    /// this only to avoid spawning workers for serial plans).
    pub par_width: usize,
    /// The sizing the plan was built with.
    pub config: MgdPlanConfig,
}

impl MgdPlan {
    /// Cluster `m`'s rows and precompute the per-node layouts.
    pub fn build(m: &CsrMatrix, cfg: MgdPlanConfig) -> Self {
        let n = m.n;
        let max_rows = cfg.max_node_rows.max(1);
        let max_edges = cfg.max_node_edges.max(1);
        // Pass 1: contiguous clustering under the row/edge budgets.
        let mut bounds: Vec<(usize, usize)> = Vec::new(); // [lo, hi)
        let mut lo = 0usize;
        let mut edges = 0usize;
        for i in 0..n {
            let deg = m.in_degree(i);
            if i > lo && (i - lo >= max_rows || edges + deg > max_edges) {
                bounds.push((lo, i));
                lo = i;
                edges = 0;
            }
            edges += deg;
        }
        if n > 0 {
            bounds.push((lo, n));
        }
        let mut node_of = vec![0u32; n];
        for (k, &(blo, bhi)) in bounds.iter().enumerate() {
            for r in blo..bhi {
                node_of[r] = k as u32;
            }
        }
        // Pass 2: per-node packed layout + ICR-ordered external gather.
        let mut nodes: Vec<MgdNode> = Vec::with_capacity(bounds.len());
        for &(blo, bhi) in &bounds {
            let rows = bhi - blo;
            let mut edge_ptr = Vec::with_capacity(rows + 1);
            let mut edge_slot = Vec::new();
            let mut edge_val = Vec::new();
            let mut diag = Vec::with_capacity(rows);
            let mut ext: Vec<u32> = Vec::new();
            for i in blo..bhi {
                let (cols, _) = m.row_off_diag(i);
                ext.extend(cols.iter().copied().filter(|&c| (c as usize) < blo));
            }
            ext.sort_unstable();
            ext.dedup();
            edge_ptr.push(0u32);
            for i in blo..bhi {
                let (cols, vals) = m.row_off_diag(i);
                for (&c, &v) in cols.iter().zip(vals) {
                    let slot = if (c as usize) >= blo {
                        LOCAL_BIT | (c - blo as u32)
                    } else {
                        ext.binary_search(&c).expect("external source collected") as u32
                    };
                    edge_slot.push(slot);
                    edge_val.push(v);
                }
                edge_ptr.push(edge_slot.len() as u32);
                diag.push(m.diag(i));
            }
            nodes.push(MgdNode {
                first_row: blo as u32,
                rows: rows as u32,
                edge_ptr,
                edge_slot,
                edge_val,
                ext,
                diag,
                succs: Vec::new(),
                init_deps: 0,
            });
        }
        // Pass 3: dependency links plus the node-DAG level decomposition
        // (longest path), whose max width estimates worker parallelism.
        // `ext` is ascending and nodes own contiguous ranges, so the
        // mapped node ids are non-decreasing and dedup by skipping repeats.
        let mut node_level = vec![0u32; nodes.len()];
        for k in 0..nodes.len() {
            let mut prev = u32::MAX;
            let mut deps = 0u32;
            let mut level = 0u32;
            // Split borrow: preds strictly precede k.
            let (before, after) = nodes.split_at_mut(k);
            let node = &mut after[0];
            for &src in &node.ext {
                let p = node_of[src as usize];
                debug_assert!((p as usize) < k, "external source must precede");
                level = level.max(node_level[p as usize] + 1);
                if p != prev {
                    prev = p;
                    deps += 1;
                    before[p as usize].succs.push(k as u32);
                }
            }
            node_level[k] = level;
            node.init_deps = deps;
        }
        let mut width_of = vec![0usize; nodes.len() + 1];
        for &l in &node_level {
            width_of[l as usize] += 1;
        }
        let par_width = width_of.into_iter().max().unwrap_or(0);
        let roots = nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.init_deps == 0)
            .map(|(k, _)| k as u32)
            .collect();
        Self {
            n,
            nodes,
            node_of,
            roots,
            par_width,
            config: cfg,
        }
    }

    /// Number of medium nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total cross-node dependency edges (counter decrements per solve).
    pub fn num_dep_edges(&self) -> usize {
        self.nodes.iter().map(|nd| nd.succs.len()).sum()
    }

    /// Statically audit the plan without executing it — the static tier
    /// of the verification ladder (see ARCHITECTURE.md): partition
    /// integrity, packed-layout bounds, ICR gather ordering, dependency
    /// counter/successor mirror consistency, acyclicity and `par_width`.
    ///
    /// `MatrixRegistry` registration and swap run this in debug builds,
    /// and `mgd check` runs it from the CLI; it is linear in plan size,
    /// so it is also suitable as an acceptance gate for externally
    /// produced plans (the ROADMAP's JIT tier). Errors name the first
    /// offending node and the violated invariant. A plan straight out of
    /// [`MgdPlan::build`] always verifies; a failure means a builder bug
    /// or a corrupted/hand-constructed plan.
    pub fn verify(&self) -> Result<()> {
        ensure!(
            self.node_of.len() == self.n,
            "node_of length {} != matrix order {}",
            self.node_of.len(),
            self.n
        );
        let num_nodes = self.nodes.len();
        // Partition: contiguous ascending row ranges covering 0..n, each
        // row owned by exactly one node. Disjointness doubles as the
        // no-aliasing proof for the per-node SoA slabs: two nodes can
        // never describe (and the executor never write) the same row.
        let mut next = 0u32;
        for (k, nd) in self.nodes.iter().enumerate() {
            ensure!(nd.rows >= 1, "node {k}: empty row range");
            ensure!(
                nd.first_row == next,
                "node {k}: first_row {} leaves a gap after row {next}",
                nd.first_row
            );
            for r in nd.first_row..nd.first_row + nd.rows {
                ensure!(
                    self.node_of[r as usize] == k as u32,
                    "row {r}: node_of says {} but the partition says {k}",
                    self.node_of[r as usize]
                );
            }
            next += nd.rows;
        }
        ensure!(
            next as usize == self.n,
            "partition covers {next} rows of {}",
            self.n
        );
        // Per-node packed layout, diagonals and the ICR gather list.
        for (k, nd) in self.nodes.iter().enumerate() {
            let rows = nd.rows as usize;
            ensure!(
                nd.edge_ptr.len() == rows + 1,
                "node {k}: edge_ptr length {} != rows + 1 ({})",
                nd.edge_ptr.len(),
                rows + 1
            );
            ensure!(nd.edge_ptr[0] == 0, "node {k}: edge_ptr does not start at 0");
            ensure!(
                nd.edge_ptr.windows(2).all(|w| w[0] <= w[1]),
                "node {k}: edge_ptr is not monotone"
            );
            ensure!(
                *nd.edge_ptr.last().unwrap() as usize == nd.edge_slot.len(),
                "node {k}: edge_ptr end {} != packed edge count {}",
                nd.edge_ptr.last().unwrap(),
                nd.edge_slot.len()
            );
            ensure!(
                nd.edge_val.len() == nd.edge_slot.len(),
                "node {k}: edge_val length {} != edge_slot length {}",
                nd.edge_val.len(),
                nd.edge_slot.len()
            );
            ensure!(
                nd.diag.len() == rows,
                "node {k}: diag length {} != rows {rows}",
                nd.diag.len()
            );
            for (r, &d) in nd.diag.iter().enumerate() {
                ensure!(
                    d.is_finite() && d != 0.0,
                    "node {k} row {}: diagonal {d} must be finite and nonzero",
                    nd.first_row as usize + r
                );
            }
            // The ICR gather list is deduplicated in ascending address
            // order (strictly ascending == sorted + deduped) and strictly
            // external: every source precedes the node's own rows.
            ensure!(
                nd.ext.windows(2).all(|w| w[0] < w[1]),
                "node {k}: ext gather list is not strictly ascending (ICR dedup broken)"
            );
            if let Some(&last) = nd.ext.last() {
                ensure!(
                    last < nd.first_row,
                    "node {k}: ext source {last} is not external (first_row {})",
                    nd.first_row
                );
            }
            // Slots: in bounds, and each row's reconstructed operand
            // columns ascend in CSR order strictly below the row itself
            // (strictly lower-triangular, no forward references).
            for r in 0..rows {
                let lo = nd.edge_ptr[r] as usize;
                let hi = nd.edge_ptr[r + 1] as usize;
                let row = nd.first_row + r as u32;
                let mut min_col = 0u32;
                for &slot in &nd.edge_slot[lo..hi] {
                    let col = if slot & LOCAL_BIT != 0 {
                        let off = slot & !LOCAL_BIT;
                        ensure!(
                            (off as usize) < r,
                            "node {k} row {row}: local slot {off} is not an earlier row"
                        );
                        nd.first_row + off
                    } else {
                        ensure!(
                            (slot as usize) < nd.ext.len(),
                            "node {k} row {row}: ext slot {slot} is out of bounds"
                        );
                        nd.ext[slot as usize]
                    };
                    ensure!(
                        col < row,
                        "node {k} row {row}: operand column {col} is not strictly lower"
                    );
                    ensure!(
                        col >= min_col,
                        "node {k} row {row}: operand columns are out of CSR order"
                    );
                    min_col = col + 1;
                }
            }
        }
        // Dependency links: recompute each node's distinct predecessors
        // from its gather list. `init_deps` (the readiness counter seed)
        // must equal exactly that count, and the `succs` lists must be
        // their exact mirror. Every recomputed edge points at a strictly
        // earlier node, so mirror equality also proves the node DAG is
        // acyclic (node ids are a topological order).
        let mut succ_of: Vec<Vec<u32>> = vec![Vec::new(); num_nodes];
        for (k, nd) in self.nodes.iter().enumerate() {
            let mut deps = 0u32;
            let mut prev = u32::MAX;
            for &src in &nd.ext {
                let p = self.node_of[src as usize];
                ensure!(
                    (p as usize) < k,
                    "node {k}: external source {src} maps to non-preceding node {p}"
                );
                if p != prev {
                    prev = p;
                    deps += 1;
                    succ_of[p as usize].push(k as u32);
                }
            }
            ensure!(
                nd.init_deps == deps,
                "node {k}: init_deps {} != distinct predecessor count {deps}",
                nd.init_deps
            );
        }
        for (k, nd) in self.nodes.iter().enumerate() {
            ensure!(
                nd.succs == succ_of[k],
                "node {k}: succs {:?} do not mirror the dependency edges {:?}",
                nd.succs,
                succ_of[k]
            );
        }
        // Roots are exactly the zero-dependency nodes, ascending.
        let want_roots: Vec<u32> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.init_deps == 0)
            .map(|(k, _)| k as u32)
            .collect();
        ensure!(
            self.roots == want_roots,
            "roots {:?} != the zero-dependency nodes {:?}",
            self.roots,
            want_roots
        );
        // par_width is consistent with the node DAG: it equals the max
        // width of the longest-path level decomposition.
        let mut level = vec![0u32; num_nodes];
        let mut width = vec![0usize; num_nodes + 1];
        for (k, nd) in self.nodes.iter().enumerate() {
            let mut l = 0u32;
            for &src in &nd.ext {
                l = l.max(level[self.node_of[src as usize] as usize] + 1);
            }
            level[k] = l;
            width[l as usize] += 1;
        }
        let want_width = width.into_iter().max().unwrap_or(0);
        ensure!(
            self.par_width == want_width,
            "par_width {} != node-DAG max level width {want_width}",
            self.par_width
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    fn check_invariants(m: &CsrMatrix, p: &MgdPlan) {
        assert_eq!(p.n, m.n);
        // Nodes partition 0..n into contiguous ascending ranges.
        let mut next = 0u32;
        for (k, nd) in p.nodes.iter().enumerate() {
            assert_eq!(nd.first_row, next, "node {k} not contiguous");
            assert!(nd.rows >= 1);
            assert_eq!(nd.edge_ptr.len() as u32, nd.rows + 1);
            assert_eq!(*nd.edge_ptr.last().unwrap() as usize, nd.num_edges());
            assert_eq!(nd.diag.len() as u32, nd.rows);
            for r in nd.first_row..nd.first_row + nd.rows {
                assert_eq!(p.node_of[r as usize], k as u32);
            }
            // ext ascending, deduped, strictly external.
            for w in nd.ext.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &e in &nd.ext {
                assert!(e < nd.first_row);
            }
            // succs ascending, deduped, strictly later.
            for w in nd.succs.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &s in &nd.succs {
                assert!(s as usize > k);
            }
            next += nd.rows;
        }
        assert_eq!(next as usize, m.n);
        // Packed edges reproduce each row's CSR order and operands.
        for nd in &p.nodes {
            for r in 0..nd.rows as usize {
                let i = nd.first_row as usize + r;
                let (cols, vals) = m.row_off_diag(i);
                let lo = nd.edge_ptr[r] as usize;
                let hi = nd.edge_ptr[r + 1] as usize;
                assert_eq!(hi - lo, cols.len(), "row {i} edge count");
                for (e, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                    let slot = nd.edge_slot[lo + e];
                    assert_eq!(nd.edge_val[lo + e], v);
                    if slot & LOCAL_BIT != 0 {
                        assert_eq!(nd.first_row + (slot & !LOCAL_BIT), c);
                    } else {
                        assert_eq!(nd.ext[slot as usize], c);
                    }
                }
                assert_eq!(nd.diag[r], m.diag(i));
            }
        }
        // init_deps counts distinct predecessor nodes; succs mirror them.
        let mut succ_of: Vec<Vec<u32>> = vec![Vec::new(); p.num_nodes()];
        for (k, nd) in p.nodes.iter().enumerate() {
            let mut preds: Vec<u32> = nd.ext.iter().map(|&s| p.node_of[s as usize]).collect();
            preds.dedup();
            assert_eq!(nd.init_deps as usize, preds.len(), "node {k}");
            for pd in preds {
                succ_of[pd as usize].push(k as u32);
            }
        }
        for (k, nd) in p.nodes.iter().enumerate() {
            assert_eq!(nd.succs, succ_of[k], "succs of node {k}");
        }
        // Roots are exactly the zero-dep nodes.
        let want: Vec<u32> = p
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, nd)| nd.init_deps == 0)
            .map(|(k, _)| k as u32)
            .collect();
        assert_eq!(p.roots, want);
        // par_width is the max width of the node-DAG level decomposition.
        let mut level = vec![0u32; p.num_nodes()];
        for (k, nd) in p.nodes.iter().enumerate() {
            for &src in &nd.ext {
                let pd = p.node_of[src as usize] as usize;
                level[k] = level[k].max(level[pd] + 1);
            }
        }
        let mut width = std::collections::HashMap::new();
        for &l in &level {
            *width.entry(l).or_insert(0usize) += 1;
        }
        let want_width = width.values().copied().max().unwrap_or(0);
        assert_eq!(p.par_width, want_width);
        assert!(p.num_nodes() == 0 || (1..=p.num_nodes()).contains(&p.par_width));
    }

    #[test]
    fn plan_invariants_across_generators() {
        let cases: Vec<CsrMatrix> = gen::test_suite().into_iter().map(|(_, m)| m).collect();
        for m in &cases {
            for cfg in [
                MgdPlanConfig::default(),
                MgdPlanConfig {
                    max_node_rows: 1,
                    max_node_edges: 1,
                },
                MgdPlanConfig {
                    max_node_rows: 7,
                    max_node_edges: 40,
                },
            ] {
                let p = MgdPlan::build(m, cfg);
                check_invariants(m, &p);
            }
        }
    }

    #[test]
    fn row_budget_caps_node_sizes() {
        let m = gen::banded(400, 8, 0.7, GenSeed(9));
        let p = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 16,
                max_node_edges: usize::MAX,
            },
        );
        for nd in &p.nodes {
            assert!(nd.rows <= 16);
        }
        assert!(p.num_nodes() >= 400 / 16);
    }

    #[test]
    fn edge_budget_isolates_hub_rows() {
        // Same generator case whose >32-degree hubs the native backend
        // tests already assert on.
        let m = gen::power_law(400, 1.1, 120, GenSeed(7));
        let p = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 64,
                max_node_edges: 32,
            },
        );
        check_invariants(&m, &p);
        // A hub row wider than the budget still gets a (single-row) node.
        let hub_nodes = p.nodes.iter().filter(|nd| nd.num_edges() > 32).count();
        assert!(hub_nodes > 0, "generator should produce >32-edge hubs");
        for nd in &p.nodes {
            if nd.num_edges() > 32 {
                assert_eq!(nd.rows, 1, "oversized node must be a lone hub row");
            }
        }
    }

    #[test]
    fn chain_collapses_into_few_sequential_nodes() {
        let m = gen::chain(1000, GenSeed(11));
        let p = MgdPlan::build(&m, MgdPlanConfig::auto(m.n, 1000, 8));
        // 1000-deep chain at 128 rows/node → ~8 nodes in a single chain.
        assert!(p.num_nodes() <= 1000 / 64, "{}", p.num_nodes());
        assert_eq!(p.roots, vec![0]);
        // A chain of nodes has zero exploitable parallelism: the executor
        // must not spawn any worker for it.
        assert_eq!(p.par_width, 1);
        for (k, nd) in p.nodes.iter().enumerate() {
            if k + 1 < p.num_nodes() {
                assert_eq!(nd.succs, vec![k as u32 + 1]);
            }
        }
    }

    #[test]
    fn ext_deduplicates_shared_sources() {
        // Rows {0,1} form node 0; rows {2,3} form node 1. Rows 2 and 3
        // both read row 0 (one shared ext entry) and row 3 reads row 2
        // (node-local, not gathered at all).
        let m = CsrMatrix::from_triplets(
            4,
            &[
                (0, 0, 2.0),
                (1, 1, 3.0),
                (2, 0, 1.0),
                (2, 2, 1.0),
                (3, 0, 1.0),
                (3, 2, 1.0),
                (3, 3, 1.0),
            ],
        )
        .unwrap();
        let p = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 2,
                max_node_edges: usize::MAX,
            },
        );
        assert_eq!(p.num_nodes(), 2);
        assert_eq!(p.nodes[0].first_row, 0);
        assert_eq!(p.nodes[1].first_row, 2);
        let nd = &p.nodes[1];
        assert_eq!(nd.ext, vec![0]); // row 0 read twice, gathered once
        assert_eq!(nd.num_edges(), 3);
        let locals = nd.edge_slot.iter().filter(|&&s| s & LOCAL_BIT != 0).count();
        assert_eq!(locals, 1); // row 3's read of row 2
        assert_eq!(nd.init_deps, 1);
        assert_eq!(p.nodes[0].succs, vec![1]);
    }

    #[test]
    fn auto_sizing_tracks_shape() {
        // Narrow: large amortization nodes.
        let narrow = MgdPlanConfig::auto(10_000, 9_000, 8);
        assert_eq!(narrow.max_node_rows, 128);
        // Wide: enough nodes for the workers.
        let wide = MgdPlanConfig::auto(10_000, 10, 8);
        assert!(wide.max_node_rows <= 10_000 / 32 + 1);
        assert!(wide.max_node_rows >= 8);
    }

    #[test]
    fn verify_accepts_every_built_plan() {
        for (_, m) in gen::test_suite() {
            for cfg in [
                MgdPlanConfig::default(),
                MgdPlanConfig {
                    max_node_rows: 3,
                    max_node_edges: 17,
                },
            ] {
                MgdPlan::build(&m, cfg).verify().unwrap();
            }
        }
    }

    /// Seeds one corruption into an otherwise valid plan and requires
    /// `verify` to reject it with an error naming the invariant.
    fn expect_reject(mut p: MgdPlan, what: &str, corrupt: impl FnOnce(&mut MgdPlan)) {
        corrupt(&mut p);
        let err = p.verify().expect_err(what);
        let msg = format!("{err:#}");
        assert!(msg.contains(what), "{what}: got {msg}");
    }

    #[test]
    fn verify_rejects_corrupted_plans() {
        let m = gen::banded(200, 4, 0.7, GenSeed(33));
        let base = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 8,
                max_node_edges: 64,
            },
        );
        base.verify().unwrap();
        // A node with two gathered sources (so reversing its gather list
        // is a real, order-only corruption) and at least one successor
        // (so clearing `succs` breaks the mirror).
        let k = base
            .nodes
            .iter()
            .position(|nd| nd.ext.len() >= 2 && !nd.succs.is_empty())
            .expect("banded plan must have an interior node with two external sources");
        expect_reject(base.clone(), "init_deps", |p| p.nodes[k].init_deps += 1);
        expect_reject(base.clone(), "mirror", |p| p.nodes[k].succs.clear());
        expect_reject(base.clone(), "ascending", |p| p.nodes[k].ext.reverse());
        expect_reject(base.clone(), "par_width", |p| p.par_width += 1);
        expect_reject(base.clone(), "gap", |p| p.nodes[k].first_row += 1);
        expect_reject(base.clone(), "finite", |p| p.nodes[k].diag[0] = 0.0);
        expect_reject(base.clone(), "out of bounds", |p| {
            p.nodes[k].edge_slot[0] = 9999;
        });
        expect_reject(base.clone(), "zero-dependency", |p| p.roots.clear());
    }
}
