//! Numeric runtime of the request path: pluggable solver backends over a
//! shared level plan.
//!
//! [`LevelSolver`] preprocesses a matrix once (level sets, per-level
//! max-degree, gather layout, plus a lazily-built medium-granularity
//! [`MgdPlan`]); a [`SolverBackend`] then executes the plan for each
//! right-hand side:
//!
//! - [`NativeBackend`] — the default: pure Rust, no FFI, no build
//!   artifacts. It owns the schedulers selected by
//!   [`SchedulerKind`] (`--scheduler level|mgd|kir|auto`):
//!   - `level` — the simple/reference path: a `std::thread` worker pool
//!     with one barrier per level set and adaptive chunk sizing;
//!   - `mgd` — the paper's medium-granularity dataflow on the serve
//!     path: barrier-free node scheduling over [`MgdPlan`] with
//!     work-stealing deques, counter-driven readiness, node-local
//!     partial sums and ICR-ordered gathers ([`mgd_exec`]), executed on
//!     the backend's persistent [`MgdPool`] (workers spawn once and park
//!     between solves — no per-solve thread spawns on the serve path —
//!     and independent solves overlap as concurrent slot-leased
//!     sessions); bitwise identical to the serial reference for any
//!     thread count;
//!   - `kir` — the `mgd` scheduler with each node's inner loop lowered
//!     to statically verified, index-baked bytecode run by an unchecked
//!     interpreter ([`kir`]); falls back to `mgd` per matrix if the
//!     verifier rejects the lowered program;
//!   - `auto` — picks per plan from the cost model
//!     ([`recommend_scheduler`]): modeled barriered vs barrier-free
//!     execution cost (deep/narrow DAGs go barrier-free). `auto` never
//!     picks `kir` — the unchecked tier is opt-in.
//! - `PjrtBackend` (cargo feature `pjrt`) — loads the AOT-compiled
//!   JAX/Pallas level kernels from `artifacts/*.hlo.txt` and executes
//!   them through PJRT. Python runs only at build time (`make
//!   artifacts`). Selected by [`BackendKind::Auto`] only when the feature
//!   is on *and* the artifacts load.
//!
//! Construct backends through [`create_backend`]; the coordinator, CLI
//! (`--backend native|pjrt|auto --scheduler level|mgd|kir|auto`) and bench
//! harness all route through it.
//!
//! The cross-thread memory-ordering contract shared by both native
//! schedulers is documented below (from `runtime/atomics.md`):
//!
#![doc = include_str!("atomics.md")]

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod kir;
pub mod level_exec;
pub mod mgd_exec;
pub mod mgd_plan;
pub mod native;
pub mod pool;
pub mod sync;
#[cfg(feature = "pjrt")]
pub(crate) mod xla_shim;

pub use backend::{create_backend, BackendConfig, BackendKind, SolverBackend};
pub use kir::{KernelProgram, VerifiedKernel};
pub use level_exec::{LevelPlan, LevelSolver};
pub use mgd_exec::MgdExecStats;
pub use mgd_plan::{MgdPlan, MgdPlanConfig};
pub use native::{
    recommend_mgd_budget, recommend_scheduler, KirStats, MgdStats, NativeBackend, NativeConfig,
    NativeStats, SchedulerKind,
};
pub use pool::{MgdPool, MgdPoolStats, RequestClass};

#[cfg(feature = "pjrt")]
pub use client::PjrtRuntime;
#[cfg(feature = "pjrt")]
pub use level_exec::PjrtBackend;
