//! Numeric runtime of the request path: pluggable solver backends over a
//! shared level plan.
//!
//! [`LevelSolver`] preprocesses a matrix once (level sets, per-level
//! max-degree, gather layout); a [`SolverBackend`] then executes the plan
//! for each right-hand side:
//!
//! - [`NativeBackend`] — the default: a pure-Rust `std::thread` worker
//!   pool that chunks the rows of each level across threads. No FFI, no
//!   build artifacts; this is what a clean `cargo build` serves with.
//! - `PjrtBackend` (cargo feature `pjrt`) — loads the AOT-compiled
//!   JAX/Pallas level kernels from `artifacts/*.hlo.txt` and executes
//!   them through PJRT. Python runs only at build time (`make
//!   artifacts`). Selected by [`BackendKind::Auto`] only when the feature
//!   is on *and* the artifacts load.
//!
//! Construct backends through [`create_backend`]; the coordinator, CLI
//! (`--backend native|pjrt|auto`) and bench harness all route through it.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod level_exec;
pub mod native;
#[cfg(feature = "pjrt")]
pub(crate) mod xla_shim;

pub use backend::{create_backend, BackendConfig, BackendKind, SolverBackend};
pub use level_exec::{LevelPlan, LevelSolver};
pub use native::{NativeBackend, NativeConfig, NativeStats};

#[cfg(feature = "pjrt")]
pub use client::PjrtRuntime;
#[cfg(feature = "pjrt")]
pub use level_exec::PjrtBackend;
