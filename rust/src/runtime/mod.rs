//! PJRT numeric runtime: load the AOT-compiled JAX/Pallas level kernels
//! from `artifacts/*.hlo.txt` and execute them on the request path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path numeric stack (see /opt/xla-example/load_hlo for
//! the wiring pattern).

pub mod client;
pub mod level_exec;

pub use client::PjrtRuntime;
pub use level_exec::LevelSolver;
