//! Native pure-Rust solver backend: a `std::thread` worker pool executing
//! the precomputed level plans on the host CPU.
//!
//! Execution mirrors the structure of the PJRT level kernels so both
//! backends share the plan layout and the numeric contract:
//!
//! - rows within a level are independent, so a level whose row count
//!   exceeds [`NativeConfig::chunk_rows`] is chunked across the pool
//!   (chunks are assigned round-robin, making thread engagement
//!   deterministic); smaller levels run inline on the calling thread;
//! - each row gathers its `(cols, vals)` slices once and reuses the gather
//!   across every RHS of a multi-RHS batch;
//! - the first [`NativeConfig::edge_budget`] edges of a row take the
//!   budgeted MAC path and the overflow edges fold into a serial carry on
//!   `b`, exactly like the kernel dispatch in
//!   [`level_exec`](super::level_exec) — heavy hub rows therefore exercise
//!   the same carry code path on both backends.
//!
//! `x` is shared across threads as `f32` bits in `AtomicU32` slots with
//! relaxed ordering; the per-level completion channel provides the
//! happens-before edge between levels, so dependent reads always observe
//! the writes of earlier levels.

use super::backend::SolverBackend;
use super::level_exec::{LevelPlan, LevelSolver};
use crate::matrix::CsrMatrix;
use anyhow::{anyhow, ensure, Result};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Tuning knobs for the native executor.
#[derive(Debug, Clone, Copy)]
pub struct NativeConfig {
    /// Worker threads; `0` = one per available CPU (capped at 8).
    pub threads: usize,
    /// Rows per parallel work item; levels at or below this size run inline.
    pub chunk_rows: usize,
    /// Edges per row on the budgeted MAC path; overflow edges take the
    /// serial carry (mirrors the compiled kernels' edge budget).
    pub edge_budget: usize,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            chunk_rows: 128,
            edge_budget: 32,
        }
    }
}

/// Execution counters recorded by the native backend.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NativeStats {
    /// Levels executed across the worker pool (≥ 2 chunks dispatched).
    pub parallel_levels: u64,
    /// Total parallel chunks dispatched.
    pub chunks_dispatched: u64,
    /// Worker threads that have executed at least one chunk.
    pub workers_engaged: usize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads, each with its own queue; jobs are
/// assigned round-robin so that dispatching `k ≥ 2` chunks engages
/// `min(k, threads)` distinct workers deterministically.
struct WorkerPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    next: AtomicUsize,
    jobs_run: Arc<Vec<AtomicU64>>,
}

impl WorkerPool {
    fn new(threads: usize) -> Self {
        let jobs_run: Arc<Vec<AtomicU64>> =
            Arc::new((0..threads).map(|_| AtomicU64::new(0)).collect());
        let mut senders = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let (tx, rx) = mpsc::channel::<Job>();
            let counts = Arc::clone(&jobs_run);
            let handle = std::thread::Builder::new()
                .name(format!("mgd-native-{w}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // Count before running so the ack a job sends on
                        // completion happens-after the increment.
                        counts[w].fetch_add(1, Ordering::Relaxed);
                        job();
                    }
                })
                .expect("spawn native worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        Self {
            senders,
            handles,
            next: AtomicUsize::new(0),
            jobs_run,
        }
    }

    fn spawn(&self, job: Job) -> Result<()> {
        let w = self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.senders[w]
            .send(job)
            .map_err(|_| anyhow!("native worker {w} is gone (pool shut down?)"))
    }

    fn workers_engaged(&self) -> usize {
        self.jobs_run
            .iter()
            .filter(|c| c.load(Ordering::Relaxed) > 0)
            .count()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes every queue; workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The native parallel level executor.
pub struct NativeBackend {
    threads: usize,
    chunk_rows: usize,
    edge_budget: usize,
    pool: Option<WorkerPool>,
    parallel_levels: AtomicU64,
    chunks_dispatched: AtomicU64,
}

impl NativeBackend {
    /// Build the backend and spawn its worker pool.
    pub fn new(cfg: NativeConfig) -> Self {
        let threads = if cfg.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .min(8)
        } else {
            cfg.threads
        };
        let chunk_rows = cfg.chunk_rows.max(1);
        let pool = (threads > 1).then(|| WorkerPool::new(threads));
        Self {
            threads,
            chunk_rows,
            edge_budget: cfg.edge_budget.max(1),
            pool,
            parallel_levels: AtomicU64::new(0),
            chunks_dispatched: AtomicU64::new(0),
        }
    }

    /// Worker threads backing this instance.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execution counters since construction.
    pub fn stats(&self) -> NativeStats {
        NativeStats {
            parallel_levels: self.parallel_levels.load(Ordering::Relaxed),
            chunks_dispatched: self.chunks_dispatched.load(Ordering::Relaxed),
            workers_engaged: self.pool.as_ref().map_or(0, WorkerPool::workers_engaged),
        }
    }

    /// Shared scalar/batched execution: solve every RHS in `bs` level by
    /// level. `r = 1` is the scalar path. Takes the batch by value so each
    /// solve pays exactly one staging copy (into the shared `Arc`), never
    /// two.
    fn execute(&self, plan: &LevelSolver, bs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let matrix = plan.matrix_arc();
        let plans = plan.plans_arc();
        let n = matrix.n;
        let r = bs.len();
        if r == 0 {
            return Ok(Vec::new());
        }
        for b in &bs {
            ensure!(b.len() == n, "rhs length {} != matrix order {n}", b.len());
        }
        // x as f32 bits: one flat (r, n) array of atomics shared by workers.
        let x: Arc<Vec<AtomicU32>> = Arc::new(
            std::iter::repeat_with(|| AtomicU32::new(0))
                .take(r * n)
                .collect(),
        );
        let bs_shared: Arc<Vec<Vec<f32>>> = Arc::new(bs);
        let (done_tx, done_rx) = mpsc::channel::<bool>();
        for li in 0..plans.len() {
            let rows_len = plans[li].rows.len();
            let nchunks = rows_len.div_ceil(self.chunk_rows);
            let pool = match &self.pool {
                Some(pool) if nchunks >= 2 => pool,
                _ => {
                    run_chunk(
                        &matrix,
                        &plans[li],
                        0,
                        rows_len,
                        &bs_shared,
                        &x,
                        self.edge_budget,
                    );
                    continue;
                }
            };
            for c in 0..nchunks {
                let lo = c * self.chunk_rows;
                let hi = (lo + self.chunk_rows).min(rows_len);
                let matrix = Arc::clone(&matrix);
                let plans = Arc::clone(&plans);
                let bs_shared = Arc::clone(&bs_shared);
                let x = Arc::clone(&x);
                let done_tx = done_tx.clone();
                let edge_budget = self.edge_budget;
                pool.spawn(Box::new(move || {
                    // Catch panics so a bad chunk job cannot kill its
                    // worker thread or starve the level barrier; the
                    // failure ack turns it into a loud per-solve error.
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_chunk(&matrix, &plans[li], lo, hi, &bs_shared, &x, edge_budget);
                    }))
                    .is_ok();
                    let _ = done_tx.send(ok);
                }))?;
            }
            // Level barrier: dependent rows only exist in later levels.
            let mut panicked = false;
            for _ in 0..nchunks {
                panicked |= !done_rx
                    .recv_timeout(Duration::from_secs(300))
                    .map_err(|_| anyhow!("native worker pool stalled in level {li}"))?;
            }
            ensure!(!panicked, "native chunk job panicked in level {li}");
            self.parallel_levels.fetch_add(1, Ordering::Relaxed);
            self.chunks_dispatched
                .fetch_add(nchunks as u64, Ordering::Relaxed);
        }
        Ok((0..r)
            .map(|k| {
                (0..n)
                    .map(|i| f32::from_bits(x[k * n + i].load(Ordering::Relaxed)))
                    .collect()
            })
            .collect())
    }
}

/// Solve one chunk of a level's rows for every RHS. The `(cols, vals)`
/// gather is done once per row and reused across the batch; edges beyond
/// `edge_budget` fold into the serial carry like the PJRT kernel path.
fn run_chunk(
    m: &CsrMatrix,
    plan: &LevelPlan,
    lo: usize,
    hi: usize,
    bs: &[Vec<f32>],
    x: &[AtomicU32],
    edge_budget: usize,
) {
    let n = m.n;
    for &row in &plan.rows[lo..hi] {
        let i = row as usize;
        let (cols, vals) = m.row_off_diag(i);
        let fit = cols.len().min(edge_budget);
        let dinv = 1.0 / m.diag(i);
        for (k, b) in bs.iter().enumerate() {
            let xk = &x[k * n..(k + 1) * n];
            let mut acc = 0f32;
            for e in 0..fit {
                acc += vals[e] * f32::from_bits(xk[cols[e] as usize].load(Ordering::Relaxed));
            }
            let mut carry = 0f32;
            for e in fit..cols.len() {
                carry += vals[e] * f32::from_bits(xk[cols[e] as usize].load(Ordering::Relaxed));
            }
            let xi = ((b[i] - carry) - acc) * dinv;
            xk[i].store(xi.to_bits(), Ordering::Relaxed);
        }
    }
}

impl SolverBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_multi_rhs(&self) -> bool {
        true
    }

    fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.execute(plan, vec![b.to_vec()])?;
        Ok(out.pop().expect("one RHS in, one solution out"))
    }

    fn solve_multi(&self, plan: &LevelSolver, bs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.execute(plan, bs.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;

    fn backend(threads: usize, chunk_rows: usize) -> NativeBackend {
        NativeBackend::new(NativeConfig {
            threads,
            chunk_rows,
            ..NativeConfig::default()
        })
    }

    /// Property test: for every generator family — including `power_law`
    /// hub rows that exceed the edge budget and exercise the overflow
    /// carry — and for multi-RHS batch sizes {1, 3, 8, 11}, the native
    /// backend matches the serial reference to 1e-3.
    #[test]
    fn native_backend_matches_reference() {
        let cases: Vec<(&str, crate::matrix::CsrMatrix)> = vec![
            ("banded", gen::banded(500, 6, 0.5, GenSeed(1))),
            ("chain", gen::chain(120, GenSeed(2))),
            ("circuit", gen::circuit(600, 5, 0.8, GenSeed(3))),
            ("grid2d", gen::grid2d(20, 20, true, GenSeed(4))),
            ("shallow", gen::shallow(900, 0.4, GenSeed(5))),
            ("random_lower", gen::random_lower(400, 2000, GenSeed(6))),
            ("power_law", gen::power_law(400, 1.1, 120, GenSeed(7))),
            ("factor_like", gen::factor_like(500, 8, 4, GenSeed(8))),
        ];
        // Small chunks so even modest levels split across the pool.
        let nb = backend(4, 16);
        for (name, m) in &cases {
            assert!(
                m.max_in_degree() <= 120,
                "{name}: generator drifted beyond the test envelope"
            );
            let plan = LevelSolver::new(m);
            for count in [1usize, 3, 8, 11] {
                let bs: Vec<Vec<f32>> = (0..count)
                    .map(|k| (0..m.n).map(|i| ((i + 3 * k) % 9) as f32 - 4.0).collect())
                    .collect();
                let xs = nb.solve_multi(&plan, &bs).unwrap();
                assert_eq!(xs.len(), count, "{name}: batch size {count}");
                for (b, x) in bs.iter().zip(&xs) {
                    assert_close_to_reference(m, b, x, 1e-3);
                }
                // Scalar path agrees with the batched path.
                let x0 = nb.solve(&plan, &bs[0]).unwrap();
                assert_close_to_reference(m, &bs[0], &x0, 1e-3);
            }
        }
        // power_law hubs (deg > 32) really did take the carry path.
        let hubs = &cases[6].1;
        assert!(hubs.max_in_degree() > NativeConfig::default().edge_budget);
    }

    #[test]
    fn wide_levels_engage_multiple_workers() {
        // shallow() has a handful of very wide levels; with chunk_rows = 8
        // every wide level dispatches many chunks round-robin across the
        // 4 workers, so ≥ 2 workers must each run at least one chunk.
        let nb = backend(4, 8);
        let m = gen::shallow(2000, 0.4, GenSeed(11));
        let plan = LevelSolver::new(&m);
        let widest = plan.plans().iter().map(|p| p.rows.len()).max().unwrap();
        assert!(widest > 8, "test premise: a level wider than one chunk");
        let b = vec![1.0f32; m.n];
        let x = nb.solve(&plan, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
        let stats = nb.stats();
        assert!(stats.parallel_levels >= 1, "{stats:?}");
        assert!(stats.chunks_dispatched >= 2, "{stats:?}");
        assert!(stats.workers_engaged >= 2, "{stats:?}");
    }

    #[test]
    fn single_thread_config_runs_inline() {
        let nb = backend(1, 8);
        let m = gen::circuit(400, 5, 0.8, GenSeed(12));
        let plan = LevelSolver::new(&m);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 11) as f32 - 5.0).collect();
        let x = nb.solve(&plan, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
        assert_eq!(nb.stats(), NativeStats::default());
    }

    #[test]
    fn rejects_wrong_rhs_length() {
        let nb = backend(2, 64);
        let m = gen::chain(50, GenSeed(13));
        let plan = LevelSolver::new(&m);
        assert!(nb.solve(&plan, &vec![0f32; 49]).is_err());
        assert!(nb.solve_multi(&plan, &[vec![0f32; 51]]).is_err());
    }

    #[test]
    fn empty_batch_is_empty() {
        let nb = backend(2, 64);
        let m = gen::chain(10, GenSeed(14));
        let plan = LevelSolver::new(&m);
        assert!(nb.solve_multi(&plan, &[]).unwrap().is_empty());
    }

    #[test]
    fn concurrent_solves_share_the_pool() {
        let nb = Arc::new(backend(4, 16));
        let m = gen::circuit(500, 5, 0.8, GenSeed(15));
        let plan = Arc::new(LevelSolver::new(&m));
        let mut handles = Vec::new();
        for t in 0..4 {
            let nb = Arc::clone(&nb);
            let plan = Arc::clone(&plan);
            let b: Vec<f32> = (0..m.n).map(|i| ((i + t) % 7) as f32 - 3.0).collect();
            handles.push(std::thread::spawn(move || {
                let x = nb.solve(&plan, &b).unwrap();
                (b, x)
            }));
        }
        for h in handles {
            let (b, x) = h.join().unwrap();
            assert_close_to_reference(&m, &b, &x, 1e-3);
        }
    }
}
