//! Native pure-Rust solver backend with two schedulers over the shared
//! plans: the **level** scheduler (the simple/reference path — a
//! `std::thread` worker pool with one barrier per level set) and the
//! **mgd** scheduler (barrier-free medium-granularity node scheduling,
//! [`mgd_exec`](super::mgd_exec), running on the backend's persistent
//! [`MgdPool`] — workers spawn once, park between solves, and are shared
//! across every solve and matrix this backend serves).
//! [`SchedulerKind::Auto`] picks per plan by comparing modeled execution
//! costs ([`recommend_scheduler`] — the same cost model the coordinator's
//! `MatrixCost` exposes): deep/narrow DAGs — where barriers serialize
//! everything — go to `mgd`, wide/shallow ones to `level`.
//!
//! The level scheduler mirrors the structure of the PJRT level kernels so
//! both backends share the plan layout and the numeric contract:
//!
//! - rows within a level are independent; a level is cut into chunks
//!   sized adaptively from its width and the worker count (never below
//!   [`NativeConfig::chunk_rows`], never more than `2 × threads` chunks),
//!   assigned round-robin so thread engagement stays deterministic;
//!   levels that fit one chunk run inline on the calling thread;
//! - each row gathers its `(cols, vals)` slices once and reuses the gather
//!   across every RHS of a multi-RHS batch;
//! - the first [`NativeConfig::edge_budget`] edges of a row take the
//!   budgeted MAC path and the overflow edges fold into a serial carry on
//!   `b`, exactly like the kernel dispatch in
//!   [`level_exec`](super::level_exec) — heavy hub rows therefore exercise
//!   the same carry code path on both backends.
//!
//! `x` is shared across threads as `f32` bits in `AtomicU32` slots with
//! relaxed ordering; the happens-before edges come from the scheduler
//! (the level barrier here, the dependency counters in `mgd_exec`) — see
//! `runtime/atomics.md` for the full protocol.

use super::backend::SolverBackend;
use super::level_exec::{LevelPlan, LevelSolver};
use super::mgd_exec;
use super::mgd_plan::MgdPlanConfig;
use super::pool::{MgdPool, MgdPoolStats, RequestClass};
use super::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use super::sync::{mpsc, Arc};
use crate::matrix::CsrMatrix;
use anyhow::{anyhow, bail, ensure, Result};
use std::str::FromStr;
use std::time::Duration;

/// Which native scheduler executes the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Pick per plan by level-width statistics (deep/narrow → `Mgd`,
    /// wide/shallow → `Level`).
    Auto,
    /// One barrier per level set (the simple/reference scheduler).
    Level,
    /// Barrier-free medium-granularity node scheduling with work stealing.
    Mgd,
    /// The `mgd` scheduler with node bodies lowered to statically
    /// verified, index-baked bytecode run unchecked
    /// ([`runtime::kir`](super::kir)). Opt-in (`Auto` never resolves to
    /// it); falls back to `Mgd` per matrix when the verifier rejects the
    /// lowered program.
    Kir,
}

impl FromStr for SchedulerKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "level" => Ok(Self::Level),
            "mgd" => Ok(Self::Mgd),
            "kir" => Ok(Self::Kir),
            other => bail!("unknown scheduler {other:?} (expected level|mgd|kir|auto)"),
        }
    }
}

impl std::fmt::Display for SchedulerKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::Level => "level",
            Self::Mgd => "mgd",
            Self::Kir => "kir",
        })
    }
}

/// Tuning knobs for the native executor.
#[derive(Debug, Clone, Copy)]
pub struct NativeConfig {
    /// Worker threads; `0` = one per available CPU (the full
    /// `available_parallelism`, overridable via the `MGD_NATIVE_THREADS`
    /// environment variable). An explicit non-zero value always wins.
    pub threads: usize,
    /// Minimum rows per parallel work item of the level scheduler; the
    /// effective chunk grows with level width so one level never
    /// dispatches more than `2 × threads` chunks. Levels that fit one
    /// chunk run inline.
    pub chunk_rows: usize,
    /// Edges per row on the budgeted MAC path of the level scheduler;
    /// overflow edges take the serial carry (mirrors the compiled
    /// kernels' edge budget).
    pub edge_budget: usize,
    /// Scheduler choice (`auto` resolves per plan).
    pub scheduler: SchedulerKind,
    /// Persistent-pool workers reserved for [`RequestClass::Latency`]
    /// sessions (clamped to the pool size, i.e. `threads - 1`). Bulk
    /// solves lease at most the unreserved remainder, so a bulk flood
    /// can never lease the pool dry. `0` (the default) reserves nothing.
    /// Only the mgd scheduler's pool has lease lanes; the level
    /// scheduler ignores the class.
    pub reserved_latency_workers: usize,
}

impl Default for NativeConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            chunk_rows: 128,
            edge_budget: 32,
            scheduler: SchedulerKind::Auto,
            reserved_latency_workers: 0,
        }
    }
}

/// Resolve the worker-thread count: explicit config wins, then the
/// `MGD_NATIVE_THREADS` environment override, then the machine's full
/// `available_parallelism` (the former hard cap of 8 is gone).
fn resolve_threads(configured: usize) -> usize {
    resolve_threads_from(
        configured,
        std::env::var("MGD_NATIVE_THREADS").ok().as_deref(),
    )
}

/// [`resolve_threads`] with the environment override injected (testable
/// without mutating process-global env, which races with concurrent
/// `env::var` readers).
fn resolve_threads_from(configured: usize, env_override: Option<&str>) -> usize {
    if configured > 0 {
        return configured;
    }
    if let Some(s) = env_override {
        if let Ok(v) = s.trim().parse::<usize>() {
            if v > 0 {
                return v;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
}

/// Modeled cost of one level barrier, in row-execution units (condvar
/// broadcast + cache-line ping-pong of the rendezvous — amortized, a
/// barrier costs roughly as much as a handful of row solves).
const LEVEL_BARRIER_COST: u64 = 4;

/// Cost-model scheduler recommendation shared by [`NativeBackend`]'s
/// `Auto` resolution and the coordinator's per-matrix cost model
/// (`coordinator::cost::MatrixCost`). Compares, in row-execution units:
///
/// - **barriered level cost** — each level runs `ceil(width/threads)`
///   chunk waves and then pays one barrier ([`LEVEL_BARRIER_COST`]);
/// - **barrier-free mgd cost** — the total work spread over the workers,
///   floored by the critical path (the level count), plus ~25% node
///   scheduling overhead (readiness counters, deque traffic).
///
/// Deep/narrow DAGs are barrier-dominated and go `Mgd`; wide/shallow
/// ones amortize their few barriers and go `Level`. Ties go `Mgd` (the
/// paper's path).
pub fn recommend_scheduler<I>(level_widths: I, threads: usize) -> SchedulerKind
where
    I: IntoIterator<Item = usize>,
{
    let t = threads.max(1) as u64;
    let (mut rows, mut depth, mut waves) = (0u64, 0u64, 0u64);
    for w in level_widths {
        rows += w as u64;
        depth += 1;
        waves += (w as u64).div_ceil(t);
    }
    let level_cost = waves + LEVEL_BARRIER_COST * depth;
    let mgd_cost = rows.div_ceil(t).max(depth) * 5 / 4;
    if mgd_cost <= level_cost {
        SchedulerKind::Mgd
    } else {
        SchedulerKind::Level
    }
}

/// Node-budget recommendation from the parallelism profile: starts from
/// [`MgdPlanConfig::auto`]'s average-width sizing and additionally caps
/// the row budget so the *widest* level can split across every worker —
/// a DAG with one fat level and a narrow tail no longer ends up with a
/// handful of oversized nodes starving the pool. Node sizing is a
/// performance knob only; every budget yields bitwise-identical
/// solutions (see [`MgdPlan`](super::mgd_plan::MgdPlan)).
pub fn recommend_mgd_budget<I>(n: usize, level_widths: I, threads: usize) -> MgdPlanConfig
where
    I: IntoIterator<Item = usize>,
{
    let (mut depth, mut max_width) = (0usize, 0usize);
    for w in level_widths {
        depth += 1;
        max_width = max_width.max(w);
    }
    let base = MgdPlanConfig::auto(n, depth, threads);
    if max_width <= 2 {
        // Serial-ish DAG: no row parallelism to preserve — keep the
        // large amortization cap.
        return base;
    }
    let split = (max_width / threads.max(1)).max(8);
    MgdPlanConfig {
        max_node_rows: base.max_node_rows.min(split),
        max_node_edges: base.max_node_edges,
    }
}

/// Effective rows-per-chunk for one level: at least the configured
/// minimum, and large enough that the level yields at most `2 × threads`
/// chunks — enough slack for load balance, no pathological 1-row chunks
/// on narrow levels.
fn adaptive_chunk(level_width: usize, min_chunk: usize, threads: usize) -> usize {
    min_chunk
        .max(level_width.div_ceil(2 * threads.max(1)))
        .max(1)
}

/// Execution counters recorded by the native backend.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NativeStats {
    /// Levels executed across the worker pool (≥ 2 chunks dispatched).
    pub parallel_levels: u64,
    /// Total parallel chunks dispatched.
    pub chunks_dispatched: u64,
    /// Worker threads that have executed at least one chunk.
    pub workers_engaged: usize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads, each with its own queue; jobs are
/// assigned round-robin so that dispatching `k ≥ 2` chunks engages
/// `min(k, threads)` distinct workers deterministically.
struct WorkerPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    next: AtomicUsize,
    jobs_run: Arc<Vec<AtomicU64>>,
}

impl WorkerPool {
    fn new(threads: usize) -> Self {
        let jobs_run: Arc<Vec<AtomicU64>> =
            Arc::new((0..threads).map(|_| AtomicU64::new(0)).collect());
        let mut senders = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let (tx, rx) = mpsc::channel::<Job>();
            let counts = Arc::clone(&jobs_run);
            let handle = std::thread::Builder::new()
                .name(format!("mgd-native-{w}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // Count before running so the ack a job sends on
                        // completion happens-after the increment.
                        // relaxed: telemetry counter; the channel orders it.
                        counts[w].fetch_add(1, Ordering::Relaxed);
                        job();
                    }
                })
                .expect("spawn native worker thread");
            senders.push(tx);
            handles.push(handle);
        }
        Self {
            senders,
            handles,
            next: AtomicUsize::new(0),
            jobs_run,
        }
    }

    fn spawn(&self, job: Job) -> Result<()> {
        // relaxed: round-robin cursor, no data published under it.
        let w = self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.senders[w]
            .send(job)
            .map_err(|_| anyhow!("native worker {w} is gone (pool shut down?)"))
    }

    fn workers_engaged(&self) -> usize {
        // relaxed: telemetry read (see runtime/atomics.md).
        self.jobs_run
            .iter()
            .filter(|c| c.load(Ordering::Relaxed) > 0)
            .count()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes every queue; workers exit their loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Counters of the barrier-free `mgd` scheduler since construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MgdStats {
    /// Solves executed through the MGD scheduler.
    pub solves: u64,
    /// Medium nodes executed in total.
    pub nodes_executed: u64,
    /// Nodes obtained by work stealing.
    pub steals: u64,
}

/// Counters of the verified kernel-IR (`kir`) tier since construction.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KirStats {
    /// Solves executed through the verified unchecked interpreter.
    pub solves: u64,
    /// Solves routed to `kir` that fell back to the checked `mgd` tier
    /// because the matrix's lowered program failed verification.
    pub fallbacks: u64,
}

/// The native solver backend (level or mgd scheduler).
pub struct NativeBackend {
    threads: usize,
    chunk_rows: usize,
    edge_budget: usize,
    scheduler: SchedulerKind,
    /// Pool workers reserved for latency-class sessions (pre-clamped to
    /// the pool size at construction).
    reserved_latency_workers: usize,
    /// Level-scheduler worker pool, spawned lazily on the first level
    /// whose width actually needs it — a backend whose solves all resolve
    /// to `mgd` never parks a level pool.
    pool: std::sync::OnceLock<WorkerPool>,
    /// Persistent barrier-free worker pool ([`MgdPool`]), spawned lazily
    /// on the first mgd solve that can use more than one worker and
    /// reused for the backend's lifetime — across solves, and (under the
    /// sharded service) across matrices. Concurrent solves run as
    /// overlapping pool sessions, each leasing at most its plan's
    /// `par_width` workers. The former per-solve `thread::scope` spawn
    /// is gone from the serve path.
    mgd_pool: std::sync::OnceLock<MgdPool>,
    parallel_levels: AtomicU64,
    chunks_dispatched: AtomicU64,
    mgd_solves: AtomicU64,
    mgd_nodes: AtomicU64,
    mgd_steals: AtomicU64,
    kir_solves: AtomicU64,
    kir_fallbacks: AtomicU64,
}

impl NativeBackend {
    /// Build the backend (cheap: worker pools are spawned on demand).
    pub fn new(cfg: NativeConfig) -> Self {
        let threads = resolve_threads(cfg.threads);
        let chunk_rows = cfg.chunk_rows.max(1);
        Self {
            threads,
            chunk_rows,
            edge_budget: cfg.edge_budget.max(1),
            scheduler: cfg.scheduler,
            reserved_latency_workers: cfg
                .reserved_latency_workers
                .min(threads.saturating_sub(1)),
            pool: std::sync::OnceLock::new(),
            mgd_pool: std::sync::OnceLock::new(),
            parallel_levels: AtomicU64::new(0),
            chunks_dispatched: AtomicU64::new(0),
            mgd_solves: AtomicU64::new(0),
            mgd_nodes: AtomicU64::new(0),
            mgd_steals: AtomicU64::new(0),
            kir_solves: AtomicU64::new(0),
            kir_fallbacks: AtomicU64::new(0),
        }
    }

    /// The level scheduler's pool: `None` in single-thread configs, else
    /// spawned on first use and reused for the backend's lifetime.
    fn level_pool(&self) -> Option<&WorkerPool> {
        (self.threads > 1).then(|| self.pool.get_or_init(|| WorkerPool::new(self.threads)))
    }

    /// The persistent mgd pool: `None` in single-thread configs, else
    /// spawned on first use (with `threads - 1` parked workers — the
    /// solving thread itself is always worker 0, and the configured
    /// latency reserve carved out of them) and reused for the backend's
    /// lifetime.
    fn mgd_worker_pool(&self) -> Option<&MgdPool> {
        (self.threads > 1).then(|| {
            self.mgd_pool.get_or_init(|| {
                MgdPool::new_with_reserved(self.threads - 1, self.reserved_latency_workers)
            })
        })
    }

    /// Introspection of the persistent mgd pool: worker/live-thread
    /// counts, sessions served, and the session concurrency high-water
    /// mark (`peak_concurrency >= 2` proves two solves really overlapped
    /// in this pool). All-zero until the first multi-worker mgd solve
    /// spawns the pool (and always in single-thread configs). Service
    /// lifecycle tests use this to assert that repeated start/shutdown
    /// cycles reuse the pool instead of leaking threads.
    pub fn mgd_pool_stats(&self) -> MgdPoolStats {
        self.mgd_pool.get().map_or(MgdPoolStats::default(), MgdPool::stats)
    }

    /// Worker threads backing this instance.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The configured scheduler (possibly `Auto`).
    pub fn scheduler(&self) -> SchedulerKind {
        self.scheduler
    }

    /// The scheduler `Auto` resolves to for `plan`: the cost-model
    /// comparison of [`recommend_scheduler`] — barrier-free `mgd` when
    /// the modeled barrier cost dominates (deep/narrow DAGs), the
    /// `level` path when the DAG is wide enough to amortize its few
    /// barriers.
    pub fn resolve_scheduler(&self, plan: &LevelSolver) -> SchedulerKind {
        match self.scheduler {
            SchedulerKind::Auto => recommend_scheduler(
                plan.plans().iter().map(|p| p.rows.len()),
                self.threads,
            ),
            pinned => pinned,
        }
    }

    /// The node budget the mgd path builds its cached plan with: the
    /// parallelism-profile sizing of [`recommend_mgd_budget`].
    fn mgd_budget(&self, plan: &LevelSolver) -> MgdPlanConfig {
        recommend_mgd_budget(
            plan.n(),
            plan.plans().iter().map(|p| p.rows.len()),
            self.threads,
        )
    }

    /// Level-scheduler execution counters since construction.
    pub fn stats(&self) -> NativeStats {
        NativeStats {
            // relaxed: monotonic telemetry counters (runtime/atomics.md).
            parallel_levels: self.parallel_levels.load(Ordering::Relaxed),
            chunks_dispatched: self.chunks_dispatched.load(Ordering::Relaxed),
            workers_engaged: self.pool.get().map_or(0, WorkerPool::workers_engaged),
        }
    }

    /// MGD-scheduler execution counters since construction.
    pub fn mgd_stats(&self) -> MgdStats {
        MgdStats {
            // relaxed: monotonic telemetry counters (runtime/atomics.md).
            solves: self.mgd_solves.load(Ordering::Relaxed),
            nodes_executed: self.mgd_nodes.load(Ordering::Relaxed),
            steals: self.mgd_steals.load(Ordering::Relaxed),
        }
    }

    /// Kernel-IR tier counters since construction: verified-interpreter
    /// solves and per-solve fallbacks onto the checked `mgd` tier.
    pub fn kir_stats(&self) -> KirStats {
        KirStats {
            // relaxed: monotonic telemetry counters (runtime/atomics.md).
            solves: self.kir_solves.load(Ordering::Relaxed),
            fallbacks: self.kir_fallbacks.load(Ordering::Relaxed),
        }
    }

    /// Barrier-free path: execute the plan's cached
    /// [`MgdPlan`](super::mgd_plan::MgdPlan) (built on first use, sized by
    /// [`MgdPlanConfig::auto`]) through [`mgd_exec::execute_on_class`] on
    /// the backend's persistent [`MgdPool`] — workers are parked between
    /// solves, never respawned, and the session leases workers according
    /// to `class` (latency sessions may claim the reserved lane). Borrows
    /// the RHS views — no staging copy on this path.
    fn execute_mgd<B: AsRef<[f32]> + Sync>(
        &self,
        plan: &LevelSolver,
        bs: &[B],
        class: RequestClass,
    ) -> Result<Vec<Vec<f32>>> {
        let mgd = plan.mgd_plan(self.mgd_budget(plan));
        // Serial plans (par_width 1, e.g. pure chains) never touch — and
        // never lazily spawn — the pool; they run inline on this thread.
        let pool = (mgd.par_width > 1).then(|| self.mgd_worker_pool()).flatten();
        let (xs, stats) = match pool {
            Some(pool) => mgd_exec::execute_on_class(&mgd, bs, pool, self.threads, class)?,
            None => mgd_exec::execute(&mgd, bs, 1)?,
        };
        // relaxed: monotonic telemetry counters, read only by mgd_stats.
        self.mgd_solves.fetch_add(1, Ordering::Relaxed);
        self.mgd_nodes.fetch_add(stats.nodes_executed, Ordering::Relaxed);
        self.mgd_steals.fetch_add(stats.steals, Ordering::Relaxed);
        Ok(xs)
    }

    /// Verified kernel-IR path: the same barrier-free scheduling as
    /// [`Self::execute_mgd`], with each node's inner loop executed as the
    /// plan's cached, verifier-accepted bytecode
    /// ([`LevelSolver::kir_kernel`] lowers + verifies once per matrix,
    /// off the hot path). A matrix whose lowered program failed
    /// verification is served on the checked `mgd` tier instead — the
    /// unchecked interpreter runs verified programs or not at all — with
    /// the fallback recorded in [`KirStats`].
    fn execute_kir<B: AsRef<[f32]> + Sync>(
        &self,
        plan: &LevelSolver,
        bs: &[B],
        class: RequestClass,
    ) -> Result<Vec<Vec<f32>>> {
        let Some(kernel) = plan.kir_kernel(self.mgd_budget(plan)) else {
            // relaxed: monotonic telemetry counter, read only by kir_stats.
            self.kir_fallbacks.fetch_add(1, Ordering::Relaxed);
            return self.execute_mgd(plan, bs, class);
        };
        // Same pool policy as the mgd path: serial plans never spawn it.
        let pool = (kernel.plan().par_width > 1)
            .then(|| self.mgd_worker_pool())
            .flatten();
        let (xs, _stats) = match pool {
            Some(pool) => {
                mgd_exec::execute_kernel_on_class(&kernel, bs, pool, self.threads, class)?
            }
            None => mgd_exec::execute_kernel(&kernel, bs, 1)?,
        };
        // relaxed: monotonic telemetry counter, read only by kir_stats.
        self.kir_solves.fetch_add(1, Ordering::Relaxed);
        Ok(xs)
    }

    /// Level-scheduler execution, scalar (`r = 1`) or batched. Takes the
    /// batch by value so each solve pays exactly one staging copy (into
    /// the shared `Arc`), never two; the mgd path never comes through
    /// here — `solve`/`solve_multi` dispatch before staging.
    fn execute(&self, plan: &LevelSolver, bs: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let matrix = plan.matrix_arc();
        let plans = plan.plans_arc();
        let n = matrix.n;
        let r = bs.len();
        if r == 0 {
            return Ok(Vec::new());
        }
        for b in &bs {
            ensure!(b.len() == n, "rhs length {} != matrix order {n}", b.len());
        }
        // x as f32 bits: one flat (r, n) array of atomics shared by workers.
        let x: Arc<Vec<AtomicU32>> = Arc::new(
            std::iter::repeat_with(|| AtomicU32::new(0))
                .take(r * n)
                .collect(),
        );
        let bs_shared: Arc<Vec<Vec<f32>>> = Arc::new(bs);
        let (done_tx, done_rx) = mpsc::channel::<bool>();
        for li in 0..plans.len() {
            let rows_len = plans[li].rows.len();
            let chunk = adaptive_chunk(rows_len, self.chunk_rows, self.threads);
            let nchunks = rows_len.div_ceil(chunk);
            // Only levels that actually split reach for the pool, so the
            // lazy spawn happens on the first genuinely parallel level.
            let pool = match (nchunks >= 2).then(|| self.level_pool()).flatten() {
                Some(pool) => pool,
                None => {
                    run_chunk(
                        &matrix,
                        &plans[li],
                        0,
                        rows_len,
                        &bs_shared,
                        &x,
                        self.edge_budget,
                    );
                    continue;
                }
            };
            for c in 0..nchunks {
                let lo = c * chunk;
                let hi = (lo + chunk).min(rows_len);
                let matrix = Arc::clone(&matrix);
                let plans = Arc::clone(&plans);
                let bs_shared = Arc::clone(&bs_shared);
                let x = Arc::clone(&x);
                let done_tx = done_tx.clone();
                let edge_budget = self.edge_budget;
                pool.spawn(Box::new(move || {
                    // Catch panics so a bad chunk job cannot kill its
                    // worker thread or starve the level barrier; the
                    // failure ack turns it into a loud per-solve error.
                    let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_chunk(&matrix, &plans[li], lo, hi, &bs_shared, &x, edge_budget);
                    }))
                    .is_ok();
                    let _ = done_tx.send(ok);
                }))?;
            }
            // Level barrier: dependent rows only exist in later levels.
            let mut panicked = false;
            for _ in 0..nchunks {
                panicked |= !done_rx
                    .recv_timeout(Duration::from_secs(300))
                    .map_err(|_| anyhow!("native worker pool stalled in level {li}"))?;
            }
            ensure!(!panicked, "native chunk job panicked in level {li}");
            // relaxed: monotonic telemetry counters, read only by stats.
            self.parallel_levels.fetch_add(1, Ordering::Relaxed);
            self.chunks_dispatched
                .fetch_add(nchunks as u64, Ordering::Relaxed);
        }
        // relaxed: every writer's ack was collected through the channel
        // above, which is the happens-before edge (runtime/atomics.md).
        Ok((0..r)
            .map(|k| {
                (0..n)
                    .map(|i| f32::from_bits(x[k * n + i].load(Ordering::Relaxed)))
                    .collect()
            })
            .collect())
    }
}

/// Solve one chunk of a level's rows for every RHS. The `(cols, vals)`
/// gather is done once per row and reused across the batch; edges beyond
/// `edge_budget` fold into the serial carry like the PJRT kernel path.
fn run_chunk(
    m: &CsrMatrix,
    plan: &LevelPlan,
    lo: usize,
    hi: usize,
    bs: &[Vec<f32>],
    x: &[AtomicU32],
    edge_budget: usize,
) {
    let n = m.n;
    for &row in &plan.rows[lo..hi] {
        let i = row as usize;
        let (cols, vals) = m.row_off_diag(i);
        let fit = cols.len().min(edge_budget);
        let dinv = 1.0 / m.diag(i);
        for (k, b) in bs.iter().enumerate() {
            let xk = &x[k * n..(k + 1) * n];
            let mut acc = 0f32;
            // relaxed: operand rows live in earlier levels; the level
            // barrier (channel ack + recv) is the happens-before edge.
            for e in 0..fit {
                acc += vals[e] * f32::from_bits(xk[cols[e] as usize].load(Ordering::Relaxed));
            }
            let mut carry = 0f32;
            // relaxed: same level-barrier edge as the budgeted loop.
            for e in fit..cols.len() {
                carry += vals[e] * f32::from_bits(xk[cols[e] as usize].load(Ordering::Relaxed));
            }
            let xi = ((b[i] - carry) - acc) * dinv;
            // relaxed: published to dependents by the level barrier.
            xk[i].store(xi.to_bits(), Ordering::Relaxed);
        }
    }
}

impl SolverBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_multi_rhs(&self) -> bool {
        true
    }

    fn pool_stats(&self) -> Option<MgdPoolStats> {
        Some(self.mgd_pool_stats())
    }

    fn prepare(&self, plan: &LevelSolver) -> Result<()> {
        // Registration-time warmup: build (and cache) the mgd plan and
        // spawn the persistent pool now, so the first request pays
        // neither the preprocessing nor the thread-spawn cost. Serial
        // plans (par_width 1) skip the pool spawn — solves of such a
        // matrix never engage it (see `execute_mgd`). The kir tier
        // additionally lowers + verifies the kernel here, so the
        // verification verdict (and any fallback) is settled before the
        // first request.
        match self.resolve_scheduler(plan) {
            SchedulerKind::Mgd => {
                let mgd = plan.mgd_plan(self.mgd_budget(plan));
                if mgd.par_width > 1 {
                    let _ = self.mgd_worker_pool();
                }
            }
            SchedulerKind::Kir => {
                let par_width = match plan.kir_kernel(self.mgd_budget(plan)) {
                    Some(kernel) => kernel.plan().par_width,
                    None => plan.mgd_plan(self.mgd_budget(plan)).par_width,
                };
                if par_width > 1 {
                    let _ = self.mgd_worker_pool();
                }
            }
            SchedulerKind::Level | SchedulerKind::Auto => {}
        }
        Ok(())
    }

    fn chosen_scheduler(&self, plan: &LevelSolver) -> Option<SchedulerKind> {
        let chosen = self.resolve_scheduler(plan);
        // A kir matrix whose lowered program failed verification is
        // actually served on the checked mgd tier (see `execute_kir`);
        // report the tier that runs, not the one that was asked for.
        if chosen == SchedulerKind::Kir && plan.kir_kernel(self.mgd_budget(plan)).is_none() {
            return Some(SchedulerKind::Mgd);
        }
        Some(chosen)
    }

    fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
        self.solve_class(plan, b, RequestClass::Bulk)
    }

    fn solve_multi(&self, plan: &LevelSolver, bs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.solve_multi_class(plan, bs, RequestClass::Bulk)
    }

    fn solve_class(&self, plan: &LevelSolver, b: &[f32], class: RequestClass) -> Result<Vec<f32>> {
        // Dispatch before staging: the barrier-free path borrows the RHS
        // (and validates it itself), skipping the copy the level path
        // needs for its shared-ownership staging. The class only matters
        // on the mgd path — the level scheduler's pool has no lease
        // lanes.
        let mut out = match self.resolve_scheduler(plan) {
            SchedulerKind::Mgd => self.execute_mgd(plan, &[b], class)?,
            SchedulerKind::Kir => self.execute_kir(plan, &[b], class)?,
            _ => self.execute(plan, vec![b.to_vec()])?,
        };
        Ok(out.pop().expect("one RHS in, one solution out"))
    }

    fn solve_multi_class(
        &self,
        plan: &LevelSolver,
        bs: &[Vec<f32>],
        class: RequestClass,
    ) -> Result<Vec<Vec<f32>>> {
        match self.resolve_scheduler(plan) {
            SchedulerKind::Mgd => self.execute_mgd(plan, bs, class),
            SchedulerKind::Kir => self.execute_kir(plan, bs, class),
            _ => self.execute(plan, bs.to_vec()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;

    /// Level-scheduler backend (pinned, so these tests keep exercising
    /// the chunked barrier path regardless of what `Auto` would pick).
    fn backend(threads: usize, chunk_rows: usize) -> NativeBackend {
        NativeBackend::new(NativeConfig {
            threads,
            chunk_rows,
            scheduler: SchedulerKind::Level,
            ..NativeConfig::default()
        })
    }

    /// Property test: for every generator family — including `power_law`
    /// hub rows that exceed the edge budget and exercise the overflow
    /// carry — and for multi-RHS batch sizes {1, 3, 8, 11}, the native
    /// backend matches the serial reference to 1e-3.
    #[test]
    fn native_backend_matches_reference() {
        let cases = gen::test_suite();
        // Small chunks so even modest levels split across the pool.
        let nb = backend(4, 16);
        for (name, m) in &cases {
            assert!(
                m.max_in_degree() <= 120,
                "{name}: generator drifted beyond the test envelope"
            );
            let plan = LevelSolver::new(m);
            for count in [1usize, 3, 8, 11] {
                let bs: Vec<Vec<f32>> = (0..count)
                    .map(|k| (0..m.n).map(|i| ((i + 3 * k) % 9) as f32 - 4.0).collect())
                    .collect();
                let xs = nb.solve_multi(&plan, &bs).unwrap();
                assert_eq!(xs.len(), count, "{name}: batch size {count}");
                for (b, x) in bs.iter().zip(&xs) {
                    assert_close_to_reference(m, b, x, 1e-3);
                }
                // Scalar path agrees with the batched path.
                let x0 = nb.solve(&plan, &bs[0]).unwrap();
                assert_close_to_reference(m, &bs[0], &x0, 1e-3);
            }
        }
        // power_law hubs (deg > 32) really did take the carry path.
        let hubs = &cases[6].1;
        assert!(hubs.max_in_degree() > NativeConfig::default().edge_budget);
    }

    #[test]
    fn wide_levels_engage_multiple_workers() {
        // shallow() has a handful of very wide levels; with chunk_rows = 8
        // every wide level dispatches many chunks round-robin across the
        // 4 workers, so ≥ 2 workers must each run at least one chunk.
        let nb = backend(4, 8);
        let m = gen::shallow(2000, 0.4, GenSeed(11));
        let plan = LevelSolver::new(&m);
        let widest = plan.plans().iter().map(|p| p.rows.len()).max().unwrap();
        assert!(widest > 8, "test premise: a level wider than one chunk");
        let b = vec![1.0f32; m.n];
        let x = nb.solve(&plan, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
        let stats = nb.stats();
        assert!(stats.parallel_levels >= 1, "{stats:?}");
        assert!(stats.chunks_dispatched >= 2, "{stats:?}");
        assert!(stats.workers_engaged >= 2, "{stats:?}");
    }

    #[test]
    fn single_thread_config_runs_inline() {
        let nb = backend(1, 8);
        let m = gen::circuit(400, 5, 0.8, GenSeed(12));
        let plan = LevelSolver::new(&m);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 11) as f32 - 5.0).collect();
        let x = nb.solve(&plan, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
        assert_eq!(nb.stats(), NativeStats::default());
    }

    #[test]
    fn rejects_wrong_rhs_length() {
        let nb = backend(2, 64);
        let m = gen::chain(50, GenSeed(13));
        let plan = LevelSolver::new(&m);
        assert!(nb.solve(&plan, &vec![0f32; 49]).is_err());
        assert!(nb.solve_multi(&plan, &[vec![0f32; 51]]).is_err());
    }

    #[test]
    fn empty_batch_is_empty() {
        let nb = backend(2, 64);
        let m = gen::chain(10, GenSeed(14));
        let plan = LevelSolver::new(&m);
        assert!(nb.solve_multi(&plan, &[]).unwrap().is_empty());
    }

    #[test]
    fn scheduler_kind_parses_and_displays() {
        assert_eq!("level".parse::<SchedulerKind>().unwrap(), SchedulerKind::Level);
        assert_eq!("mgd".parse::<SchedulerKind>().unwrap(), SchedulerKind::Mgd);
        assert_eq!("kir".parse::<SchedulerKind>().unwrap(), SchedulerKind::Kir);
        assert_eq!("auto".parse::<SchedulerKind>().unwrap(), SchedulerKind::Auto);
        assert!("coarse".parse::<SchedulerKind>().is_err());
        for k in [
            SchedulerKind::Auto,
            SchedulerKind::Level,
            SchedulerKind::Mgd,
            SchedulerKind::Kir,
        ] {
            assert_eq!(k.to_string().parse::<SchedulerKind>().unwrap(), k);
        }
    }

    #[test]
    fn auto_picks_mgd_on_narrow_and_level_on_wide() {
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            ..NativeConfig::default()
        });
        assert_eq!(nb.scheduler(), SchedulerKind::Auto);
        // A chain has average level width 1 — barrier-dominated.
        let chain = LevelSolver::new(&gen::chain(200, GenSeed(31)));
        assert_eq!(nb.resolve_scheduler(&chain), SchedulerKind::Mgd);
        // A shallow DAG has a few very wide levels — barriers are cheap.
        let shallow = LevelSolver::new(&gen::shallow(2000, 0.4, GenSeed(32)));
        assert_eq!(nb.resolve_scheduler(&shallow), SchedulerKind::Level);
        // Pinned schedulers resolve to themselves.
        for pin in [SchedulerKind::Level, SchedulerKind::Mgd] {
            let nb = NativeBackend::new(NativeConfig {
                threads: 4,
                scheduler: pin,
                ..NativeConfig::default()
            });
            assert_eq!(nb.resolve_scheduler(&chain), pin);
            assert_eq!(nb.resolve_scheduler(&shallow), pin);
        }
    }

    #[test]
    fn cost_model_recommendation_matches_dag_shape() {
        // Pure chain: every level width 1 — barrier cost dominates, the
        // barrier-free path wins by a wide margin.
        assert_eq!(
            recommend_scheduler(std::iter::repeat(1usize).take(200), 4),
            SchedulerKind::Mgd
        );
        // A few very wide levels amortize their barriers — level wins.
        assert_eq!(
            recommend_scheduler([500usize, 500, 500, 500], 4),
            SchedulerKind::Level
        );
        // Budget tuning: one fat level among narrow ones caps the row
        // budget so the fat level splits across every worker...
        let mut widths = vec![400usize];
        widths.extend(std::iter::repeat(36usize).take(100));
        let cfg = recommend_mgd_budget(4000, widths.iter().copied(), 4);
        assert_eq!(cfg.max_node_rows, 100);
        // ...while a serial chain keeps the large amortization cap.
        let chain = recommend_mgd_budget(200, std::iter::repeat(1usize).take(200), 4);
        assert_eq!(chain.max_node_rows, 128);
    }

    #[test]
    fn backend_reports_its_chosen_scheduler() {
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            ..NativeConfig::default()
        });
        let chain = LevelSolver::new(&gen::chain(200, GenSeed(31)));
        assert_eq!(nb.chosen_scheduler(&chain), Some(SchedulerKind::Mgd));
        let shallow = LevelSolver::new(&gen::shallow(2000, 0.4, GenSeed(32)));
        assert_eq!(nb.chosen_scheduler(&shallow), Some(SchedulerKind::Level));
    }

    #[test]
    fn mgd_scheduler_is_bitwise_serial_through_the_backend() {
        use crate::matrix::triangular::solve_serial;
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Mgd,
            ..NativeConfig::default()
        });
        let m = gen::circuit(700, 5, 0.8, GenSeed(33));
        let plan = LevelSolver::new(&m);
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|k| (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect())
            .collect();
        let xs = nb.solve_multi(&plan, &bs).unwrap();
        for (b, x) in bs.iter().zip(&xs) {
            let want = solve_serial(&m, b);
            for i in 0..m.n {
                assert_eq!(x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        let x0 = nb.solve(&plan, &bs[0]).unwrap();
        let want = solve_serial(&m, &bs[0]);
        for i in 0..m.n {
            assert_eq!(x0[i].to_bits(), want[i].to_bits(), "scalar row {i}");
        }
        let stats = nb.mgd_stats();
        assert_eq!(stats.solves, 2);
        assert!(stats.nodes_executed > 0, "{stats:?}");
        // The level-path counters stay untouched on the mgd path.
        assert_eq!(nb.stats(), NativeStats::default());
    }

    /// The `kir` tier through the full backend surface: verified at
    /// prepare time, bitwise-serial solves through the unchecked
    /// interpreter, solves counted in [`KirStats`], no fallback.
    #[test]
    fn kir_scheduler_is_bitwise_serial_through_the_backend() {
        use crate::matrix::triangular::solve_serial;
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Kir,
            ..NativeConfig::default()
        });
        let m = gen::circuit(700, 5, 0.8, GenSeed(33));
        let plan = LevelSolver::new(&m);
        nb.prepare(&plan).unwrap();
        assert_eq!(nb.chosen_scheduler(&plan), Some(SchedulerKind::Kir));
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|k| (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect())
            .collect();
        let xs = nb.solve_multi(&plan, &bs).unwrap();
        for (b, x) in bs.iter().zip(&xs) {
            let want = solve_serial(&m, b);
            for i in 0..m.n {
                assert_eq!(x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        let x0 = nb.solve(&plan, &bs[0]).unwrap();
        let want = solve_serial(&m, &bs[0]);
        for i in 0..m.n {
            assert_eq!(x0[i].to_bits(), want[i].to_bits(), "scalar row {i}");
        }
        let stats = nb.kir_stats();
        assert_eq!(stats.solves, 2, "{stats:?}");
        assert_eq!(stats.fallbacks, 0, "{stats:?}");
        // Neither the level- nor the mgd-path counters moved.
        assert_eq!(nb.stats(), NativeStats::default());
        assert_eq!(nb.mgd_stats().solves, 0);
    }

    /// A matrix whose kernel failed verification is served on the checked
    /// `mgd` tier: correct results, fallback recorded in [`KirStats`],
    /// and `chosen_scheduler` reports the tier that actually runs.
    #[test]
    fn kir_verification_failure_falls_back_to_mgd() {
        use crate::matrix::triangular::solve_serial;
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Kir,
            ..NativeConfig::default()
        });
        let m = gen::circuit(400, 5, 0.8, GenSeed(34));
        let plan = LevelSolver::new(&m);
        // Poison the per-matrix kernel cache with a verification failure.
        plan.fail_kir_for_tests();
        assert_eq!(nb.chosen_scheduler(&plan), Some(SchedulerKind::Mgd));
        let b: Vec<f32> = (0..m.n).map(|i| (i % 11) as f32 - 5.0).collect();
        let x = nb.solve(&plan, &b).unwrap();
        let want = solve_serial(&m, &b);
        for i in 0..m.n {
            assert_eq!(x[i].to_bits(), want[i].to_bits(), "row {i}");
        }
        let stats = nb.kir_stats();
        assert_eq!(stats.solves, 0, "{stats:?}");
        assert_eq!(stats.fallbacks, 1, "{stats:?}");
        // The fallback really ran the checked mgd tier.
        assert_eq!(nb.mgd_stats().solves, 1);
    }

    #[test]
    fn mgd_pool_is_persistent_across_solves() {
        use crate::matrix::triangular::solve_serial;
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Mgd,
            ..NativeConfig::default()
        });
        // No pool before the first solve (lazy spawn).
        assert_eq!(nb.mgd_pool_stats(), MgdPoolStats::default());
        // A wide shallow DAG with real node-level parallelism engages the
        // pool (contiguous clustering keeps chains/bands serial).
        let m = gen::shallow(1200, 0.4, GenSeed(44));
        let plan = LevelSolver::new(&m);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 7) as f32 - 3.0).collect();
        let want = solve_serial(&m, &b);
        for round in 0..10 {
            let x = nb.solve(&plan, &b).unwrap();
            for i in 0..m.n {
                assert_eq!(x[i].to_bits(), want[i].to_bits(), "round {round} row {i}");
            }
            let stats = nb.mgd_pool_stats();
            // The pool spawns exactly once and never grows per solve —
            // the whole point of replacing the per-solve thread::scope.
            assert_eq!(stats.workers, 3, "round {round}: {stats:?}");
            assert_eq!(stats.live, 3, "round {round}: {stats:?}");
        }
        assert!(nb.mgd_pool_stats().sessions >= 10);
    }

    #[test]
    fn prepare_warms_plan_and_pool_for_mgd_matrices() {
        let nb = NativeBackend::new(NativeConfig {
            threads: 2,
            scheduler: SchedulerKind::Mgd,
            ..NativeConfig::default()
        });
        // Serial plan: the cached MgdPlan is built, but no pool spawns —
        // a chain's solves can never engage it.
        let chain = LevelSolver::new(&gen::chain(200, GenSeed(45)));
        nb.prepare(&chain).unwrap();
        assert_eq!(nb.mgd_pool_stats(), MgdPoolStats::default());
        // Parallel plan: the pool exists before any request is served.
        let wide = LevelSolver::new(&gen::shallow(800, 0.4, GenSeed(46)));
        nb.prepare(&wide).unwrap();
        assert_eq!(nb.mgd_pool_stats().live, 1);
        // Level-pinned backends skip the warmup entirely.
        let level = backend(2, 64);
        level.prepare(&wide).unwrap();
        assert_eq!(level.mgd_pool_stats(), MgdPoolStats::default());
    }

    #[test]
    fn reserved_latency_workers_are_clamped_and_surfaced() {
        use crate::matrix::triangular::solve_serial;
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Mgd,
            // Deliberately over-asked: clamps to the pool size (3).
            reserved_latency_workers: 16,
            ..NativeConfig::default()
        });
        let m = gen::shallow(900, 0.4, GenSeed(47));
        let plan = LevelSolver::new(&m);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 7) as f32 - 3.0).collect();
        let want = solve_serial(&m, &b);
        // With the whole pool reserved, a bulk solve runs caller-only but
        // stays bitwise-correct...
        let x = nb.solve(&plan, &b).unwrap();
        for i in 0..m.n {
            assert_eq!(x[i].to_bits(), want[i].to_bits(), "bulk row {i}");
        }
        // ...and a latency solve may lease every worker.
        let x = nb.solve_class(&plan, &b, RequestClass::Latency).unwrap();
        for i in 0..m.n {
            assert_eq!(x[i].to_bits(), want[i].to_bits(), "latency row {i}");
        }
        let stats = nb.mgd_pool_stats();
        assert_eq!(stats.workers, 3);
        assert_eq!(stats.reserved, 3, "{stats:?}");
    }

    #[test]
    fn adaptive_chunk_bounds() {
        // Never below the configured minimum.
        assert_eq!(adaptive_chunk(10, 16, 4), 16);
        // Wide levels grow the chunk so at most 2×threads chunks exist.
        assert_eq!(adaptive_chunk(1000, 16, 4), 125);
        assert!(1000usize.div_ceil(adaptive_chunk(1000, 16, 4)) <= 8);
        // Degenerate inputs stay sane.
        assert_eq!(adaptive_chunk(0, 1, 0), 1);
        // A min_chunk of 1 no longer yields 1-row chunks on wide levels.
        assert!(adaptive_chunk(1000, 1, 8) >= 1000 / 16);
    }

    #[test]
    fn thread_resolution_prefers_explicit_then_env_then_cpus() {
        assert_eq!(resolve_threads_from(5, None), 5);
        // threads = 0 resolves to at least one worker with no 8-cap logic
        // left in the path (the exact count is machine-dependent).
        assert!(resolve_threads_from(0, None) >= 1);
        assert_eq!(resolve_threads_from(0, Some("3")), 3);
        assert_eq!(resolve_threads_from(2, Some("3")), 2); // explicit wins
        // Garbage and zero fall through to the CPU count.
        assert!(resolve_threads_from(0, Some("not-a-number")) >= 1);
        assert!(resolve_threads_from(0, Some("0")) >= 1);
    }

    /// Two mgd solves on **distinct matrices** issued from two threads
    /// must be able to overlap as concurrent sessions of the backend's
    /// one persistent pool. Overlap is timing-dependent per round, so a
    /// start barrier plus bounded retries makes the observation robust;
    /// a pool that serializes sessions can never raise the peak above 1.
    #[test]
    fn concurrent_mgd_solves_overlap_in_one_pool() {
        use crate::matrix::triangular::solve_serial;
        use crate::runtime::sync::Barrier;
        let nb = Arc::new(NativeBackend::new(NativeConfig {
            threads: 4,
            scheduler: SchedulerKind::Mgd,
            ..NativeConfig::default()
        }));
        let ma = gen::shallow(3000, 0.4, GenSeed(51));
        let mb = gen::shallow(2600, 0.5, GenSeed(52));
        let pa = Arc::new(LevelSolver::new(&ma));
        let pb = Arc::new(LevelSolver::new(&mb));
        let b_a: Vec<f32> = (0..ma.n).map(|i| (i % 7) as f32 - 3.0).collect();
        let b_b: Vec<f32> = (0..mb.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let want_a = solve_serial(&ma, &b_a);
        let want_b = solve_serial(&mb, &b_b);
        for _round in 0..50 {
            let barrier = Arc::new(Barrier::new(2));
            let handles: Vec<_> = [(Arc::clone(&pa), b_a.clone()), (Arc::clone(&pb), b_b.clone())]
                .into_iter()
                .map(|(plan, b)| {
                    let nb = Arc::clone(&nb);
                    let barrier = Arc::clone(&barrier);
                    std::thread::spawn(move || {
                        barrier.wait();
                        nb.solve(&plan, &b).unwrap()
                    })
                })
                .collect();
            let xs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            for (x, want) in xs.iter().zip([&want_a, &want_b]) {
                for i in 0..want.len() {
                    assert_eq!(x[i].to_bits(), want[i].to_bits(), "row {i}");
                }
            }
            if nb.mgd_pool_stats().peak_concurrency >= 2 {
                break;
            }
        }
        let stats = nb.mgd_pool_stats();
        assert!(
            stats.peak_concurrency >= 2,
            "no overlap in 50 paired solves: {stats:?}"
        );
        assert_eq!(stats.workers, 3, "one shared pool, never respawned");
    }

    #[test]
    fn concurrent_solves_share_the_pool() {
        let nb = Arc::new(backend(4, 16));
        let m = gen::circuit(500, 5, 0.8, GenSeed(15));
        let plan = Arc::new(LevelSolver::new(&m));
        let mut handles = Vec::new();
        for t in 0..4 {
            let nb = Arc::clone(&nb);
            let plan = Arc::clone(&plan);
            let b: Vec<f32> = (0..m.n).map(|i| ((i + t) % 7) as f32 - 3.0).collect();
            handles.push(std::thread::spawn(move || {
                let x = nb.solve(&plan, &b).unwrap();
                (b, x)
            }));
        }
        for h in handles {
            let (b, x) = h.join().unwrap();
            assert_close_to_reference(&m, &b, &x, 1e-3);
        }
    }
}
