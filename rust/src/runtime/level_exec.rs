//! Level-wise numeric solver on the PJRT request path.
//!
//! Preprocesses a matrix once (levels + padded gather plans, the runtime
//! counterpart of the python `model.plan_levels`) and then solves any RHS
//! by dispatching one compiled kernel invocation per level chunk. Rows
//! whose in-degree exceeds the variant's edge budget fold the overflow
//! into a serial carry, exactly like the L2 python mirror.

use super::client::PjrtRuntime;
use crate::graph::{Dag, Levels};
use crate::matrix::CsrMatrix;
use anyhow::Result;

/// Per-level execution plan.
struct LevelPlan {
    rows: Vec<u32>,
    max_deg: usize,
}

/// A matrix prepared for repeated PJRT solves.
pub struct LevelSolver {
    matrix: CsrMatrix,
    plans: Vec<LevelPlan>,
}

impl LevelSolver {
    /// Preprocess `m` (amortized across solves, like the paper's compiler).
    pub fn new(m: &CsrMatrix) -> Self {
        let g = Dag::from_csr(m);
        let lv = Levels::compute(&g);
        let plans = (0..lv.num_levels())
            .map(|l| {
                let rows = lv.level(l).to_vec();
                let max_deg = rows
                    .iter()
                    .map(|&i| m.in_degree(i as usize))
                    .max()
                    .unwrap_or(0);
                LevelPlan { rows, max_deg }
            })
            .collect();
        Self {
            matrix: m.clone(),
            plans,
        }
    }

    /// Number of levels (kernel dispatch chains per solve).
    pub fn num_levels(&self) -> usize {
        self.plans.len()
    }

    /// Solve `L x = b` through the PJRT kernels.
    pub fn solve(&self, rt: &PjrtRuntime, b: &[f32]) -> Result<Vec<f32>> {
        let m = &self.matrix;
        assert_eq!(b.len(), m.n);
        let mut x = vec![0f32; m.n];
        // Reusable padded tiles (sized per selected variant below).
        for plan in &self.plans {
            let variant = rt.select(plan.rows.len(), plan.max_deg);
            let (bsz, esz) = (variant.batch, variant.edges);
            for chunk in plan.rows.chunks(bsz) {
                let mut vals = vec![0f32; bsz * esz];
                let mut xg = vec![0f32; bsz * esz];
                let mut bb = vec![0f32; bsz];
                let mut dinv = vec![1f32; bsz];
                for (r, &i) in chunk.iter().enumerate() {
                    let i = i as usize;
                    let (cols, vs) = m.row_off_diag(i);
                    let k = cols.len();
                    let fit = k.min(esz);
                    for e in 0..fit {
                        vals[r * esz + e] = vs[e];
                        xg[r * esz + e] = x[cols[e] as usize];
                    }
                    // Overflow edges fold into a serial carry on the host.
                    let mut carry = 0f32;
                    for e in fit..k {
                        carry += vs[e] * x[cols[e] as usize];
                    }
                    bb[r] = b[i] - carry;
                    dinv[r] = 1.0 / m.diag(i);
                }
                let out = rt.execute_level(variant, &vals, &xg, &bb, &dinv)?;
                for (r, &i) in chunk.iter().enumerate() {
                    x[i as usize] = out[r];
                }
            }
        }
        Ok(x)
    }
}

impl LevelSolver {
    /// Solve a batch of RHS in one pass, using the multi-RHS kernel when a
    /// variant matches the batch (padding smaller batches with zeros) and
    /// falling back to scalar solves otherwise. Dispatch and the shared
    /// `vals` staging are amortized across the batch (EXPERIMENTS.md §Perf).
    pub fn solve_multi(&self, rt: &PjrtRuntime, bs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let m = &self.matrix;
        let r_req = bs.len();
        if r_req == 0 {
            return Ok(Vec::new());
        }
        let global_max_deg = self.plans.iter().map(|p| p.max_deg).max().unwrap_or(0);
        let Some(probe) = rt.select_multi(pick_rhs_width(rt, r_req), global_max_deg) else {
            // No multi variant compiled: scalar fallback.
            return bs.iter().map(|b| self.solve(rt, b)).collect();
        };
        let r = probe.rhs;
        if r_req > r {
            // Split oversized batches.
            let mut out = Vec::with_capacity(r_req);
            for chunk in bs.chunks(r) {
                out.extend(self.solve_multi(rt, chunk)?);
            }
            return Ok(out);
        }
        for b in bs {
            anyhow::ensure!(b.len() == m.n, "rhs length");
        }
        let mut xs: Vec<Vec<f32>> = vec![vec![0f32; m.n]; r];
        for plan in &self.plans {
            let Some(variant) = rt.select_multi(r, plan.max_deg) else {
                unreachable!("probe guaranteed a variant");
            };
            let (bsz, esz) = (variant.batch, variant.edges);
            for chunk in plan.rows.chunks(bsz) {
                let mut vals = vec![0f32; bsz * esz];
                let mut xg = vec![0f32; r * bsz * esz];
                let mut bb = vec![0f32; r * bsz];
                let mut dinv = vec![1f32; bsz];
                for (row, &i) in chunk.iter().enumerate() {
                    let i = i as usize;
                    let (cols, vs) = m.row_off_diag(i);
                    let fit = cols.len().min(esz);
                    for e in 0..fit {
                        vals[row * esz + e] = vs[e];
                    }
                    dinv[row] = 1.0 / m.diag(i);
                    for k in 0..r {
                        let x = &xs[k];
                        for e in 0..fit {
                            xg[(k * bsz + row) * esz + e] = x[cols[e] as usize];
                        }
                        let mut carry = 0f32;
                        for e in fit..cols.len() {
                            carry += vs[e] * x[cols[e] as usize];
                        }
                        let bk = bs.get(k).map_or(0.0, |b| b[i]);
                        bb[k * bsz + row] = bk - carry;
                    }
                }
                let out = rt.execute_level_multi(variant, &vals, &xg, &bb, &dinv)?;
                for (row, &i) in chunk.iter().enumerate() {
                    for (k, x) in xs.iter_mut().enumerate() {
                        x[i as usize] = out[k * bsz + row];
                    }
                }
            }
        }
        xs.truncate(r_req);
        Ok(xs)
    }
}

/// The RHS width to probe for: the smallest compiled width ≥ the request,
/// else the largest available (requests are padded/split to fit).
fn pick_rhs_width(rt: &PjrtRuntime, want: usize) -> usize {
    let widths: Vec<usize> = rt.multi_variant_widths();
    widths
        .iter()
        .copied()
        .filter(|&w| w >= want)
        .min()
        .or_else(|| widths.iter().copied().max())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use std::path::PathBuf;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        PjrtRuntime::load(&dir).ok()
    }

    #[test]
    fn pjrt_solve_matches_reference() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        for m in [
            gen::circuit(600, 5, 0.8, GenSeed(1)),
            gen::grid2d(20, 20, true, GenSeed(2)),
            gen::chain(100, GenSeed(3)),
        ] {
            let solver = LevelSolver::new(&m);
            let b: Vec<f32> = (0..m.n).map(|i| (i % 11) as f32 - 5.0).collect();
            let x = solver.solve(&rt, &b).unwrap();
            assert_close_to_reference(&m, &b, &x, 1e-3);
        }
    }

    #[test]
    fn multi_rhs_matches_scalar_path() {
        let Some(rt) = runtime() else {
            return;
        };
        if rt.multi_variant_widths().is_empty() {
            eprintln!("skipping: no multi variants");
            return;
        }
        let m = gen::circuit(500, 5, 0.8, GenSeed(21));
        let solver = LevelSolver::new(&m);
        // Batch sizes below, equal to, and above the compiled width (8).
        for count in [1usize, 3, 8, 11] {
            let bs: Vec<Vec<f32>> = (0..count)
                .map(|k| (0..m.n).map(|i| ((i + k) % 9) as f32 - 4.0).collect())
                .collect();
            let xs = solver.solve_multi(&rt, &bs).unwrap();
            assert_eq!(xs.len(), count);
            for (b, x) in bs.iter().zip(&xs) {
                assert_close_to_reference(&m, b, x, 1e-3);
            }
        }
    }

    #[test]
    fn pjrt_solve_heavy_rows_use_carry() {
        let Some(rt) = runtime() else {
            return;
        };
        // Hub rows exceed every edge budget (> 32).
        let m = gen::power_law(400, 1.1, 120, GenSeed(4));
        let solver = LevelSolver::new(&m);
        let b = vec![1.0f32; m.n];
        let x = solver.solve(&rt, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
    }
}
