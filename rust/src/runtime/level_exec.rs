//! Shared level-plan preprocessing, plus the PJRT dispatch path (behind
//! the `pjrt` cargo feature).
//!
//! [`LevelSolver`] preprocesses a matrix once — level sets, per-level
//! maximum in-degree, and the gather layout implied by the diagonal-last
//! CSR rows — and is shared read-only by every [`SolverBackend`]
//! implementation (the runtime counterpart of the python
//! `model.plan_levels`). Backends only execute; they never re-derive the
//! schedule.
//!
//! When the `pjrt` feature is on, [`PjrtBackend`] dispatches one compiled
//! kernel invocation per level chunk. Rows whose in-degree exceeds the
//! variant's edge budget fold the overflow into a serial carry, exactly
//! like the L2 python mirror; the native backend reproduces the same
//! budget/carry split in pure Rust.

use super::kir::VerifiedKernel;
use super::mgd_plan::{MgdPlan, MgdPlanConfig};
use crate::graph::{Dag, Levels};
use crate::matrix::CsrMatrix;
use std::sync::{Arc, OnceLock};

/// Per-level execution plan: the level's rows (ascending ids) and the
/// maximum off-diagonal in-degree, which sizes the gather tile.
pub struct LevelPlan {
    /// Rows of this level, mutually independent.
    pub rows: Vec<u32>,
    /// Maximum in-level off-diagonal degree (edge-budget selector).
    pub max_deg: usize,
}

/// A matrix prepared for repeated solves: the backend-agnostic plan.
///
/// Cheap to share — the matrix and plans sit behind `Arc`s so parallel
/// backends can hand references to worker threads without copying.
pub struct LevelSolver {
    matrix: Arc<CsrMatrix>,
    plans: Arc<Vec<LevelPlan>>,
    /// Lazily-built medium-granularity plan (the `mgd` scheduler's input),
    /// cached so repeated solves share one preprocessing pass.
    mgd: OnceLock<Arc<MgdPlan>>,
    /// Lazily lowered + verified kernel IR (the `kir` scheduler's input).
    /// `Some` caches a verified kernel; `None` caches a verification
    /// failure so the fallback to `mgd` is decided once, off the hot path.
    kir: OnceLock<Option<Arc<VerifiedKernel>>>,
}

impl LevelSolver {
    /// Preprocess `m` (amortized across solves, like the paper's compiler).
    pub fn new(m: &CsrMatrix) -> Self {
        let g = Dag::from_csr(m);
        let lv = Levels::compute(&g);
        let plans = (0..lv.num_levels())
            .map(|l| {
                let rows = lv.level(l).to_vec();
                let max_deg = rows
                    .iter()
                    .map(|&i| m.in_degree(i as usize))
                    .max()
                    .unwrap_or(0);
                LevelPlan { rows, max_deg }
            })
            .collect();
        Self {
            matrix: Arc::new(m.clone()),
            plans: Arc::new(plans),
            mgd: OnceLock::new(),
            kir: OnceLock::new(),
        }
    }

    /// The medium-granularity plan of this matrix, built on first use and
    /// cached for every later solve. The sizing of the first caller wins;
    /// node sizing is a performance knob, never a correctness one (every
    /// clustering yields bitwise-identical solutions — see
    /// [`MgdPlan`]'s module docs).
    pub fn mgd_plan(&self, cfg: MgdPlanConfig) -> Arc<MgdPlan> {
        Arc::clone(
            self.mgd
                .get_or_init(|| Arc::new(MgdPlan::build(&self.matrix, cfg))),
        )
    }

    /// The verified kernel IR of this matrix (the `kir` scheduler tier),
    /// lowered from [`Self::mgd_plan`] and statically verified on first
    /// use, then cached — including a cached `None` when verification
    /// fails, so callers decide the fallback to the checked `mgd` tier
    /// once per matrix instead of re-verifying per solve.
    pub fn kir_kernel(&self, cfg: MgdPlanConfig) -> Option<Arc<VerifiedKernel>> {
        self.kir
            .get_or_init(|| {
                let plan = self.mgd_plan(cfg);
                VerifiedKernel::build(&plan).ok().map(Arc::new)
            })
            .clone()
    }

    /// The cached medium-granularity plan, if any caller built one yet.
    /// Audit/introspection hook (e.g. the registry's debug-build static
    /// audit): never builds, so it cannot poison the first-config-wins
    /// cache the backend owns.
    pub fn cached_mgd_plan(&self) -> Option<Arc<MgdPlan>> {
        self.mgd.get().cloned()
    }

    /// Test hook: pre-poison the kir cache with a verification failure so
    /// fallback paths can be exercised deterministically.
    #[cfg(test)]
    pub(crate) fn fail_kir_for_tests(&self) {
        let _ = self.kir.set(None);
    }

    /// Matrix order.
    pub fn n(&self) -> usize {
        self.matrix.n
    }

    /// Number of levels (kernel dispatch chains per solve).
    pub fn num_levels(&self) -> usize {
        self.plans.len()
    }

    /// The per-level plans, in dependency order.
    pub fn plans(&self) -> &[LevelPlan] {
        &self.plans
    }

    /// The planned matrix.
    pub fn matrix(&self) -> &CsrMatrix {
        &self.matrix
    }

    /// Maximum off-diagonal degree over the whole matrix.
    pub fn max_deg(&self) -> usize {
        self.plans.iter().map(|p| p.max_deg).max().unwrap_or(0)
    }

    /// Shared handle to the matrix (for worker threads).
    pub fn matrix_arc(&self) -> Arc<CsrMatrix> {
        Arc::clone(&self.matrix)
    }

    /// Shared handle to the plans (for worker threads).
    pub fn plans_arc(&self) -> Arc<Vec<LevelPlan>> {
        Arc::clone(&self.plans)
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

#[cfg(feature = "pjrt")]
mod pjrt {
    //! PJRT dispatch: one compiled kernel invocation per level chunk.

    use super::super::backend::SolverBackend;
    use super::super::client::PjrtRuntime;
    use super::LevelSolver;
    use anyhow::{Context, Result};
    use std::cell::RefCell;
    use std::path::{Path, PathBuf};

    /// The PJRT solver backend.
    ///
    /// PJRT clients are not `Send`/`Sync` (Rc-backed FFI handles), so the
    /// backend itself only carries the artifact path; each thread that
    /// solves through it lazily compiles its own private runtime into
    /// thread-local storage. Load failures surface as request errors —
    /// never as silently dropped replies.
    pub struct PjrtBackend {
        artifacts: PathBuf,
    }

    thread_local! {
        static RUNTIME: RefCell<Option<(PathBuf, PjrtRuntime)>> = const { RefCell::new(None) };
    }

    impl PjrtBackend {
        /// Validate the artifacts by loading them once on the calling
        /// thread (fail fast at service startup), then hand out a backend
        /// whose worker threads load their own runtimes on first use.
        pub fn load(artifacts: &Path) -> Result<Self> {
            PjrtRuntime::load(artifacts).context("validate PJRT artifacts")?;
            Ok(Self {
                artifacts: artifacts.to_path_buf(),
            })
        }

        fn with_runtime<T>(&self, f: impl FnOnce(&PjrtRuntime) -> Result<T>) -> Result<T> {
            RUNTIME.with(|cell| {
                let mut slot = cell.borrow_mut();
                let stale = match &*slot {
                    Some((path, _)) => *path != self.artifacts,
                    None => true,
                };
                if stale {
                    let rt = PjrtRuntime::load(&self.artifacts)
                        .context("load PJRT runtime on worker thread")?;
                    *slot = Some((self.artifacts.clone(), rt));
                }
                f(&slot.as_ref().expect("runtime cached above").1)
            })
        }
    }

    impl SolverBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn supports_multi_rhs(&self) -> bool {
            true
        }

        fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
            self.with_runtime(|rt| solve(rt, plan, b))
        }

        fn solve_multi(&self, plan: &LevelSolver, bs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            self.with_runtime(|rt| solve_multi(rt, plan, bs))
        }
    }

    /// Solve `L x = b` through the PJRT kernels.
    pub fn solve(rt: &PjrtRuntime, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
        let m = plan.matrix();
        anyhow::ensure!(b.len() == m.n, "rhs length");
        let mut x = vec![0f32; m.n];
        for level in plan.plans() {
            let variant = rt.select(level.rows.len(), level.max_deg);
            let (bsz, esz) = (variant.batch, variant.edges);
            for chunk in level.rows.chunks(bsz) {
                let mut vals = vec![0f32; bsz * esz];
                let mut xg = vec![0f32; bsz * esz];
                let mut bb = vec![0f32; bsz];
                let mut dinv = vec![1f32; bsz];
                for (r, &i) in chunk.iter().enumerate() {
                    let i = i as usize;
                    let (cols, vs) = m.row_off_diag(i);
                    let k = cols.len();
                    let fit = k.min(esz);
                    for e in 0..fit {
                        vals[r * esz + e] = vs[e];
                        xg[r * esz + e] = x[cols[e] as usize];
                    }
                    // Overflow edges fold into a serial carry on the host.
                    let mut carry = 0f32;
                    for e in fit..k {
                        carry += vs[e] * x[cols[e] as usize];
                    }
                    bb[r] = b[i] - carry;
                    dinv[r] = 1.0 / m.diag(i);
                }
                let out = rt.execute_level(variant, &vals, &xg, &bb, &dinv)?;
                for (r, &i) in chunk.iter().enumerate() {
                    x[i as usize] = out[r];
                }
            }
        }
        Ok(x)
    }

    /// Solve a batch of RHS in one pass, using the multi-RHS kernel when a
    /// variant matches the batch (padding smaller batches with zeros) and
    /// falling back to scalar solves otherwise. Dispatch and the shared
    /// `vals` staging are amortized across the batch.
    pub fn solve_multi(
        rt: &PjrtRuntime,
        plan: &LevelSolver,
        bs: &[Vec<f32>],
    ) -> Result<Vec<Vec<f32>>> {
        let m = plan.matrix();
        let r_req = bs.len();
        if r_req == 0 {
            return Ok(Vec::new());
        }
        let global_max_deg = plan.max_deg();
        let Some(probe) = rt.select_multi(pick_rhs_width(rt, r_req), global_max_deg) else {
            // No multi variant compiled: scalar fallback.
            return bs.iter().map(|b| solve(rt, plan, b)).collect();
        };
        let r = probe.rhs;
        if r_req > r {
            // Split oversized batches.
            let mut out = Vec::with_capacity(r_req);
            for chunk in bs.chunks(r) {
                out.extend(solve_multi(rt, plan, chunk)?);
            }
            return Ok(out);
        }
        for b in bs {
            anyhow::ensure!(b.len() == m.n, "rhs length");
        }
        let mut xs: Vec<Vec<f32>> = vec![vec![0f32; m.n]; r];
        for level in plan.plans() {
            let Some(variant) = rt.select_multi(r, level.max_deg) else {
                unreachable!("probe guaranteed a variant");
            };
            let (bsz, esz) = (variant.batch, variant.edges);
            for chunk in level.rows.chunks(bsz) {
                let mut vals = vec![0f32; bsz * esz];
                let mut xg = vec![0f32; r * bsz * esz];
                let mut bb = vec![0f32; r * bsz];
                let mut dinv = vec![1f32; bsz];
                for (row, &i) in chunk.iter().enumerate() {
                    let i = i as usize;
                    let (cols, vs) = m.row_off_diag(i);
                    let fit = cols.len().min(esz);
                    for e in 0..fit {
                        vals[row * esz + e] = vs[e];
                    }
                    dinv[row] = 1.0 / m.diag(i);
                    for k in 0..r {
                        let x = &xs[k];
                        for e in 0..fit {
                            xg[(k * bsz + row) * esz + e] = x[cols[e] as usize];
                        }
                        let mut carry = 0f32;
                        for e in fit..cols.len() {
                            carry += vs[e] * x[cols[e] as usize];
                        }
                        let bk = bs.get(k).map_or(0.0, |b| b[i]);
                        bb[k * bsz + row] = bk - carry;
                    }
                }
                let out = rt.execute_level_multi(variant, &vals, &xg, &bb, &dinv)?;
                for (row, &i) in chunk.iter().enumerate() {
                    for (k, x) in xs.iter_mut().enumerate() {
                        x[i as usize] = out[k * bsz + row];
                    }
                }
            }
        }
        xs.truncate(r_req);
        Ok(xs)
    }

    /// The RHS width to probe for: the smallest compiled width ≥ the
    /// request, else the largest available (requests are padded/split).
    fn pick_rhs_width(rt: &PjrtRuntime, want: usize) -> usize {
        let widths: Vec<usize> = rt.multi_variant_widths();
        widths
            .iter()
            .copied()
            .filter(|&w| w >= want)
            .min()
            .or_else(|| widths.iter().copied().max())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    #[test]
    fn plans_partition_rows_in_dependency_order() {
        let m = gen::circuit(500, 5, 0.8, GenSeed(2));
        let plan = LevelSolver::new(&m);
        assert_eq!(plan.n(), m.n);
        assert_eq!(plan.num_levels(), plan.plans().len());
        let mut seen = vec![false; m.n];
        let mut level_of = vec![0usize; m.n];
        for (l, lp) in plan.plans().iter().enumerate() {
            assert!(!lp.rows.is_empty(), "level {l} empty");
            for &i in &lp.rows {
                assert!(!seen[i as usize], "row {i} in two levels");
                seen[i as usize] = true;
                level_of[i as usize] = l;
            }
        }
        assert!(seen.iter().all(|&s| s), "levels must cover every row");
        // Every dependency sits in a strictly earlier level.
        for i in 0..m.n {
            let (cols, _) = m.row_off_diag(i);
            for &c in cols {
                assert!(level_of[c as usize] < level_of[i]);
            }
        }
    }

    #[test]
    fn per_level_max_deg_is_exact() {
        let m = gen::power_law(400, 1.1, 120, GenSeed(4));
        let plan = LevelSolver::new(&m);
        for lp in plan.plans() {
            let want = lp
                .rows
                .iter()
                .map(|&i| m.in_degree(i as usize))
                .max()
                .unwrap();
            assert_eq!(lp.max_deg, want);
        }
        assert_eq!(plan.max_deg(), m.max_in_degree());
    }

    #[test]
    fn mgd_plan_is_cached_and_first_config_wins() {
        let m = gen::circuit(300, 4, 0.8, GenSeed(7));
        let plan = LevelSolver::new(&m);
        let a = plan.mgd_plan(MgdPlanConfig {
            max_node_rows: 8,
            max_node_edges: 64,
        });
        let b = plan.mgd_plan(MgdPlanConfig {
            max_node_rows: 32,
            max_node_edges: 1024,
        });
        assert!(Arc::ptr_eq(&a, &b), "second call must hit the cache");
        assert_eq!(b.config.max_node_rows, 8);
        assert_eq!(a.n, m.n);
    }

    #[test]
    fn chain_plan_is_fully_sequential() {
        let m = gen::chain(64, GenSeed(5));
        let plan = LevelSolver::new(&m);
        assert_eq!(plan.num_levels(), 64);
        assert!(plan.plans().iter().all(|p| p.rows.len() == 1));
    }

    #[cfg(feature = "pjrt")]
    mod pjrt_tests {
        use super::super::PjrtBackend;
        use std::path::PathBuf;

        #[test]
        fn pjrt_backend_load_fails_cleanly_without_toolchain() {
            // With the in-tree xla_shim (or without artifacts) the load
            // must error — selection then falls back to native; it must
            // never hang or panic.
            let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            match PjrtBackend::load(&dir) {
                Ok(_) => eprintln!("real PJRT toolchain present; backend loaded"),
                Err(e) => eprintln!("expected offline failure: {e:#}"),
            }
        }
    }
}
