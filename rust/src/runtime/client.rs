//! PJRT client wrapper: HLO-text loading, one compiled executable per
//! `(batch, edge_budget)` kernel variant. Compiled only with the `pjrt`
//! cargo feature.
//!
//! Offline builds link against [`crate::runtime::xla_shim`], whose
//! constructors fail cleanly (backend selection then falls back to the
//! native executor). On a machine with the XLA toolchain, depend on the
//! real `xla` crate and drop the alias import below — the call surface is
//! identical.

use crate::runtime::xla_shim as xla;

use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

/// A loaded kernel variant.
pub struct LevelExecutable {
    /// Rows per invocation.
    pub batch: usize,
    /// Padded edges per row.
    pub edges: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// A loaded multi-RHS kernel variant (`manifest_multi.txt`).
pub struct MultiExecutable {
    /// RHS per invocation.
    pub rhs: usize,
    /// Rows per invocation.
    pub batch: usize,
    /// Padded edges per row.
    pub edges: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime with all kernel variants compiled and ready.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    variants: Vec<LevelExecutable>,
    multi_variants: Vec<MultiExecutable>,
}

impl PjrtRuntime {
    /// Load every variant listed in `<artifacts>/manifest.txt`, compiling
    /// each HLO-text module on the PJRT CPU client.
    pub fn load(artifacts: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let manifest = artifacts.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest.display()))?;
        let mut variants = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let name = it.next().context("manifest: file name")?;
            let batch: usize = it.next().context("manifest: batch")?.parse()?;
            let edges: usize = it.next().context("manifest: edges")?.parse()?;
            let path: PathBuf = artifacts.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            variants.push(LevelExecutable { batch, edges, exe });
        }
        ensure!(!variants.is_empty(), "no kernel variants in manifest");
        // Largest-batch first so selection prefers amortized dispatch.
        variants.sort_by_key(|v| std::cmp::Reverse(v.batch));
        // Multi-RHS variants are optional (older artifact dirs).
        let mut multi_variants = Vec::new();
        if let Ok(text) = std::fs::read_to_string(artifacts.join("manifest_multi.txt")) {
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let mut it = line.split_whitespace();
                let name = it.next().context("multi manifest: name")?;
                let rhs: usize = it.next().context("multi manifest: rhs")?.parse()?;
                let batch: usize = it.next().context("multi manifest: batch")?.parse()?;
                let edges: usize = it.next().context("multi manifest: edges")?.parse()?;
                let path = artifacts.join(name);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                multi_variants.push(MultiExecutable {
                    rhs,
                    batch,
                    edges,
                    exe,
                });
            }
        }
        Ok(Self {
            client,
            variants,
            multi_variants,
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Available `(batch, edges)` variants, largest batch first.
    pub fn variant_shapes(&self) -> Vec<(usize, usize)> {
        self.variants.iter().map(|v| (v.batch, v.edges)).collect()
    }

    /// Pick the best variant for a level of `rows` rows whose maximum
    /// in-level degree is `max_deg`: the smallest batch that still fits the
    /// degree budget, falling back to the largest-edge variant.
    pub fn select(&self, rows: usize, max_deg: usize) -> &LevelExecutable {
        // Prefer a variant whose edge budget covers max_deg and whose batch
        // wastes the least padding; variants are sorted largest-batch first.
        let fitting: Vec<&LevelExecutable> = self
            .variants
            .iter()
            .filter(|v| v.edges >= max_deg)
            .collect();
        let pool: Vec<&LevelExecutable> = if fitting.is_empty() {
            self.variants.iter().collect()
        } else {
            fitting
        };
        *pool
            .iter()
            .min_by_key(|v| {
                let invocations = rows.div_ceil(v.batch);
                (invocations * v.batch, v.edges)
            })
            .expect("at least one variant")
    }

    /// RHS widths of the compiled multi variants.
    pub fn multi_variant_widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.multi_variants.iter().map(|v| v.rhs).collect();
        w.sort_unstable();
        w.dedup();
        w
    }

    /// The multi-RHS variant matching `rhs`, if one was compiled.
    pub fn select_multi(&self, rhs: usize, max_deg: usize) -> Option<&MultiExecutable> {
        self.multi_variants
            .iter()
            .filter(|v| v.rhs == rhs && v.edges >= max_deg)
            .min_by_key(|v| v.batch)
            .or_else(|| {
                self.multi_variants
                    .iter()
                    .filter(|v| v.rhs == rhs)
                    .max_by_key(|v| v.edges)
            })
    }

    /// Execute one padded level against `rhs` right-hand sides:
    /// `vals` is `(batch, edges)` row-major, `xg` is `(rhs, batch, edges)`,
    /// `b` is `(rhs, batch)`, `dinv` is `(batch,)`. Returns `(rhs, batch)`
    /// flattened.
    pub fn execute_level_multi(
        &self,
        variant: &MultiExecutable,
        vals: &[f32],
        xg: &[f32],
        b: &[f32],
        dinv: &[f32],
    ) -> Result<Vec<f32>> {
        let (r, bsz, esz) = (variant.rhs, variant.batch, variant.edges);
        ensure!(vals.len() == bsz * esz, "vals shape");
        ensure!(xg.len() == r * bsz * esz, "xg shape");
        ensure!(b.len() == r * bsz && dinv.len() == bsz, "vector shapes");
        let lv = xla::Literal::vec1(vals).reshape(&[bsz as i64, esz as i64])?;
        let lx = xla::Literal::vec1(xg).reshape(&[r as i64, bsz as i64, esz as i64])?;
        let lb = xla::Literal::vec1(b).reshape(&[r as i64, bsz as i64])?;
        let ld = xla::Literal::vec1(dinv);
        let result = variant.exe.execute::<xla::Literal>(&[lv, lx, lb, ld])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let x = out.to_vec::<f32>()?;
        ensure!(x.len() == r * bsz, "multi kernel output shape");
        Ok(x)
    }

    /// Execute one padded level: flat row-major `vals`/`xg` of shape
    /// `(batch, edges)`, `b`/`dinv` of length `batch`. Returns `x[batch]`.
    pub fn execute_level(
        &self,
        variant: &LevelExecutable,
        vals: &[f32],
        xg: &[f32],
        b: &[f32],
        dinv: &[f32],
    ) -> Result<Vec<f32>> {
        let (bsz, esz) = (variant.batch, variant.edges);
        ensure!(vals.len() == bsz * esz && xg.len() == bsz * esz, "tile shape");
        ensure!(b.len() == bsz && dinv.len() == bsz, "vector shape");
        let lv = xla::Literal::vec1(vals).reshape(&[bsz as i64, esz as i64])?;
        let lx = xla::Literal::vec1(xg).reshape(&[bsz as i64, esz as i64])?;
        let lb = xla::Literal::vec1(b);
        let ld = xla::Literal::vec1(dinv);
        let result = variant.exe.execute::<xla::Literal>(&[lv, lx, lb, ld])?[0][0]
            .to_literal_sync()?;
        // Lowered with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let x = out.to_vec::<f32>()?;
        if x.len() != bsz {
            bail!("kernel returned {} values, expected {bsz}", x.len());
        }
        Ok(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_and_executes_variants() {
        let rt = match PjrtRuntime::load(&artifacts_dir()) {
            Ok(rt) => rt,
            Err(e) => {
                // Artifacts are a build product; skip when absent (CI runs
                // `make artifacts` first — the Makefile test target does).
                eprintln!("skipping: {e}");
                return;
            }
        };
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        for &(bsz, esz) in &rt.variant_shapes() {
            let v = rt.select(bsz, esz);
            assert_eq!((v.batch, v.edges), (bsz, esz));
            // out = (b - Σ vals·xg) · dinv with vals = 0 → out = b·dinv.
            let vals = vec![0f32; bsz * esz];
            let xg = vec![1f32; bsz * esz];
            let b: Vec<f32> = (0..bsz).map(|i| i as f32).collect();
            let dinv = vec![2f32; bsz];
            let x = rt.execute_level(v, &vals, &xg, &b, &dinv).unwrap();
            for (i, &xi) in x.iter().enumerate() {
                assert_eq!(xi, 2.0 * i as f32);
            }
        }
    }

    #[test]
    fn select_prefers_fitting_edge_budget() {
        let Ok(rt) = PjrtRuntime::load(&artifacts_dir()) else {
            return;
        };
        // max_deg 20 does not fit the 16-edge variant.
        let v = rt.select(10, 20);
        assert!(v.edges >= 20 || rt.variant_shapes().iter().all(|&(_, e)| e < 20));
    }
}
