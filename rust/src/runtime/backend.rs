//! The pluggable numeric-backend abstraction of the serve path.
//!
//! A [`SolverBackend`] executes the level plans prepared once per matrix by
//! [`LevelSolver`](super::LevelSolver) against a stream of right-hand
//! sides. Two implementations exist:
//!
//! - [`NativeBackend`](super::NativeBackend) (always available): pure
//!   Rust, the default request path. Executes through a scheduler chosen
//!   by [`NativeConfig::scheduler`]
//!   (`SchedulerKind::{Level, Mgd, Kir, Auto}`): the barriered level
//!   pool, the barrier-free medium-granularity DAG executor, or the
//!   latter with verified kernel-IR node bodies.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): dispatches the
//!   AOT-compiled JAX/Pallas level kernels through PJRT, one compiled
//!   executable per `(batch, edge_budget)` variant.
//!
//! Backend choice is a [`BackendKind`] in [`BackendConfig`]; [`create_backend`]
//! is the single construction point used by the coordinator, the CLI and the
//! bench harness. Construction errors propagate — a backend that cannot
//! initialize fails `SolveService::start` instead of hanging requests.

use super::level_exec::LevelSolver;
use super::native::{NativeBackend, NativeConfig};
use super::pool::RequestClass;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::Arc;

/// A numeric executor for prepared level plans.
///
/// Implementations must be shareable across the coordinator's worker
/// threads (`Send + Sync`); per-thread state (e.g. non-`Send` FFI handles)
/// belongs in thread-local storage inside the backend.
pub trait SolverBackend: Send + Sync {
    /// Short backend identifier for logs, tables and responses.
    fn name(&self) -> &'static str;

    /// True when [`SolverBackend::solve_multi`] batches more efficiently
    /// than repeated scalar solves (capability probe used by the service's
    /// batching loop).
    fn supports_multi_rhs(&self) -> bool {
        false
    }

    /// Warm per-matrix state ahead of the first request (idempotent).
    ///
    /// The sharded service calls this at registration time so that
    /// *registration*, not the first solve, pays the amortizable costs:
    /// the native backend builds (and caches) the matrix's
    /// [`MgdPlan`](super::MgdPlan) and spawns its persistent
    /// [`MgdPool`](super::MgdPool) here. The default does nothing.
    fn prepare(&self, plan: &LevelSolver) -> Result<()> {
        let _ = plan;
        Ok(())
    }

    /// The scheduler this backend would execute `plan` with, if the
    /// concept applies: the native backend reports its per-plan `auto`
    /// resolution (the cost-model pick of
    /// [`recommend_scheduler`](super::native::recommend_scheduler)) so
    /// the coordinator can record — and `mgd serve` report — the choice
    /// made for each registered matrix. Backends without a scheduler
    /// seam (PJRT) return `None`, the default.
    fn chosen_scheduler(&self, plan: &LevelSolver) -> Option<super::SchedulerKind> {
        let _ = plan;
        None
    }

    /// Introspection of the backend's persistent worker pool, if it has
    /// one: worker/live-thread counts, sessions served, and the session
    /// concurrency high-water mark. The serving runtime folds this into
    /// [`ServingStats`](crate::coordinator::ServingStats); the default
    /// (for pool-less backends) is `None`.
    fn pool_stats(&self) -> Option<super::MgdPoolStats> {
        None
    }

    /// Solve `L x = b` through the prepared plan.
    fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>>;

    /// Solve a batch of RHS; the default falls back to scalar solves.
    fn solve_multi(&self, plan: &LevelSolver, bs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        bs.iter().map(|b| self.solve(plan, b)).collect()
    }

    /// [`SolverBackend::solve`] with the request's scheduling class
    /// attached. Backends with class-aware resources (the native
    /// backend's reserved latency-lane pool workers) use the class to
    /// pick the session lease; the default ignores it.
    fn solve_class(&self, plan: &LevelSolver, b: &[f32], class: RequestClass) -> Result<Vec<f32>> {
        let _ = class;
        self.solve(plan, b)
    }

    /// [`SolverBackend::solve_multi`] with the batch's scheduling class
    /// attached (the sharded service only batches same-class requests).
    /// The default ignores the class.
    fn solve_multi_class(
        &self,
        plan: &LevelSolver,
        bs: &[Vec<f32>],
        class: RequestClass,
    ) -> Result<Vec<Vec<f32>>> {
        let _ = class;
        self.solve_multi(plan, bs)
    }
}

/// Which backend to construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT when the feature is enabled *and* its artifacts load, else native.
    Auto,
    /// The pure-Rust parallel level executor.
    Native,
    /// The PJRT kernel path (requires the `pjrt` cargo feature).
    Pjrt,
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "native" => Ok(Self::Native),
            "pjrt" => Ok(Self::Pjrt),
            other => bail!("unknown backend {other:?} (expected native|pjrt|auto)"),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Auto => "auto",
            Self::Native => "native",
            Self::Pjrt => "pjrt",
        })
    }
}

/// Backend construction options.
#[derive(Debug, Clone)]
pub struct BackendConfig {
    /// Which backend to construct.
    pub kind: BackendKind,
    /// Artifact directory for the PJRT backend (`manifest.txt` + HLO text).
    pub artifacts: PathBuf,
    /// Native executor tuning.
    pub native: NativeConfig,
}

impl Default for BackendConfig {
    fn default() -> Self {
        Self {
            kind: BackendKind::Auto,
            artifacts: PathBuf::from("artifacts"),
            native: NativeConfig::default(),
        }
    }
}

/// Construct the configured backend.
///
/// - `Native` always succeeds.
/// - `Pjrt` errors when the crate was built without the `pjrt` feature or
///   when the artifacts fail to load (no silent fallback: an explicit
///   request for PJRT that cannot be served is a startup error).
/// - `Auto` prefers PJRT when available and quietly falls back to native.
pub fn create_backend(cfg: &BackendConfig) -> Result<Arc<dyn SolverBackend>> {
    match cfg.kind {
        BackendKind::Native => Ok(Arc::new(NativeBackend::new(cfg.native))),
        BackendKind::Pjrt => load_pjrt(cfg),
        BackendKind::Auto => match load_pjrt(cfg) {
            Ok(b) => Ok(b),
            Err(_e) => {
                #[cfg(feature = "pjrt")]
                eprintln!("pjrt backend unavailable ({_e:#}); falling back to native");
                Ok(Arc::new(NativeBackend::new(cfg.native)))
            }
        },
    }
}

#[cfg(feature = "pjrt")]
fn load_pjrt(cfg: &BackendConfig) -> Result<Arc<dyn SolverBackend>> {
    use anyhow::Context;
    let backend = super::level_exec::PjrtBackend::load(&cfg.artifacts)
        .with_context(|| format!("load PJRT backend from {}", cfg.artifacts.display()))?;
    Ok(Arc::new(backend))
}

#[cfg(not(feature = "pjrt"))]
fn load_pjrt(cfg: &BackendConfig) -> Result<Arc<dyn SolverBackend>> {
    bail!(
        "backend 'pjrt' requires a build with `--features pjrt` \
         (artifacts dir: {})",
        cfg.artifacts.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert_eq!("auto".parse::<BackendKind>().unwrap(), BackendKind::Auto);
        assert!("cuda".parse::<BackendKind>().is_err());
        for k in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(k.to_string().parse::<BackendKind>().unwrap(), k);
        }
    }

    #[test]
    fn native_backend_always_constructs() {
        let cfg = BackendConfig {
            kind: BackendKind::Native,
            ..BackendConfig::default()
        };
        let b = create_backend(&cfg).unwrap();
        assert_eq!(b.name(), "native");
        assert!(b.supports_multi_rhs());
    }

    #[test]
    fn native_backend_honors_scheduler_choice() {
        use super::super::native::SchedulerKind;
        for scheduler in [SchedulerKind::Level, SchedulerKind::Mgd, SchedulerKind::Auto] {
            let cfg = BackendConfig {
                kind: BackendKind::Native,
                native: crate::runtime::NativeConfig {
                    threads: 2,
                    scheduler,
                    ..crate::runtime::NativeConfig::default()
                },
                ..BackendConfig::default()
            };
            let backend = create_backend(&cfg).unwrap();
            let m = gen::chain(150, GenSeed(17)); // deep: the mgd sweet spot
            let plan = LevelSolver::new(&m);
            let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32 - 2.0).collect();
            let x = backend.solve(&plan, &b).unwrap();
            assert_close_to_reference(&m, &b, &x, 1e-3);
        }
    }

    #[test]
    fn pjrt_without_toolchain_errors_explicitly() {
        // Without the feature this is a build-flag error; with the feature
        // (and the xla_shim stub or a missing artifacts dir) the load fails.
        // Either way an explicit pjrt request must error, not hang.
        let cfg = BackendConfig {
            kind: BackendKind::Pjrt,
            artifacts: PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        assert!(create_backend(&cfg).is_err());
    }

    #[test]
    fn auto_falls_back_to_a_working_backend() {
        let cfg = BackendConfig {
            artifacts: PathBuf::from("/nonexistent/artifacts"),
            ..BackendConfig::default()
        };
        let backend = create_backend(&cfg).unwrap();
        let m = gen::circuit(300, 4, 0.8, GenSeed(9));
        let plan = LevelSolver::new(&m);
        let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32 - 2.0).collect();
        let x = backend.solve(&plan, &b).unwrap();
        assert_close_to_reference(&m, &b, &x, 1e-3);
    }

    #[test]
    fn default_solve_multi_matches_scalar_path() {
        struct ScalarOnly;
        impl SolverBackend for ScalarOnly {
            fn name(&self) -> &'static str {
                "scalar-only"
            }
            fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
                Ok(crate::matrix::triangular::solve_serial(plan.matrix(), b))
            }
        }
        let m = gen::banded(200, 4, 0.6, GenSeed(3));
        let plan = LevelSolver::new(&m);
        let bs: Vec<Vec<f32>> = (0..3)
            .map(|k| (0..m.n).map(|i| ((i + k) % 7) as f32).collect())
            .collect();
        let backend = ScalarOnly;
        assert!(!backend.supports_multi_rhs());
        let xs = backend.solve_multi(&plan, &bs).unwrap();
        assert_eq!(xs.len(), 3);
        for (b, x) in bs.iter().zip(&xs) {
            assert_close_to_reference(&m, b, x, 1e-3);
        }
    }
}
