//! Barrier-free medium-granularity DAG executor (the `mgd` scheduler of
//! the native backend).
//!
//! Executes an [`MgdPlan`] with counter-driven readiness instead of the
//! per-level barriers of the level scheduler: each node carries an atomic
//! dependency counter seeded with its distinct-predecessor count; whoever
//! completes a node decrements its successors' counters and pushes any
//! counter that hits zero onto its *own* deque, so a freshly-enabled
//! consumer runs next on the worker that just produced its operands
//! (cache-warm, the runtime analog of the compiler's producer forwarding).
//! Workers pop their own deque LIFO and steal FIFO from the back of a
//! victim's deque when idle — deep/narrow DAG regions flow through one
//! worker with zero barrier waits while wide regions fan out.
//!
//! Within a node, execution keeps the current row's partial sum in a plain
//! accumulator (the "feedback register" of paper §IV.B) and parks each
//! completed in-node solution in a node-local buffer (the psum slab);
//! later rows of the same node resolve intra-node operands from that
//! buffer without touching the shared `x` slab. External operands are
//! gathered once per node and RHS through the plan's deduplicated,
//! ascending [`MgdNode::ext`] list (the ICR-ordered gather).
//!
//! Results are **bitwise identical** to
//! [`solve_serial`](crate::matrix::triangular::solve_serial) for any
//! thread count and steal order: each row reduces its edges in CSR order
//! with a single `f32` accumulator and divides by the diagonal, and every
//! operand is read after a happens-before edge from its producer (see
//! `runtime/atomics.md` for the full protocol).
//!
//! Workers come from an [`MgdPool`]: [`execute_on`] runs one solve as a
//! pool *session* (the caller is worker 0; parked pool threads claim the
//! remaining slots), so a long-lived serving pool pays thread spawns once
//! instead of per solve. [`execute`] is the one-shot convenience wrapper
//! that builds a transient pool per call — it is also the
//! per-solve-spawn baseline that `mgd bench serving` compares the
//! persistent pool against.
//!
//! The same scheduler also drives the verified kernel-IR tier
//! ([`execute_kernel`] / [`execute_kernel_on_class`]): each node runs as
//! statically verified bytecode ([`runtime::kir`](super::kir)) instead of
//! the checked SoA walk — same reduction order, same bits, no per-edge
//! bounds checks or `LOCAL_BIT` branches.
//!
//! # Example
//!
//! One-shot and pooled execution of the same plan; both are bitwise equal
//! to the serial reference:
//!
//! ```
//! use mgd_sptrsv::matrix::gen::{self, GenSeed};
//! use mgd_sptrsv::matrix::triangular::solve_serial;
//! use mgd_sptrsv::runtime::{mgd_exec, MgdPlan, MgdPlanConfig, MgdPool};
//!
//! let m = gen::circuit(300, 4, 0.8, GenSeed(7));
//! let plan = MgdPlan::build(&m, MgdPlanConfig::default());
//! let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32 - 2.0).collect();
//!
//! // One-shot: spawns and joins a transient pool inside the call.
//! let (xs, _) = mgd_exec::execute(&plan, &[b.clone()], 4).unwrap();
//!
//! // Serving: one persistent pool amortized across many solves.
//! let pool = MgdPool::new(3); // 3 parked workers + the caller = 4
//! let (ys, stats) = mgd_exec::execute_on(&plan, &[b.clone()], &pool, 4).unwrap();
//! assert_eq!(stats.nodes_executed, plan.num_nodes() as u64);
//!
//! let want = solve_serial(&m, &b);
//! for i in 0..m.n {
//!     assert_eq!(xs[0][i].to_bits(), want[i].to_bits());
//!     assert_eq!(ys[0][i].to_bits(), want[i].to_bits());
//! }
//! ```

use super::kir::{KernelProgram, VerifiedKernel};
use super::mgd_plan::{LOCAL_BIT, MgdNode, MgdPlan};
use super::pool::{MgdPool, RequestClass};
use super::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use super::sync::Mutex;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// Counters recorded by one [`execute`] / [`execute_on`] call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MgdExecStats {
    /// Medium nodes executed (== plan nodes on success).
    pub nodes_executed: u64,
    /// Nodes obtained by stealing from another worker's deque.
    pub steals: u64,
}

/// Shared state of one barrier-free solve. Generic over the RHS view so
/// callers can pass `&[Vec<f32>]` or borrowed `&[&[f32]]` without a
/// staging copy.
struct Run<'a, B: AsRef<[f32]> + Sync> {
    plan: &'a MgdPlan,
    /// Verified bytecode for each node when this run executes on the
    /// unchecked kir tier; `None` runs the checked [`run_node`] walk.
    /// Only ever `Some` for a program that came out of a
    /// [`VerifiedKernel`] (see [`execute_kernel_on_class`]).
    kernel: Option<&'a KernelProgram>,
    bs: &'a [B],
    /// `f32` bits of the solution, `(rhs, n)` row-major.
    x: &'a [AtomicU32],
    /// Remaining-dependency counter per node.
    counters: Vec<AtomicU32>,
    /// Per-worker deque of ready node ids.
    deques: Vec<Mutex<VecDeque<u32>>>,
    /// Per-deque length mirror so idle workers scan victims without
    /// taking locks (advisory; the lock is the source of truth).
    lens: Vec<AtomicUsize>,
    /// Nodes not yet completed; 0 is the global exit condition.
    remaining: AtomicUsize,
    /// A node job panicked: everyone bails out.
    poisoned: AtomicBool,
    steals: AtomicU64,
}

/// Workers one solve of `plan` can usefully engage: never more than the
/// requested `threads`, the node count, or the node DAG's level width —
/// a pure chain (width 1) runs entirely on the calling thread.
fn effective_workers(plan: &MgdPlan, threads: usize) -> usize {
    threads
        .max(1)
        .min(plan.nodes.len().max(1))
        .min(plan.par_width.max(1))
}

/// Execute `plan` for every RHS in `bs` on up to `threads` workers
/// (including the calling thread), spawning a **transient** [`MgdPool`]
/// for this one call. Returns the solutions and the run counters.
///
/// This is the one-shot path (tests, ad-hoc solves) and the
/// per-solve-spawn baseline of `mgd bench serving`; servers should hold a
/// persistent pool and call [`execute_on`] so repeated solves skip the
/// spawn cost entirely.
pub fn execute<B: AsRef<[f32]> + Sync>(
    plan: &MgdPlan,
    bs: &[B],
    threads: usize,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    let extra = effective_workers(plan, threads).saturating_sub(1);
    // A zero-worker pool spawns no threads, so serial plans stay
    // spawn-free through this wrapper too.
    let pool = MgdPool::new(extra);
    execute_on(plan, bs, &pool, threads)
}

/// Execute `plan` for every RHS in `bs` as one session of a caller-owned
/// (typically persistent) [`MgdPool`]: the calling thread is worker 0 and
/// up to `min(threads, pool.workers() + 1) - 1` parked pool threads claim
/// the remaining worker slots. Returns the solutions and the run
/// counters.
///
/// The worker count is additionally clamped to what the plan can keep
/// busy (node count and DAG width), so serial plans never touch the pool
/// at all.
///
/// The session runs as [`RequestClass::Bulk`] — it leases only the
/// pool's unreserved workers. Latency-critical solves go through
/// [`execute_on_class`].
pub fn execute_on<B: AsRef<[f32]> + Sync>(
    plan: &MgdPlan,
    bs: &[B],
    pool: &MgdPool,
    threads: usize,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    execute_on_class(plan, bs, pool, threads, RequestClass::Bulk)
}

/// [`execute_on`] with an explicit session [`RequestClass`]: `Latency`
/// sessions may additionally lease the pool's reserved latency-lane
/// workers (see [`MgdPool::new_with_reserved`]), so a latency-critical
/// solve arriving during a bulk flood still finds workers to claim.
pub fn execute_on_class<B: AsRef<[f32]> + Sync>(
    plan: &MgdPlan,
    bs: &[B],
    pool: &MgdPool,
    threads: usize,
    class: RequestClass,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    execute_impl(plan, None, bs, pool, threads, class)
}

/// [`execute`] on the verified kernel-IR tier: one-shot convenience that
/// spawns a transient pool and runs every node through the unchecked
/// bytecode interpreter (`runtime::kir`). Bitwise identical to the
/// checked paths — the verifier proved the programs preserve the CSR
/// reduction order.
pub fn execute_kernel<B: AsRef<[f32]> + Sync>(
    kernel: &VerifiedKernel,
    bs: &[B],
    threads: usize,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    let extra = effective_workers(kernel.plan(), threads).saturating_sub(1);
    let pool = MgdPool::new(extra);
    execute_kernel_on_class(kernel, bs, &pool, threads, RequestClass::Bulk)
}

/// [`execute_on_class`] on the verified kernel-IR tier: the same
/// barrier-free node scheduling (counters, deques, steals — all driven by
/// the kernel's plan), with each node's inner loop executed by the
/// unchecked interpreter instead of the checked SoA walk. Accepting only
/// [`VerifiedKernel`] is what keeps the unchecked tier gated behind
/// `kir::verify`.
pub fn execute_kernel_on_class<B: AsRef<[f32]> + Sync>(
    kernel: &VerifiedKernel,
    bs: &[B],
    pool: &MgdPool,
    threads: usize,
    class: RequestClass,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    execute_impl(kernel.plan(), Some(kernel.program()), bs, pool, threads, class)
}

/// Shared body of the checked and kernel-IR execution paths: identical
/// scheduling, per-node compute tier chosen by `kernel`.
fn execute_impl<B: AsRef<[f32]> + Sync>(
    plan: &MgdPlan,
    kernel: Option<&KernelProgram>,
    bs: &[B],
    pool: &MgdPool,
    threads: usize,
    class: RequestClass,
) -> Result<(Vec<Vec<f32>>, MgdExecStats)> {
    let n = plan.n;
    let r = bs.len();
    if r == 0 {
        return Ok((Vec::new(), MgdExecStats::default()));
    }
    for b in bs {
        let len = b.as_ref().len();
        ensure!(len == n, "rhs length {len} != matrix order {n}");
    }
    let x: Vec<AtomicU32> = std::iter::repeat_with(|| AtomicU32::new(0))
        .take(r * n)
        .collect();
    let num_nodes = plan.nodes.len();
    // Never engage more workers than the plan can keep busy or the pool
    // can lease to this session's class: a chain (width 1) runs on the
    // calling thread with zero pool traffic, and a bulk session only
    // counts the unreserved workers it may actually claim.
    let nworkers = effective_workers(plan, threads).min(pool.claimable(class) + 1);
    if nworkers <= 1 {
        // Serial path: node ids are topological, no scheduling needed.
        let mut scratch = Vec::new();
        let mut local = Vec::new();
        match kernel {
            Some(prog) => {
                for np in &prog.nodes {
                    super::kir::run_node_program(n, np, bs, &x, &mut scratch, &mut local);
                }
            }
            None => {
                for node in &plan.nodes {
                    run_node(n, node, bs, &x, &mut scratch, &mut local);
                }
            }
        }
        let stats = MgdExecStats {
            nodes_executed: num_nodes as u64,
            steals: 0,
        };
        return Ok((unpack(&x, r, n), stats));
    }
    let run = Run {
        plan,
        kernel,
        bs,
        x: &x,
        counters: plan
            .nodes
            .iter()
            .map(|nd| AtomicU32::new(nd.init_deps))
            .collect(),
        deques: (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect(),
        lens: (0..nworkers).map(|_| AtomicUsize::new(0)).collect(),
        remaining: AtomicUsize::new(num_nodes),
        poisoned: AtomicBool::new(false),
        steals: AtomicU64::new(0),
    };
    // Seed the roots round-robin so the fan-out starts distributed. A
    // pool worker that never wakes for this session leaves its deque to
    // the thieves — the steal scan covers every deque, so distribution is
    // a locality hint, never a liveness requirement.
    for (i, &root) in plan.roots.iter().enumerate() {
        let w = i % nworkers;
        run.deques[w].lock().unwrap().push_back(root);
        // relaxed: advisory deque-length mirror; the mutex is authoritative.
        run.lens[w].fetch_add(1, Ordering::Relaxed);
    }
    // One pool session: the caller runs slot 0; parked workers claim
    // slots 1..nworkers. `run` lives on this stack — the session-close
    // handshake inside `pool.run_with_class` keeps the borrow sound.
    pool.run_with_class(nworkers - 1, class, &|slot| worker_loop(&run, slot))?;
    // relaxed: the session-close handshake already ordered every worker's
    // stores before this point; these are post-join reads.
    ensure!(
        !run.poisoned.load(Ordering::Relaxed),
        "mgd node job panicked"
    );
    // relaxed: post-join read, ordered by the session close.
    debug_assert_eq!(run.remaining.load(Ordering::Relaxed), 0);
    let stats = MgdExecStats {
        nodes_executed: num_nodes as u64,
        // relaxed: post-join telemetry read, ordered by the session close.
        steals: run.steals.load(Ordering::Relaxed),
    };
    Ok((unpack(&x, r, n), stats))
}

fn unpack(x: &[AtomicU32], r: usize, n: usize) -> Vec<Vec<f32>> {
    (0..r)
        .map(|k| {
            (0..n)
                // relaxed: runs after the pool session closed, which
                // ordered every worker's `x` stores before this read.
                .map(|i| f32::from_bits(x[k * n + i].load(Ordering::Relaxed)))
                .collect()
        })
        .collect()
}

fn worker_loop<B: AsRef<[f32]> + Sync>(run: &Run<'_, B>, w: usize) {
    let mut scratch: Vec<f32> = Vec::new();
    let mut local: Vec<f32> = Vec::new();
    let mut idle_spins = 0u32;
    loop {
        // relaxed: advisory early-exit flag; the authoritative error is
        // re-read after the session joins.
        if run.poisoned.load(Ordering::Relaxed) {
            return;
        }
        let nid = pop_own(run, w).or_else(|| steal(run, w));
        let Some(nid) = nid else {
            // `remaining == 0` is the only clean exit: every node completed,
            // so no deque can ever become non-empty again.
            if run.remaining.load(Ordering::Acquire) == 0 {
                return;
            }
            // Tiered backoff: spin briefly, then yield, then doze — a
            // worker idling through a long serial DAG stretch must not
            // burn a whole core (the ~50 µs wake lag is small next to a
            // node's execution time).
            idle_spins = idle_spins.saturating_add(1);
            if idle_spins < 64 {
                std::hint::spin_loop();
            } else if idle_spins < 1024 {
                std::thread::yield_now();
            } else {
                std::thread::sleep(std::time::Duration::from_micros(50));
            }
            continue;
        };
        idle_spins = 0;
        // Catch panics so one bad node cannot strand the other workers in
        // their idle loops; the poison flag turns it into a solve error.
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec_node(run, nid, &mut scratch, &mut local);
        }))
        .is_ok();
        if !ok {
            // relaxed: flag only; the session close orders it for the
            // caller's post-join read.
            run.poisoned.store(true, Ordering::Relaxed);
            return;
        }
        complete(run, w, nid);
    }
}

/// Run one node on whichever compute tier the run was launched with: the
/// verified bytecode interpreter when a kernel is present, the checked
/// reference walk otherwise. Per-node results are bitwise identical, so
/// the scheduler above never needs to know the tier.
fn exec_node<B: AsRef<[f32]> + Sync>(
    run: &Run<'_, B>,
    nid: u32,
    scratch: &mut Vec<f32>,
    local: &mut Vec<f32>,
) {
    match run.kernel {
        Some(prog) => super::kir::run_node_program(
            run.plan.n,
            &prog.nodes[nid as usize],
            run.bs,
            run.x,
            scratch,
            local,
        ),
        None => run_node(
            run.plan.n,
            &run.plan.nodes[nid as usize],
            run.bs,
            run.x,
            scratch,
            local,
        ),
    }
}

/// Publish a finished node: decrement each successor's counter with
/// `Release` (ordering this node's `x` stores before the decrement) and
/// push any successor that hit zero onto our own deque — newest first, so
/// the consumer whose operands are hottest runs next.
fn complete<B: AsRef<[f32]> + Sync>(run: &Run<'_, B>, w: usize, nid: u32) {
    let node = &run.plan.nodes[nid as usize];
    for &s in &node.succs {
        if run.counters[s as usize].fetch_sub(1, Ordering::Release) == 1 {
            // Last dependency: acquire the release sequence on the counter
            // so every predecessor's stores are visible to whoever runs
            // `s` (the deque mutex extends the edge to a stealing worker).
            std::sync::atomic::fence(Ordering::Acquire);
            let mut q = run.deques[w].lock().unwrap();
            q.push_front(s);
            // relaxed: advisory length mirror; the mutex is authoritative.
            run.lens[w].fetch_add(1, Ordering::Relaxed);
        }
    }
    run.remaining.fetch_sub(1, Ordering::Release);
}

fn pop_own<B: AsRef<[f32]> + Sync>(run: &Run<'_, B>, w: usize) -> Option<u32> {
    // relaxed: advisory emptiness probe; a stale zero only delays the pop.
    if run.lens[w].load(Ordering::Relaxed) == 0 {
        return None;
    }
    let mut q = run.deques[w].lock().unwrap();
    let v = q.pop_front();
    if v.is_some() {
        // relaxed: advisory length mirror; the mutex is authoritative.
        run.lens[w].fetch_sub(1, Ordering::Relaxed);
    }
    v
}

fn steal<B: AsRef<[f32]> + Sync>(run: &Run<'_, B>, w: usize) -> Option<u32> {
    let nw = run.deques.len();
    for off in 1..nw {
        let t = (w + off) % nw;
        // relaxed: advisory victim probe; a stale zero only skips a victim.
        if run.lens[t].load(Ordering::Relaxed) == 0 {
            continue;
        }
        let mut q = run.deques[t].lock().unwrap();
        if let Some(v) = q.pop_back() {
            // relaxed: length mirror + telemetry; the mutex is authoritative.
            run.lens[t].fetch_sub(1, Ordering::Relaxed);
            run.steals.fetch_add(1, Ordering::Relaxed);
            return Some(v);
        }
    }
    None
}

/// Solve one node's rows for every RHS. Intra-node operands come from the
/// `local` psum buffer, external ones from the ICR-ordered `scratch`
/// gather; each row reduces in CSR order (bitwise-serial numerics).
fn run_node<B: AsRef<[f32]>>(
    n: usize,
    node: &MgdNode,
    bs: &[B],
    x: &[AtomicU32],
    scratch: &mut Vec<f32>,
    local: &mut Vec<f32>,
) {
    let first = node.first_row as usize;
    let rows = node.rows as usize;
    for (k, b) in bs.iter().enumerate() {
        let b = b.as_ref();
        let xk = &x[k * n..(k + 1) * n];
        scratch.clear();
        scratch.extend(
            node.ext
                .iter()
                // relaxed: the Release decrement + Acquire fence on this
                // node's dependency counter ordered the producers' stores.
                .map(|&c| f32::from_bits(xk[c as usize].load(Ordering::Relaxed))),
        );
        local.clear();
        for r in 0..rows {
            let lo = node.edge_ptr[r] as usize;
            let hi = node.edge_ptr[r + 1] as usize;
            let mut acc = 0f32;
            for e in lo..hi {
                let slot = node.edge_slot[e];
                let v = if slot & LOCAL_BIT != 0 {
                    local[(slot & !LOCAL_BIT) as usize]
                } else {
                    scratch[slot as usize]
                };
                acc += node.edge_val[e] * v;
            }
            let xi = (b[first + r] - acc) / node.diag[r];
            local.push(xi);
            // relaxed: published to consumers by the Release decrement of
            // their dependency counters in `complete`.
            xk[first + r].store(xi.to_bits(), Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::solve_serial;
    use crate::runtime::mgd_plan::MgdPlanConfig;

    fn rhs_batch(n: usize, count: usize) -> Vec<Vec<f32>> {
        (0..count)
            .map(|k| (0..n).map(|i| ((i + 3 * k) % 9) as f32 - 4.0).collect())
            .collect()
    }

    /// Property test (tentpole acceptance): for all 8 generator families ×
    /// thread counts {1, 2, 8} × RHS batches {1, 3, 11}, the MGD executor
    /// is **bitwise identical** to the serial reference — the reduction
    /// order is fixed by the plan, never by thread or steal timing.
    #[test]
    fn mgd_is_bitwise_serial_across_generators_threads_batches() {
        for (name, m) in &gen::test_suite() {
            let plan = MgdPlan::build(m, MgdPlanConfig::default());
            for threads in [1usize, 2, 8] {
                for count in [1usize, 3, 11] {
                    let bs = rhs_batch(m.n, count);
                    let (xs, stats) = execute(&plan, &bs, threads).unwrap();
                    assert_eq!(xs.len(), count);
                    assert_eq!(stats.nodes_executed, plan.num_nodes() as u64);
                    for (b, x) in bs.iter().zip(&xs) {
                        let want = solve_serial(m, b);
                        for i in 0..m.n {
                            assert_eq!(
                                x[i].to_bits(),
                                want[i].to_bits(),
                                "{name}: threads={threads} batch={count} row {i}: \
                                 {} != {}",
                                x[i],
                                want[i],
                            );
                        }
                    }
                }
            }
        }
    }

    /// Determinism: repeated contended runs produce identical bits. Tiny
    /// single-row nodes maximize counter traffic and steal interleavings,
    /// so this doubles as the stress test of the Release/Acquire counter
    /// protocol (runtime/atomics.md): any missing happens-before edge
    /// shows up as a row solved from a stale (zero) operand.
    #[test]
    fn mgd_determinism_and_ordering_stress() {
        let m = gen::circuit(800, 5, 0.8, GenSeed(21));
        let plan = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 1,
                max_node_edges: 1,
            },
        );
        assert_eq!(plan.num_nodes(), m.n); // node-per-row: max scheduling churn
        let bs = rhs_batch(m.n, 2);
        let (first, _) = execute(&plan, &bs, 8).unwrap();
        for round in 0..20 {
            let (xs, stats) = execute(&plan, &bs, 8).unwrap();
            assert_eq!(stats.nodes_executed, m.n as u64);
            for (a, b) in first.iter().zip(&xs) {
                for i in 0..m.n {
                    assert_eq!(
                        a[i].to_bits(),
                        b[i].to_bits(),
                        "round {round}, row {i}: nondeterministic bits"
                    );
                }
            }
        }
    }

    #[test]
    fn steals_happen_on_wide_dags() {
        // A wide shallow DAG seeds hundreds of independent roots across
        // the deques; idle workers must actually steal. Any single run
        // could in principle finish without a steal (scheduling is
        // timing-dependent), so retry a few times — a dead steal path
        // (e.g. broken `lens` bookkeeping) fails every attempt.
        let m = gen::shallow(4000, 0.4, GenSeed(22));
        let plan = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 8,
                max_node_edges: 4096,
            },
        );
        assert!(plan.num_nodes() > 64);
        let bs = rhs_batch(m.n, 1);
        let want = solve_serial(&m, &bs[0]);
        let mut stolen = 0u64;
        for _ in 0..20 {
            let (xs, stats) = execute(&plan, &bs, 4).unwrap();
            for i in 0..m.n {
                assert_eq!(xs[0][i].to_bits(), want[i].to_bits());
            }
            stolen += stats.steals;
            if stolen > 0 {
                break;
            }
        }
        assert!(stolen > 0, "no steal in 20 contended wide-DAG runs");
    }

    /// Serving contract: a persistent pool reused across many solves (and
    /// across different plans) stays bitwise-serial and never grows its
    /// thread count — the leak/regression guard for the pooled path.
    #[test]
    fn pooled_execution_reuses_workers_across_solves_and_plans() {
        let pool = MgdPool::new(3);
        for (name, m) in &gen::test_suite() {
            let plan = MgdPlan::build(m, MgdPlanConfig::default());
            let bs = rhs_batch(m.n, 2);
            for round in 0..3 {
                let (xs, stats) = execute_on(&plan, &bs, &pool, 4).unwrap();
                assert_eq!(stats.nodes_executed, plan.num_nodes() as u64);
                for (b, x) in bs.iter().zip(&xs) {
                    let want = solve_serial(m, b);
                    for i in 0..m.n {
                        assert_eq!(
                            x[i].to_bits(),
                            want[i].to_bits(),
                            "{name}: pooled round {round} row {i}"
                        );
                    }
                }
            }
            assert_eq!(pool.live_workers(), 3, "{name}: pool grew or leaked");
        }
        assert!(pool.stats().sessions > 0);
    }

    #[test]
    fn empty_batch_and_bad_lengths() {
        let m = gen::chain(50, GenSeed(23));
        let plan = MgdPlan::build(&m, MgdPlanConfig::default());
        let (xs, stats) = execute::<Vec<f32>>(&plan, &[], 4).unwrap();
        assert!(xs.is_empty());
        assert_eq!(stats, MgdExecStats::default());
        assert!(execute(&plan, &[vec![0f32; 49]], 4).is_err());
        assert!(execute(&plan, &[vec![0f32; 50], vec![0f32; 51]], 4).is_err());
    }

    #[test]
    fn more_workers_than_nodes_is_clamped() {
        let m = gen::chain(10, GenSeed(24));
        let plan = MgdPlan::build(
            &m,
            MgdPlanConfig {
                max_node_rows: 128,
                max_node_edges: usize::MAX,
            },
        );
        assert_eq!(plan.num_nodes(), 1);
        let bs = rhs_batch(m.n, 3);
        let (xs, stats) = execute(&plan, &bs, 16).unwrap();
        assert_eq!(stats.steals, 0); // single node → serial path
        let want = solve_serial(&m, &bs[2]);
        for i in 0..m.n {
            assert_eq!(xs[2][i].to_bits(), want[i].to_bits());
        }
    }
}
