//! Persistent worker pool of the barrier-free MGD scheduler, with
//! **concurrent sessions**.
//!
//! [`mgd_exec`](super::mgd_exec) used to spawn scoped workers per solve
//! (`std::thread::scope`), which is fine at bench sizes but measurable on
//! tiny latency-critical solves — exactly the repeated-solve regime the
//! serving runtime targets, where the paper amortizes *all* per-matrix
//! setup across a stream of right-hand sides. [`MgdPool`] keeps the
//! workers alive instead: threads are spawned once, park on a condvar
//! between solves, and join only when the pool is dropped (graceful
//! shutdown — no detached threads, no leaks under repeated service
//! start/stop).
//!
//! # Session protocol
//!
//! One solve is one *session*: [`MgdPool::run`] installs a closure into a
//! free slot of the session slab, wakes the parked workers, runs slot `0`
//! of the closure on the calling thread, and returns only after every
//! worker that joined the session has left it. Workers *claim*
//! participant slots (`1..=extra`) under the state mutex; a session is
//! closed by marking it non-claimable and waiting for its active count to
//! reach zero.
//!
//! Sessions **overlap**: each session holds a *slot lease* of at most
//! `extra` workers (for a solve, its plan's `par_width`), and workers the
//! lease does not claim stay parked — available to any other session
//! installed meanwhile. A mixed-traffic service can therefore run a small
//! solve's session next to a large one instead of queueing behind it,
//! mirroring how the paper's accelerator keeps PEs busy on independent
//! DAG regions. The pool tracks how much overlap actually happens
//! ([`MgdPoolStats::concurrent_sessions`] /
//! [`MgdPoolStats::peak_concurrency`]).
//!
//! A worker that never wakes in time (or is leased to another session)
//! simply misses the session: the MGD executor tolerates absent workers
//! (their seeded deques are stolen empty), so a session never blocks on a
//! straggler to *start* work, only to *finish* it — the calling thread
//! always participates as slot 0, so every session makes progress even
//! when the pool is fully leased out.
//!
//! # Priority lease lanes
//!
//! Sessions carry a [`RequestClass`]. A pool built with
//! [`MgdPool::new_with_reserved`] sets aside its first `reserved` workers
//! as a **latency lane**: those workers only ever claim slots of
//! [`RequestClass::Latency`] sessions, so a flood of
//! [`RequestClass::Bulk`] sessions can lease at most
//! `workers - reserved` threads and can never lease the pool dry — a
//! latency-critical solve arriving mid-flood always finds its reserved
//! workers parked and claimable. This is the pool-level analog of the
//! paper's partial-sum caching: keep resources available for the
//! latency-determining front instead of letting background work block it.
//! [`MgdPool::run`] submits a `Bulk` session;
//! [`MgdPool::run_with_class`] chooses.
//!
//! # Safety
//!
//! Each installed closure is stored as a lifetime-erased raw pointer so a
//! borrowing closure (the executor's, which borrows the per-solve run
//! state on the caller's stack) can cross into long-lived threads without
//! a staging copy. Soundness rests on one per-session invariant, enforced
//! in [`MgdPool::run`] even under unwinding (a drop guard closes the
//! session if the caller's slot panics): **the call does not return until
//! no worker can observe that session's pointer** — the session is marked
//! closing (no new claims) and its `active == 0` (no live borrows) before
//! the pointer goes out of scope. Sessions are independent: closing one
//! neither blocks on nor unblocks another.
//!
//! Memory ordering: all session state crosses threads under the state
//! `Mutex`/`Condvar` pair, which provides the happens-before edges for the
//! closure pointers and the slot claims. The `x`-slab ordering *inside* a
//! solve is the executor's counter protocol, documented in
//! `runtime/atomics.md`.

use super::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use super::sync::{thread, Arc, Condvar, Mutex};
use anyhow::{ensure, Result};

/// Scheduling class of one request (and of the pool session that serves
/// it). The class travels from the serving front end
/// (`SolveRequest`/shard queue ordering) down to the [`MgdPool`] slot
/// lease, where it decides whether a session may claim reserved workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RequestClass {
    /// Latency-critical traffic: drained ahead of `Bulk` by the sharded
    /// service's per-shard queues and allowed to lease the pool's
    /// reserved workers.
    Latency,
    /// Throughput traffic (the default): bounded to the unreserved part
    /// of the pool so it can never starve the latency lane.
    #[default]
    Bulk,
}

impl std::fmt::Display for RequestClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Latency => "latency",
            Self::Bulk => "bulk",
        })
    }
}

/// Point-in-time introspection of one [`MgdPool`] (leak checks, serving
/// metrics, bench reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MgdPoolStats {
    /// Worker threads this pool was built with (excludes callers, which
    /// participate in sessions on their own thread).
    pub workers: usize,
    /// Worker threads currently alive. Equal to `workers` from
    /// construction until drop; a persistent pool must never grow or
    /// shrink this across solves or service restarts.
    pub live: usize,
    /// Sessions executed through [`MgdPool::run`] since construction
    /// (including caller-only sessions that engaged no worker).
    pub sessions: u64,
    /// Sessions in flight right now (callers inside [`MgdPool::run`],
    /// including caller-only sessions).
    pub concurrent_sessions: usize,
    /// Maximum number of simultaneously in-flight sessions ever observed
    /// — the overlap proof: `>= 2` means two solves really did share the
    /// pool instead of queueing.
    pub peak_concurrency: usize,
    /// Workers reserved for [`RequestClass::Latency`] sessions (the first
    /// `reserved` worker indices); `Bulk` sessions can lease at most
    /// `workers - reserved` threads.
    pub reserved: usize,
}

/// Lifetime-erased session closure (`&dyn Fn(usize)` of the caller's
/// stack frame). Only ever dereferenced between a slot claim and the
/// matching `active` decrement, both of which the owning session's
/// close handshake orders before [`MgdPool::run`] returns.
#[derive(Clone, Copy)]
struct SessionFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointer crosses threads only under the state mutex, and the
// session protocol guarantees the pointee outlives every dereference (see
// the module-level Safety section).
unsafe impl Send for SessionFn {}

/// One installed session (a slot-lease of up to `limit` workers).
struct Job {
    f: SessionFn,
    /// Only `Latency` sessions may be claimed by reserved workers.
    class: RequestClass,
    /// Next participant slot a worker may claim (slot 0 is the caller's).
    next_slot: usize,
    /// Highest claimable slot; the session leases at most `limit` workers
    /// and leaves the rest to concurrently installed sessions.
    limit: usize,
    /// Workers currently executing the closure.
    active: usize,
    /// Closing sessions accept no new claims (set by the session closer).
    closing: bool,
    /// A worker's closure invocation panicked (reported by `run`).
    panicked: bool,
}

/// State shared between the pool handle and its worker threads.
struct State {
    /// Session slab: `None` entries are free and reused by the next
    /// install. Grows to the peak number of simultaneous sessions and
    /// stays there (entries are a few words each).
    sessions: Vec<Option<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a claimable session (or shutdown).
    work: Condvar,
    /// Session closers park here waiting for their session's `active`
    /// count to drain.
    done: Condvar,
}

/// A persistent pool of parked MGD workers, shared across solves (and, in
/// the sharded service, across matrices). Construction spawns the
/// threads; drop shuts them down gracefully (wake + join). Multiple
/// sessions may run concurrently, each leasing a disjoint subset of the
/// workers (see the module docs).
pub struct MgdPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
    live: Arc<AtomicUsize>,
    /// Workers reserved for `Latency` sessions (worker indices
    /// `0..reserved` skip `Bulk` jobs in their slab scan).
    reserved: usize,
    sessions: AtomicU64,
    /// Sessions currently inside [`MgdPool::run`].
    concurrent: AtomicUsize,
    /// High-water mark of `concurrent`.
    peak: AtomicUsize,
}

impl MgdPool {
    /// Spawn a pool of exactly `workers` parked threads. `0` is valid and
    /// spawns nothing: every [`MgdPool::run`] then executes on the caller
    /// alone (the serial path keeps working through the same API). No
    /// workers are reserved; see [`MgdPool::new_with_reserved`].
    pub fn new(workers: usize) -> Self {
        Self::new_with_reserved(workers, 0)
    }

    /// Like [`MgdPool::new`], but the first `reserved` workers (clamped
    /// to the pool size) only ever serve [`RequestClass::Latency`]
    /// sessions — [`RequestClass::Bulk`] sessions lease at most
    /// `workers - reserved` threads, so bulk floods cannot lease the
    /// pool dry. `reserved == workers` is valid: bulk sessions then run
    /// caller-only.
    pub fn new_with_reserved(workers: usize, reserved: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                sessions: Vec::new(),
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let reserved = reserved.min(workers);
        let live = Arc::new(AtomicUsize::new(workers));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let live = Arc::clone(&live);
            handles.push(
                thread::Builder::new()
                    .name(format!("mgd-pool-{w}"))
                    .spawn(move || {
                        worker_loop(&shared, w, w < reserved);
                        live.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn mgd pool worker thread"),
            );
        }
        Self {
            shared,
            handles,
            live,
            reserved,
            sessions: AtomicU64::new(0),
            concurrent: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// Worker threads this pool was built with.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Workers reserved for the latency lane (see
    /// [`MgdPool::new_with_reserved`]).
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// How many pool workers a session of `class` may lease: every worker
    /// for `Latency`, the unreserved remainder for `Bulk`.
    pub fn claimable(&self, class: RequestClass) -> usize {
        match class {
            RequestClass::Latency => self.handles.len(),
            RequestClass::Bulk => self.handles.len() - self.reserved,
        }
    }

    /// Worker threads currently alive (see [`MgdPoolStats::live`]).
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Snapshot the pool counters.
    pub fn stats(&self) -> MgdPoolStats {
        MgdPoolStats {
            workers: self.workers(),
            live: self.live_workers(),
            // relaxed: monotonic telemetry counter, no data is published
            // under it (see runtime/atomics.md).
            sessions: self.sessions.load(Ordering::Relaxed),
            concurrent_sessions: self.concurrent.load(Ordering::SeqCst),
            peak_concurrency: self.peak.load(Ordering::SeqCst),
            reserved: self.reserved,
        }
    }

    /// Execute one session: run `f(0)` on the calling thread while up to
    /// `extra` pool workers (clamped to the pool size) claim slots
    /// `1..=extra` and run `f(slot)` concurrently. Returns once **every**
    /// participant has finished — `f` may therefore borrow from the
    /// caller's stack. Errors if a worker's invocation of `f` panicked;
    /// a panic on the caller's own slot propagates (after the session is
    /// closed safely).
    ///
    /// Sessions overlap: concurrent callers run side by side, each
    /// leasing at most its own `extra` workers; workers a session does
    /// not claim stay available to the others. A session never waits for
    /// another to finish — at worst it runs caller-only because every
    /// worker is leased elsewhere.
    ///
    /// This form submits a [`RequestClass::Bulk`] session — it may only
    /// lease **unreserved** workers. Latency-critical callers use
    /// [`MgdPool::run_with_class`].
    pub fn run<F: Fn(usize) + Sync>(&self, extra: usize, f: &F) -> Result<()> {
        self.run_with_class(extra, RequestClass::Bulk, f)
    }

    /// [`MgdPool::run`] with an explicit session class: `Latency`
    /// sessions may lease any worker (including the reserved lane),
    /// `Bulk` sessions lease at most [`MgdPool::claimable`]`(Bulk)`
    /// workers so they can never starve latency traffic of its reserve.
    pub fn run_with_class<F: Fn(usize) + Sync>(
        &self,
        extra: usize,
        class: RequestClass,
        f: &F,
    ) -> Result<()> {
        // relaxed: monotonic telemetry counter, read only by `stats`.
        self.sessions.fetch_add(1, Ordering::Relaxed);
        let cur = self.concurrent.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(cur, Ordering::SeqCst);
        // Decrement `concurrent` however this call exits (return, error,
        // or an unwinding caller slot).
        let _concurrency = ConcurrencyGuard(&self.concurrent);
        let extra = extra.min(self.claimable(class));
        if extra == 0 {
            f(0);
            return Ok(());
        }
        let idx = {
            let mut st = self.shared.state.lock().unwrap();
            let job = Job {
                f: erase(f),
                class,
                next_slot: 1,
                limit: extra,
                active: 0,
                closing: false,
                panicked: false,
            };
            let idx = match st.sessions.iter().position(Option::is_none) {
                Some(i) => {
                    st.sessions[i] = Some(job);
                    i
                }
                None => {
                    st.sessions.push(Some(job));
                    st.sessions.len() - 1
                }
            };
            drop(st);
            self.shared.work.notify_all();
            idx
        };
        // Close the session even if `f(0)` unwinds: without this, a
        // worker could later claim a slot and call through a dangling
        // pointer into a dead stack frame.
        let mut guard = SessionCloser {
            shared: &self.shared,
            idx,
            armed: true,
        };
        f(0);
        guard.armed = false;
        drop(guard);
        let panicked = close_session(&self.shared, idx);
        ensure!(!panicked, "mgd pool worker panicked during a session");
        Ok(())
    }
}

impl Drop for MgdPool {
    fn drop(&mut self) {
        // Graceful shutdown: flag, wake every parked worker, join all.
        // `&mut self` proves no session is in flight (`run` borrows the
        // pool for its full duration), so workers exit their loop at the
        // next wakeup.
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Decrements the pool's in-flight session count on drop (normal return
/// and unwinding alike), keeping `concurrent_sessions` honest.
struct ConcurrencyGuard<'a>(&'a AtomicUsize);

impl Drop for ConcurrencyGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Erase the closure's borrow lifetime for storage in the shared state.
///
/// SAFETY: the returned pointer must not be dereferenced after the
/// session that carries it is closed; [`MgdPool::run`] upholds this by
/// draining the session before returning (or unwinding).
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> SessionFn {
    let ptr: *const (dyn Fn(usize) + Sync + 'a) = f;
    SessionFn(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(ptr)
    })
}

/// Unwind guard of [`MgdPool::run`]: if the caller's slot-0 invocation
/// panics, its session must still be closed (and drained) before the
/// closure's stack frame dies, or a late-claiming worker would call
/// through a dangling pointer. Disarmed on the normal path, where the
/// explicit [`close_session`] call reports worker panics.
struct SessionCloser<'a> {
    shared: &'a Shared,
    idx: usize,
    armed: bool,
}

impl Drop for SessionCloser<'_> {
    fn drop(&mut self) {
        if self.armed {
            let _ = close_session(self.shared, self.idx);
        }
    }
}

/// Mark the session at slab slot `idx` closing, wait for its active
/// workers to drain, and uninstall it (other sessions are untouched).
/// Returns whether any worker panicked inside it.
fn close_session(shared: &Shared, idx: usize) -> bool {
    let mut st = shared.state.lock().unwrap();
    match st.sessions[idx].as_mut() {
        Some(job) => job.closing = true,
        None => return false,
    }
    while st.sessions[idx].as_ref().is_some_and(|j| j.active > 0) {
        st = shared.done.wait(st).unwrap();
    }
    let job = st.sessions[idx].take().expect("closing session vanished");
    job.panicked
}

fn worker_loop(shared: &Shared, w: usize, latency_only: bool) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        // Scan the slab for a session with an unclaimed slot, starting at
        // a per-worker offset so concurrent sessions spread across the
        // pool instead of all workers piling into slab slot 0. Reserved
        // workers skip every non-latency session: their slots stay
        // parked for the next latency-class solve no matter how deep the
        // bulk backlog runs.
        let nslots = st.sessions.len();
        let mut claim = None;
        for off in 0..nslots {
            let idx = (w + off) % nslots;
            if let Some(job) = st.sessions[idx].as_mut() {
                if latency_only && job.class != RequestClass::Latency {
                    continue;
                }
                if !job.closing && job.next_slot <= job.limit {
                    let slot = job.next_slot;
                    job.next_slot += 1;
                    job.active += 1;
                    claim = Some((job.f, slot, idx));
                    break;
                }
            }
        }
        match claim {
            Some((f, slot, idx)) => {
                drop(st);
                // Catch panics so one bad session cannot kill a pool
                // thread (the pool must survive for the next solve); the
                // flag turns it into a loud per-session error.
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // SAFETY: `active` was incremented under the lock, so
                    // this session's closer is still waiting on us — the
                    // closure's stack frame is alive.
                    unsafe { (&*f.0)(slot) }
                }))
                .is_ok();
                st = shared.state.lock().unwrap();
                let job = st.sessions[idx]
                    .as_mut()
                    .expect("session closed with active worker");
                job.active -= 1;
                if !ok {
                    job.panicked = true;
                }
                shared.done.notify_all();
                // Loop around without waiting: another session may have
                // been installed while this one ran.
            }
            None => st = shared.work.wait(st).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync::atomic::AtomicBool;
    use crate::runtime::sync::model;

    #[test]
    fn caller_and_workers_all_participate() {
        let pool = MgdPool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.live_workers(), 3);
        let arrived = AtomicUsize::new(0);
        // Every slot spins until all four participants arrive, so the
        // session cannot close before each parked worker has woken,
        // claimed a slot, and entered the closure.
        pool.run(3, &|_slot| {
            arrived.fetch_add(1, Ordering::SeqCst);
            while arrived.load(Ordering::SeqCst) < 4 {
                std::thread::yield_now();
            }
        })
        .unwrap();
        assert_eq!(arrived.load(Ordering::SeqCst), 4);
        assert_eq!(pool.stats().sessions, 1);
    }

    #[test]
    fn sessions_reuse_the_same_threads() {
        let pool = MgdPool::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(2, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        // Caller always participates; workers join opportunistically.
        assert!(hits.load(Ordering::Relaxed) >= 50);
        let stats = pool.stats();
        assert_eq!(stats.sessions, 50);
        assert_eq!(stats.live, 2, "pool must not grow or shrink per solve");
        assert_eq!(stats.concurrent_sessions, 0, "no session left in flight");
    }

    #[test]
    fn concurrent_sessions_run_safely() {
        let pool = Arc::new(MgdPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    pool.run(2, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = pool.stats();
        assert_eq!(stats.sessions, 40);
        assert!(total.load(Ordering::Relaxed) >= 40);
        assert_eq!(stats.concurrent_sessions, 0);
        assert!(stats.peak_concurrency >= 1);
    }

    /// Acceptance: two sessions provably overlap in one pool. Each
    /// caller's slot 0 spins until the *other* session has arrived, so
    /// the test deadlocks (and the bounded spin fails it loudly) unless
    /// the pool really runs both sessions at once — the old serialized
    /// protocol could never pass this.
    #[test]
    fn two_sessions_overlap_and_raise_peak_concurrency() {
        let pool = Arc::new(MgdPool::new(2));
        let arrived = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            let arrived = Arc::clone(&arrived);
            handles.push(std::thread::spawn(move || {
                pool.run(1, &|slot| {
                    if slot == 0 {
                        arrived.fetch_add(1, Ordering::SeqCst);
                        let mut spins = 0u64;
                        while arrived.load(Ordering::SeqCst) < 2 {
                            std::thread::yield_now();
                            spins += 1;
                            assert!(
                                spins < 50_000_000,
                                "sessions failed to overlap (pool serialized them?)"
                            );
                        }
                    }
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = pool.stats();
        assert_eq!(stats.sessions, 2);
        assert!(
            stats.peak_concurrency >= 2,
            "overlap not recorded: {stats:?}"
        );
        assert_eq!(stats.concurrent_sessions, 0);
    }

    /// A session's slot lease caps how many workers it can claim; the
    /// rest of the pool stays claimable by a concurrently installed
    /// session (both rendezvous inside their worker slots).
    #[test]
    fn slot_leases_partition_the_workers_across_sessions() {
        let pool = Arc::new(MgdPool::new(2));
        let engaged = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            let engaged = Arc::clone(&engaged);
            handles.push(std::thread::spawn(move || {
                // Lease exactly one worker; hold the session open until a
                // worker slot of *each* session has checked in. If one
                // session could claim both workers the other would never
                // engage one, and the bounded spin fails the test.
                pool.run(1, &|slot| {
                    if slot != 0 {
                        engaged.fetch_add(1, Ordering::SeqCst);
                    }
                    let mut spins = 0u64;
                    while engaged.load(Ordering::SeqCst) < 2 {
                        std::thread::yield_now();
                        spins += 1;
                        assert!(spins < 50_000_000, "worker slots never split 1+1");
                    }
                })
                .unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(engaged.load(Ordering::SeqCst), 2);
        assert!(pool.stats().peak_concurrency >= 2);
    }

    #[test]
    fn extra_is_clamped_to_pool_size() {
        let pool = MgdPool::new(1);
        let slots = Mutex::new(Vec::new());
        pool.run(16, &|slot| {
            slots.lock().unwrap().push(slot);
        })
        .unwrap();
        let seen = slots.into_inner().unwrap();
        assert!(seen.contains(&0), "caller slot always runs");
        assert!(seen.iter().all(|&s| s <= 1), "only slots 0..=workers");
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = MgdPool::new(0);
        let hits = AtomicUsize::new(0);
        pool.run(4, &|slot| {
            assert_eq!(slot, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        assert_eq!(
            pool.stats(),
            MgdPoolStats {
                workers: 0,
                live: 0,
                sessions: 1,
                concurrent_sessions: 0,
                peak_concurrency: 1,
                reserved: 0,
            }
        );
    }

    /// A fully-reserved pool never lends a worker to a bulk session: the
    /// lease clamps to zero and the session runs caller-only, while a
    /// latency session still engages every worker.
    #[test]
    fn reserved_workers_refuse_bulk_sessions() {
        let pool = MgdPool::new_with_reserved(2, 2);
        assert_eq!(pool.reserved(), 2);
        assert_eq!(pool.claimable(RequestClass::Bulk), 0);
        assert_eq!(pool.claimable(RequestClass::Latency), 2);
        let slots = Mutex::new(Vec::new());
        pool.run(2, &|slot| {
            slots.lock().unwrap().push(slot);
        })
        .unwrap();
        assert_eq!(*slots.lock().unwrap(), vec![0], "bulk leased a reserved worker");
        // Latency sessions lease the whole pool; the rendezvous only
        // resolves if both reserved workers really join.
        let arrived = AtomicUsize::new(0);
        pool.run_with_class(2, RequestClass::Latency, &|_slot| {
            arrived.fetch_add(1, Ordering::SeqCst);
            while arrived.load(Ordering::SeqCst) < 3 {
                std::thread::yield_now();
            }
        })
        .unwrap();
        assert_eq!(arrived.load(Ordering::SeqCst), 3);
        assert_eq!(pool.stats().reserved, 2);
    }

    /// With one of two workers reserved, a bulk session saturating its
    /// lease cannot stop a concurrent latency session from engaging the
    /// reserved worker — the "bulk flood leases the pool dry" regression.
    #[test]
    fn bulk_flood_cannot_lease_the_latency_reserve() {
        let pool = Arc::new(MgdPool::new_with_reserved(2, 1));
        let latency_engaged = Arc::new(AtomicUsize::new(0));
        // Bulk session: claims its single unreserved worker and holds the
        // session open until a latency session has engaged a worker slot.
        let bulk = {
            let pool = Arc::clone(&pool);
            let latency_engaged = Arc::clone(&latency_engaged);
            std::thread::spawn(move || {
                pool.run(2, &|_slot| {
                    let mut spins = 0u64;
                    while latency_engaged.load(Ordering::SeqCst) == 0 {
                        std::thread::yield_now();
                        spins += 1;
                        assert!(
                            spins < 500_000_000,
                            "latency session never engaged the reserved worker"
                        );
                    }
                })
                .unwrap();
            })
        };
        // Latency session issued while the bulk session occupies every
        // unreserved thread: its worker slot must still run (on the
        // reserved worker), or the bulk session above spins forever.
        pool.run_with_class(1, RequestClass::Latency, &|slot| {
            if slot != 0 {
                latency_engaged.fetch_add(1, Ordering::SeqCst);
            } else {
                let mut spins = 0u64;
                while latency_engaged.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                    spins += 1;
                    assert!(spins < 500_000_000, "reserved worker never claimed the slot");
                }
            }
        })
        .unwrap();
        bulk.join().unwrap();
        assert_eq!(latency_engaged.load(Ordering::SeqCst), 1);
        assert!(pool.stats().peak_concurrency >= 2);
    }

    #[test]
    fn worker_panic_is_an_error_and_the_pool_survives() {
        let pool = MgdPool::new(2);
        let arrived = AtomicUsize::new(0);
        let res = pool.run(2, &|slot| {
            if slot == 0 {
                // Hold the session open until a worker has actually
                // claimed a slot (otherwise the panic might never fire).
                while arrived.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            } else {
                arrived.fetch_add(1, Ordering::SeqCst);
                panic!("boom");
            }
        });
        assert!(res.is_err(), "worker panic must surface as an error");
        // The pool threads survive the panic and serve the next session.
        assert_eq!(pool.live_workers(), 2);
        let ok = AtomicUsize::new(0);
        pool.run(2, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert!(ok.load(Ordering::Relaxed) >= 1);
        assert_eq!(pool.stats().concurrent_sessions, 0);
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = MgdPool::new(3);
        let live = Arc::clone(&pool.live);
        pool.run(3, &|_| {}).unwrap();
        drop(pool);
        assert_eq!(live.load(Ordering::SeqCst), 0, "shutdown leaked a thread");
    }

    /// Model-checked lease protocol (the in-tree replacement for the
    /// out-of-tree thread simulation this protocol used to rely on):
    /// across every explored interleaving of worker wakeup, slot claim,
    /// session close and pool shutdown, the session closure is never
    /// invoked after [`MgdPool::run`] returned (no dangling borrow of the
    /// caller's stack) and the caller's slot 0 always runs.
    #[test]
    fn model_session_close_never_leaves_dangling_invocations() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let alive = Arc::new(AtomicBool::new(true));
            let hits = Arc::new(AtomicUsize::new(0));
            let pool = MgdPool::new(1);
            {
                let alive = Arc::clone(&alive);
                let hits = Arc::clone(&hits);
                pool.run(1, &move |_slot| {
                    if !alive.load(Ordering::SeqCst) {
                        model::flag("session closure invoked after close");
                    }
                    hits.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            }
            // `run` has returned: the borrow it erased is dead from here
            // on, and any late invocation is a protocol bug.
            alive.store(false, Ordering::SeqCst);
            if hits.load(Ordering::SeqCst) == 0 {
                model::flag("caller slot 0 never ran");
            }
            drop(pool);
        });
        out.assert_ok();
        assert!(out.schedules > 1, "explorer found only one interleaving");
    }

    /// Seeded-mutation coverage for the model checker itself: a replica
    /// of the session protocol whose closer forgets the `closing`
    /// handshake (it uninstalls the job while a worker may still claim
    /// it) must be caught as a dangling invocation.
    #[test]
    fn model_catches_a_close_without_handshake_mutation() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let shared = Arc::new((Mutex::new(false), Condvar::new()));
            let alive = Arc::new(AtomicBool::new(true));
            let worker = {
                let shared = Arc::clone(&shared);
                let alive = Arc::clone(&alive);
                thread::spawn(move || {
                    let (job, work) = &*shared;
                    let mut installed = job.lock().unwrap();
                    while !*installed {
                        installed = work.wait(installed).unwrap();
                    }
                    drop(installed);
                    // Mutant: the "claim" happens after the closer already
                    // gave up — exactly the use-after-close the real
                    // protocol's closing/active handshake forbids.
                    if !alive.load(Ordering::SeqCst) {
                        model::flag("dangling session invocation");
                    }
                })
            };
            {
                let (job, work) = &*shared;
                *job.lock().unwrap() = true;
                work.notify_all();
            }
            // Buggy closer: no wait for the worker to drain.
            alive.store(false, Ordering::SeqCst);
            worker.join().unwrap();
        });
        out.assert_fails_with("dangling session invocation");
    }
}
