//! Persistent worker pool of the barrier-free MGD scheduler.
//!
//! [`mgd_exec`](super::mgd_exec) used to spawn scoped workers per solve
//! (`std::thread::scope`), which is fine at bench sizes but measurable on
//! tiny latency-critical solves — exactly the repeated-solve regime the
//! serving runtime targets, where the paper amortizes *all* per-matrix
//! setup across a stream of right-hand sides. [`MgdPool`] keeps the
//! workers alive instead: threads are spawned once, park on a condvar
//! between solves, and join only when the pool is dropped (graceful
//! shutdown — no detached threads, no leaks under repeated service
//! start/stop).
//!
//! # Session protocol
//!
//! One solve is one *session*: [`MgdPool::run`] installs a closure, wakes
//! the parked workers, runs slot `0` of the closure on the calling thread,
//! and returns only after every worker that joined the session has left
//! it. Workers *claim* participant slots (`1..=extra`) under the state
//! mutex; a session is closed by marking it non-claimable and waiting for
//! the active count to reach zero. Sessions serialize: the pool executes
//! one solve at a time, each using every claimed worker (concurrent
//! callers queue on the install step). That is the intended shape for a
//! shared serving pool — a solve already fans out across all cores, so
//! running two at once would just interleave their cache footprints.
//!
//! A worker that never wakes in time simply misses the session: the MGD
//! executor tolerates absent workers (their seeded deques are stolen
//! empty), so the pool never blocks on a straggler to *start* work, only
//! to *finish* it.
//!
//! # Safety
//!
//! The installed closure is stored as a lifetime-erased raw pointer so a
//! borrowing closure (the executor's, which borrows the per-solve run
//! state on the caller's stack) can cross into long-lived threads without
//! a staging copy. Soundness rests on one
//! invariant, enforced in [`MgdPool::run`] even under unwinding (a drop
//! guard closes the session if the caller's slot panics): **the call does
//! not return until no worker can observe the pointer** — the session is
//! marked closing (no new claims) and `active == 0` (no live borrows)
//! before the pointer goes out of scope.
//!
//! Memory ordering: all session state crosses threads under the state
//! `Mutex`/`Condvar` pair, which provides the happens-before edges for the
//! closure pointer and the slot claims. The `x`-slab ordering *inside* a
//! solve is the executor's counter protocol, documented in
//! `runtime/atomics.md`.

use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Point-in-time introspection of one [`MgdPool`] (leak checks, serving
/// metrics, bench reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MgdPoolStats {
    /// Worker threads this pool was built with (excludes callers, which
    /// participate in sessions on their own thread).
    pub workers: usize,
    /// Worker threads currently alive. Equal to `workers` from
    /// construction until drop; a persistent pool must never grow or
    /// shrink this across solves or service restarts.
    pub live: usize,
    /// Sessions executed through [`MgdPool::run`] since construction
    /// (including caller-only sessions that engaged no worker).
    pub sessions: u64,
}

/// Lifetime-erased session closure (`&dyn Fn(usize)` of the caller's
/// stack frame). Only ever dereferenced between a slot claim and the
/// matching `active` decrement, both of which the session-close handshake
/// orders before [`MgdPool::run`] returns.
#[derive(Clone, Copy)]
struct SessionFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointer crosses threads only under the state mutex, and the
// session protocol guarantees the pointee outlives every dereference (see
// the module-level Safety section).
unsafe impl Send for SessionFn {}

/// One installed session.
struct Job {
    f: SessionFn,
    /// Next participant slot a worker may claim (slot 0 is the caller's).
    next_slot: usize,
    /// Highest claimable slot; `limit` workers may join at most.
    limit: usize,
    /// Workers currently executing the closure.
    active: usize,
    /// Closing sessions accept no new claims (set by the session closer).
    closing: bool,
    /// A worker's closure invocation panicked (reported by `run`).
    panicked: bool,
}

/// State shared between the pool handle and its worker threads.
struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a session (or shutdown).
    work: Condvar,
    /// Session closers (and queued installers) park here waiting for
    /// `active` to drain (or the slot to free up).
    done: Condvar,
}

/// A persistent pool of parked MGD workers, shared across solves (and, in
/// the sharded service, across matrices). Construction spawns the
/// threads; drop shuts them down gracefully (wake + join).
pub struct MgdPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    live: Arc<AtomicUsize>,
    sessions: AtomicU64,
}

impl MgdPool {
    /// Spawn a pool of exactly `workers` parked threads. `0` is valid and
    /// spawns nothing: every [`MgdPool::run`] then executes on the caller
    /// alone (the serial path keeps working through the same API).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let live = Arc::new(AtomicUsize::new(workers));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let live = Arc::clone(&live);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("mgd-pool-{w}"))
                    .spawn(move || {
                        worker_loop(&shared);
                        live.fetch_sub(1, Ordering::SeqCst);
                    })
                    .expect("spawn mgd pool worker thread"),
            );
        }
        Self {
            shared,
            handles,
            live,
            sessions: AtomicU64::new(0),
        }
    }

    /// Worker threads this pool was built with.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Worker threads currently alive (see [`MgdPoolStats::live`]).
    pub fn live_workers(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Snapshot the pool counters.
    pub fn stats(&self) -> MgdPoolStats {
        MgdPoolStats {
            workers: self.workers(),
            live: self.live_workers(),
            sessions: self.sessions.load(Ordering::Relaxed),
        }
    }

    /// Execute one session: run `f(0)` on the calling thread while up to
    /// `extra` pool workers (clamped to the pool size) claim slots
    /// `1..=extra` and run `f(slot)` concurrently. Returns once **every**
    /// participant has finished — `f` may therefore borrow from the
    /// caller's stack. Errors if a worker's invocation of `f` panicked;
    /// a panic on the caller's own slot propagates (after the session is
    /// closed safely).
    ///
    /// Sessions serialize: if another session is in flight, this call
    /// parks until it fully drains.
    pub fn run<F: Fn(usize) + Sync>(&self, extra: usize, f: &F) -> Result<()> {
        self.sessions.fetch_add(1, Ordering::Relaxed);
        let extra = extra.min(self.handles.len());
        if extra == 0 {
            f(0);
            return Ok(());
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.job.is_some() {
                // Another session is draining; queue behind it.
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = Some(Job {
                f: erase(f),
                next_slot: 1,
                limit: extra,
                active: 0,
                closing: false,
                panicked: false,
            });
            drop(st);
            self.shared.work.notify_all();
        }
        // Close the session even if `f(0)` unwinds: without this, a
        // worker could later claim a slot and call through a dangling
        // pointer into a dead stack frame.
        let mut guard = SessionCloser {
            shared: &self.shared,
            armed: true,
        };
        f(0);
        guard.armed = false;
        drop(guard);
        let panicked = close_session(&self.shared);
        ensure!(!panicked, "mgd pool worker panicked during a session");
        Ok(())
    }
}

impl Drop for MgdPool {
    fn drop(&mut self) {
        // Graceful shutdown: flag, wake every parked worker, join all.
        // `&mut self` proves no session is in flight (`run` borrows the
        // pool for its full duration), so workers exit their loop at the
        // next wakeup.
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Erase the closure's borrow lifetime for storage in the shared state.
///
/// SAFETY: the returned pointer must not be dereferenced after the
/// session that carries it is closed; [`MgdPool::run`] upholds this by
/// draining the session before returning (or unwinding).
fn erase<'a>(f: &'a (dyn Fn(usize) + Sync + 'a)) -> SessionFn {
    let ptr: *const (dyn Fn(usize) + Sync + 'a) = f;
    SessionFn(unsafe {
        std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(ptr)
    })
}

/// Unwind guard of [`MgdPool::run`]: if the caller's slot-0 invocation
/// panics, the session must still be closed (and drained) before the
/// closure's stack frame dies, or a late-claiming worker would call
/// through a dangling pointer. Disarmed on the normal path, where the
/// explicit [`close_session`] call reports worker panics.
struct SessionCloser<'a> {
    shared: &'a Shared,
    armed: bool,
}

impl Drop for SessionCloser<'_> {
    fn drop(&mut self) {
        if self.armed {
            let _ = close_session(self.shared);
        }
    }
}

/// Mark the current session closing, wait for active workers to drain,
/// and uninstall it. Returns whether any worker panicked.
fn close_session(shared: &Shared) -> bool {
    let mut st = shared.state.lock().unwrap();
    match st.job.as_mut() {
        Some(job) => job.closing = true,
        None => return false,
    }
    while st.job.as_ref().is_some_and(|j| j.active > 0) {
        st = shared.done.wait(st).unwrap();
    }
    let job = st.job.take().expect("closing session vanished");
    drop(st);
    // Wake sessions queued on the install step.
    shared.done.notify_all();
    job.panicked
}

fn worker_loop(shared: &Shared) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        let claim = match st.job.as_mut() {
            Some(job) if !job.closing && job.next_slot <= job.limit => {
                let slot = job.next_slot;
                job.next_slot += 1;
                job.active += 1;
                Some((job.f, slot))
            }
            _ => None,
        };
        match claim {
            Some((f, slot)) => {
                drop(st);
                // Catch panics so one bad session cannot kill a pool
                // thread (the pool must survive for the next solve); the
                // flag turns it into a loud per-session error.
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    // SAFETY: `active` was incremented under the lock, so
                    // the session closer is still waiting on us — the
                    // closure's stack frame is alive.
                    unsafe { (&*f.0)(slot) }
                }))
                .is_ok();
                st = shared.state.lock().unwrap();
                let job = st.job.as_mut().expect("session closed with active worker");
                job.active -= 1;
                if !ok {
                    job.panicked = true;
                }
                shared.done.notify_all();
            }
            None => st = shared.work.wait(st).unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn caller_and_workers_all_participate() {
        let pool = MgdPool::new(3);
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.live_workers(), 3);
        let arrived = AtomicUsize::new(0);
        // Every slot spins until all four participants arrive, so the
        // session cannot close before each parked worker has woken,
        // claimed a slot, and entered the closure.
        pool.run(3, &|_slot| {
            arrived.fetch_add(1, Ordering::SeqCst);
            while arrived.load(Ordering::SeqCst) < 4 {
                std::thread::yield_now();
            }
        })
        .unwrap();
        assert_eq!(arrived.load(Ordering::SeqCst), 4);
        assert_eq!(pool.stats().sessions, 1);
    }

    #[test]
    fn sessions_reuse_the_same_threads() {
        let pool = MgdPool::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(2, &|_| {
                hits.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        // Caller always participates; workers join opportunistically.
        assert!(hits.load(Ordering::Relaxed) >= 50);
        let stats = pool.stats();
        assert_eq!(stats.sessions, 50);
        assert_eq!(stats.live, 2, "pool must not grow or shrink per solve");
    }

    #[test]
    fn concurrent_sessions_serialize_safely() {
        let pool = Arc::new(MgdPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    pool.run(2, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    })
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.stats().sessions, 40);
        assert!(total.load(Ordering::Relaxed) >= 40);
    }

    #[test]
    fn extra_is_clamped_to_pool_size() {
        let pool = MgdPool::new(1);
        let slots = Mutex::new(Vec::new());
        pool.run(16, &|slot| {
            slots.lock().unwrap().push(slot);
        })
        .unwrap();
        let seen = slots.into_inner().unwrap();
        assert!(seen.contains(&0), "caller slot always runs");
        assert!(seen.iter().all(|&s| s <= 1), "only slots 0..=workers");
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = MgdPool::new(0);
        let hits = AtomicUsize::new(0);
        pool.run(4, &|slot| {
            assert_eq!(slot, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::Relaxed), 1);
        assert_eq!(pool.stats(), MgdPoolStats { workers: 0, live: 0, sessions: 1 });
    }

    #[test]
    fn worker_panic_is_an_error_and_the_pool_survives() {
        let pool = MgdPool::new(2);
        let arrived = AtomicUsize::new(0);
        let res = pool.run(2, &|slot| {
            if slot == 0 {
                // Hold the session open until a worker has actually
                // claimed a slot (otherwise the panic might never fire).
                while arrived.load(Ordering::SeqCst) == 0 {
                    std::thread::yield_now();
                }
            } else {
                arrived.fetch_add(1, Ordering::SeqCst);
                panic!("boom");
            }
        });
        assert!(res.is_err(), "worker panic must surface as an error");
        // The pool threads survive the panic and serve the next session.
        assert_eq!(pool.live_workers(), 2);
        let ok = AtomicUsize::new(0);
        pool.run(2, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        })
        .unwrap();
        assert!(ok.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn drop_joins_every_worker() {
        let pool = MgdPool::new(3);
        let live = Arc::clone(&pool.live);
        pool.run(3, &|_| {}).unwrap();
        drop(pool);
        assert_eq!(live.load(Ordering::SeqCst), 0, "shutdown leaked a thread");
    }
}
