//! The static abstract interpreter gating the unchecked tier.
//!
//! [`verify`] replays every [`NodeProgram`] symbolically against the
//! [`MgdPlan`] it claims to implement and discharges the lemmas the
//! interpreter's `unsafe` blocks cite:
//!
//! - `gather-window` — every `Gather` reads `src_row < n` and writes
//!   `dst < scratch_len`;
//! - `mac-window` — every MAC reads inside its scratch / psum window;
//! - `def-before-use` — every scratch read is preceded by its `Gather`,
//!   every psum read by the producing row's `StorePsum`; psum slots and
//!   `x[row]` are written exactly once (single-write);
//! - `row-window` — every `Div` / `StoreX` row lies in the node's
//!   window, and the window lies inside the matrix order;
//! - `diag-nonzero` — every `LoadDiag` bakes a finite nonzero value that
//!   is bit-identical to the plan's diagonal;
//! - CSR order — each row's MAC sequence is exactly the plan's packed
//!   edge list, in CSR order with bit-identical coefficients (the
//!   bitwise-vs-serial obligation);
//! - cross-node effects — the gather sequence is exactly the plan's
//!   ICR-ordered `ext` list and every window row is published, so the
//!   program's external reads and writes match the predecessor counters
//!   and successor lists the DAG schedule was built from.
//!
//! The verifier is pure and runs off the hot path (once per matrix at
//! registration); rejection messages are stable substrings the CLI and
//! tests assert on.

use super::super::mgd_plan::{LOCAL_BIT, MgdNode, MgdPlan};
use super::{KOp, KernelProgram, NodeProgram};
use anyhow::{bail, ensure, Context, Result};

/// Where the abstract interpreter stands inside the current row's
/// mandatory epilogue (`LoadDiag` → `Div` → `StorePsum` → `StoreX`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    /// Accumulating the row's MACs (or between rows / in the gather
    /// prefix).
    Macs,
    /// Diagonal loaded, divide pending.
    Diaged,
    /// Row solved into the accumulator register, stores pending.
    Dived,
    /// Psum parked; the publishing `StoreX` must follow.
    PsumStored,
}

/// Statically verify that `prog` is a faithful, in-bounds lowering of
/// `plan`. `Ok(())` is the proof the unchecked interpreter relies on;
/// `Err` carries the first violated obligation (distinct message per
/// corruption kind).
pub fn verify(prog: &KernelProgram, plan: &MgdPlan) -> Result<()> {
    ensure!(
        prog.n == plan.n,
        "program order {} != plan order {}",
        prog.n,
        plan.n
    );
    ensure!(
        prog.nodes.len() == plan.nodes.len(),
        "program has {} node programs, plan has {} nodes",
        prog.nodes.len(),
        plan.nodes.len()
    );
    for (k, (np, nd)) in prog.nodes.iter().zip(&plan.nodes).enumerate() {
        verify_node(np, nd, plan.n).with_context(|| {
            format!(
                "node {k} (rows {}..{})",
                nd.first_row,
                nd.first_row as usize + nd.rows as usize
            )
        })?;
    }
    Ok(())
}

fn verify_node(np: &NodeProgram, nd: &MgdNode, n: usize) -> Result<()> {
    ensure!(
        np.first_row == nd.first_row && np.rows == nd.rows,
        "program window {}+{} != plan window {}+{}",
        np.first_row,
        np.rows,
        nd.first_row,
        nd.rows
    );
    let first = nd.first_row as usize;
    let rows = nd.rows as usize;
    let ext_len = nd.ext.len();
    ensure!(
        np.scratch_len as usize == ext_len,
        "scratch window {} != plan ICR gather list length {ext_len}",
        np.scratch_len
    );
    // Lemma row-window (outer half): the node's whole row window lies
    // inside the matrix order, so any in-window row index is `< n`.
    ensure!(
        first + rows <= n,
        "row window {first}..{} out of bounds of order {n}",
        first + rows
    );

    let mut stage = Stage::Macs;
    let mut gathers = 0usize; // gather prefix length consumed so far
    let mut rows_done = 0usize;
    let mut edge = 0usize; // MACs seen in the current row
    let mut scratch_def = vec![false; ext_len];
    let mut psum_def = vec![false; rows];
    let mut x_def = vec![false; rows];

    for (pc, op) in np.ops.iter().enumerate() {
        match *op {
            KOp::Gather { src_row, dst } => {
                ensure!(
                    stage == Stage::Macs && rows_done == 0 && edge == 0,
                    "op {pc}: Gather after row work began — gathers must prefix the program"
                );
                // Lemma gather-window: both halves checked before the
                // slot is marked defined.
                ensure!(
                    (dst as usize) < ext_len,
                    "op {pc}: Gather dst slot {dst} out of bounds of scratch window {ext_len}"
                );
                ensure!(
                    (src_row as usize) < n,
                    "op {pc}: Gather source row {src_row} out of bounds of order {n}"
                );
                ensure!(
                    dst as usize == gathers,
                    "op {pc}: Gather dst {dst} out of ICR order (expected slot {gathers})"
                );
                ensure!(
                    src_row == nd.ext[gathers],
                    "op {pc}: Gather {gathers} loads row {src_row} but the plan's ICR gather \
                     list names row {} — the cross-node dependency set would diverge from \
                     the predecessor counters",
                    nd.ext[gathers]
                );
                scratch_def[gathers] = true;
                gathers += 1;
            }
            KOp::MacExt { coeff, src } => {
                ensure!(
                    stage == Stage::Macs,
                    "op {pc}: MacExt inside a row epilogue ({stage:?})"
                );
                ensure!(rows_done < rows, "op {pc}: MacExt after the window's last row");
                // Lemma mac-window, then lemma def-before-use — bounds
                // first so the def lookup itself cannot trap.
                ensure!(
                    (src as usize) < ext_len,
                    "op {pc}: MacExt scratch slot {src} out of bounds of gather window {ext_len}"
                );
                ensure!(
                    scratch_def[src as usize],
                    "op {pc}: MacExt reads scratch slot {src} before any Gather defines it"
                );
                check_edge(nd, rows_done, edge, false, src, coeff)
                    .with_context(|| format!("op {pc}"))?;
                edge += 1;
            }
            KOp::MacLocal { coeff, src } => {
                ensure!(
                    stage == Stage::Macs,
                    "op {pc}: MacLocal inside a row epilogue ({stage:?})"
                );
                ensure!(rows_done < rows, "op {pc}: MacLocal after the window's last row");
                ensure!(
                    (src as usize) < rows,
                    "op {pc}: MacLocal psum slot {src} out of bounds of node window {rows}"
                );
                ensure!(
                    psum_def[src as usize],
                    "op {pc}: MacLocal reads psum slot {src} before any row defines it"
                );
                check_edge(nd, rows_done, edge, true, src, coeff)
                    .with_context(|| format!("op {pc}"))?;
                edge += 1;
            }
            KOp::LoadDiag { diag } => {
                ensure!(
                    stage == Stage::Macs,
                    "op {pc}: LoadDiag inside a row epilogue ({stage:?})"
                );
                ensure!(rows_done < rows, "op {pc}: LoadDiag after the window's last row");
                let lo = nd.edge_ptr[rows_done] as usize;
                let hi = nd.edge_ptr[rows_done + 1] as usize;
                ensure!(
                    edge == hi - lo,
                    "op {pc}: row {rows_done} reduces {edge} edges but the plan's CSR row \
                     has {} — the CSR reduction order is not preserved",
                    hi - lo
                );
                // Lemma diag-nonzero precedes the bit comparison so a
                // zeroed bake gets its own message, not a mismatch one.
                ensure!(
                    diag.is_finite() && diag != 0.0,
                    "op {pc}: baked diagonal {diag} must be finite and nonzero"
                );
                ensure!(
                    diag.to_bits() == nd.diag[rows_done].to_bits(),
                    "op {pc}: baked diagonal {diag} != plan diagonal {}",
                    nd.diag[rows_done]
                );
                stage = Stage::Diaged;
            }
            KOp::Div { row } => {
                ensure!(
                    stage == Stage::Diaged,
                    "op {pc}: Div without a preceding LoadDiag ({stage:?})"
                );
                // Lemma row-window (inner half): the divide reads
                // `b[row]` for exactly the current in-window row.
                ensure!(
                    row as usize == first + rows_done,
                    "op {pc}: Div row {row} != expected row {}",
                    first + rows_done
                );
                stage = Stage::Dived;
            }
            KOp::StorePsum { dst } => {
                ensure!(
                    (dst as usize) < rows,
                    "op {pc}: StorePsum slot {dst} out of bounds of node window {rows}"
                );
                ensure!(
                    !psum_def[dst as usize],
                    "op {pc}: psum slot {dst} written twice — single-write per slot violated"
                );
                ensure!(
                    stage == Stage::Dived,
                    "op {pc}: StorePsum before the row's Div ({stage:?})"
                );
                ensure!(
                    dst as usize == rows_done,
                    "op {pc}: StorePsum slot {dst} != current row {rows_done}"
                );
                psum_def[dst as usize] = true;
                stage = Stage::PsumStored;
            }
            KOp::StoreX { row } => {
                let r = match (row as usize).checked_sub(first) {
                    Some(r) if r < rows => r,
                    _ => bail!(
                        "op {pc}: StoreX row {row} out of bounds of window {first}..{}",
                        first + rows
                    ),
                };
                ensure!(
                    !x_def[r],
                    "op {pc}: x[{row}] written twice — single-write per row violated"
                );
                ensure!(
                    stage == Stage::PsumStored,
                    "op {pc}: StoreX before the row's psum store ({stage:?})"
                );
                ensure!(
                    r == rows_done,
                    "op {pc}: StoreX row {row} != current row {}",
                    first + rows_done
                );
                x_def[r] = true;
                rows_done += 1;
                edge = 0;
                stage = Stage::Macs;
            }
        }
    }

    ensure!(stage == Stage::Macs, "node program ends mid-row ({stage:?})");
    // Cross-node effects, read side: the gather prefix consumed the
    // plan's ICR gather list exactly (order and rows already matched op
    // by op above — this closes the length).
    ensure!(
        gathers == ext_len,
        "only {gathers} of the plan's {ext_len} ICR gather list entries are loaded — the \
         cross-node dependency set would diverge from the predecessor counters"
    );
    // Write side: every window row published, so successors decremented
    // by this node observe every operand they gather.
    ensure!(
        rows_done == rows,
        "only {rows_done} of {rows} window rows are solved and published"
    );
    Ok(())
}

/// One MAC checked against the plan's packed edge list: same operand
/// kind, same slot, bit-identical coefficient, exactly at CSR position
/// `edge` of row `row` — any divergence breaks the bitwise-vs-serial
/// reduction-order contract.
fn check_edge(
    nd: &MgdNode,
    row: usize,
    edge: usize,
    local: bool,
    src: u32,
    coeff: f32,
) -> Result<()> {
    let lo = nd.edge_ptr[row] as usize;
    let hi = nd.edge_ptr[row + 1] as usize;
    ensure!(
        lo + edge < hi,
        "row {row} reduces more than the plan's {} CSR edges — the CSR reduction order is \
         not preserved",
        hi - lo
    );
    let want_slot = nd.edge_slot[lo + edge];
    let want_local = want_slot & LOCAL_BIT != 0;
    let want_src = want_slot & !LOCAL_BIT;
    let want_coeff = nd.edge_val[lo + edge];
    ensure!(
        local == want_local && src == want_src && coeff.to_bits() == want_coeff.to_bits(),
        "row {row} edge {edge} ({} slot {src}, coeff {coeff}) diverges from the plan's CSR \
         reduction order ({} slot {want_src}, coeff {want_coeff})",
        if local { "local" } else { "ext" },
        if want_local { "local" } else { "ext" }
    );
    Ok(())
}
