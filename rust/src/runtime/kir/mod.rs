//! Verified kernel IR: per-matrix bytecode lowered from an [`MgdPlan`],
//! statically verified, then executed by an unchecked interpreter.
//!
//! This is the first rung of the roadmap's JIT ladder: instead of walking
//! the plan's SoA layout at run time (bounds checks, `LOCAL_BIT` branch
//! per edge), [`lower`] flattens every medium node into a straight-line
//! [`NodeProgram`] with all indices and coefficients baked in. The node
//! DAG, dependency counters and pool scheduling are untouched — only the
//! per-node inner loop changes tier.
//!
//! An unchecked fast path is only shippable behind a proof, so the module
//! is structured as verify-then-trust (the same shape as
//! [`MgdPlan::verify`] and the sync model checker):
//!
//! 1. [`lower`] — `MgdPlan` → [`KernelProgram`], pure data transform;
//! 2. [`verify`] — a static abstract interpreter that replays every
//!    program against the plan and proves, per node: all loads/stores in
//!    bounds of their SoA windows, def-before-use and single-write per
//!    psum slot and per `x[row]`, divides only by the plan's finite
//!    nonzero diagonal, the CSR reduction order preserved per row (the
//!    bitwise-vs-serial obligation), and the gather list identical to the
//!    plan's ICR external-row list so the cross-node effects match the
//!    predecessor counters and successor lists exactly;
//! 3. the interpreter (`interp`, crate-private) — executes with unchecked
//!    indexing, every `unsafe` discharged by a named verifier lemma.
//!
//! [`VerifiedKernel`] is the gate between 2 and 3: the only constructor
//! runs `lower` + `verify`, and the unchecked executor entry points
//! ([`execute_kernel`](crate::runtime::mgd_exec::execute_kernel)) accept
//! nothing else. A verification failure is an `Err` the caller maps to a
//! fallback onto the checked `mgd` tier — never a panic, never UB.
//!
//! Seeded corruptions ([`corrupt_program`], `mgd check-ir --corrupt ...`)
//! prove each obligation is actually load-bearing: every kind must be
//! rejected with a distinct message.
//!
//! # Example
//!
//! ```
//! use std::sync::Arc;
//! use mgd_sptrsv::matrix::gen::{self, GenSeed};
//! use mgd_sptrsv::matrix::triangular::solve_serial;
//! use mgd_sptrsv::runtime::kir::VerifiedKernel;
//! use mgd_sptrsv::runtime::{mgd_exec, MgdPlan, MgdPlanConfig};
//!
//! let m = gen::circuit(300, 4, 0.8, GenSeed(7));
//! let plan = Arc::new(MgdPlan::build(&m, MgdPlanConfig::default()));
//!
//! // Lower + statically verify once, then execute on the unchecked tier.
//! let kernel = VerifiedKernel::build(&plan).unwrap();
//! let b: Vec<f32> = (0..m.n).map(|i| (i % 5) as f32 - 2.0).collect();
//! let (xs, _) = mgd_exec::execute_kernel(&kernel, &[b.clone()], 4).unwrap();
//!
//! let want = solve_serial(&m, &b);
//! for i in 0..m.n {
//!     assert_eq!(xs[0][i].to_bits(), want[i].to_bits());
//! }
//! ```

mod interp;
mod verify;

pub(crate) use self::interp::run_node_program;
pub use self::verify::verify;

use super::mgd_plan::{LOCAL_BIT, MgdPlan};
use anyhow::{bail, Context, Result};
use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

/// One bytecode instruction of a [`NodeProgram`]. All indices and
/// coefficients are baked at lowering time; the verified interpreter
/// executes them with unchecked indexing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KOp {
    /// Load `x[src_row]` from the shared slab into scratch slot `dst`
    /// (one entry of the node's ICR-ordered external gather).
    Gather {
        /// Absolute source row in the shared `x` slab (`< n`).
        src_row: u32,
        /// Destination scratch slot (`< scratch_len`).
        dst: u32,
    },
    /// `acc += coeff * scratch[src]` — an external-operand MAC.
    MacExt {
        /// Baked edge coefficient (`L_ij` in CSR order).
        coeff: f32,
        /// Scratch slot holding the gathered external operand.
        src: u32,
    },
    /// `acc += coeff * psum[src]` — an intra-node MAC.
    MacLocal {
        /// Baked edge coefficient (`L_ij` in CSR order).
        coeff: f32,
        /// Node-local psum slot of the operand row.
        src: u32,
    },
    /// Load the row's baked diagonal into the divisor register.
    LoadDiag {
        /// Baked diagonal value (finite and nonzero, proven by `verify`).
        diag: f32,
    },
    /// `t = (b[row] - acc) / diag; acc = 0` — close the row reduction.
    Div {
        /// Absolute row of the RHS entry (`first_row + r`).
        row: u32,
    },
    /// Park the row result in the node-local psum slab.
    StorePsum {
        /// Node-local psum slot (`== r` for in-node row `r`).
        dst: u32,
    },
    /// Publish the row result to the shared `x` slab.
    StoreX {
        /// Absolute destination row (`first_row + r`).
        row: u32,
    },
}

/// Straight-line bytecode for one medium node: the external gathers, then
/// per row its CSR-ordered MACs, diagonal load, divide and the two
/// stores. Same window as the plan node it was lowered from.
#[derive(Debug, Clone)]
pub struct NodeProgram {
    /// First absolute row of the node's contiguous window.
    pub first_row: u32,
    /// Rows in the window.
    pub rows: u32,
    /// Scratch slots the gathers fill (`== ext.len()` of the plan node).
    pub scratch_len: u32,
    /// The instruction sequence.
    pub ops: Vec<KOp>,
}

/// A lowered [`MgdPlan`]: one [`NodeProgram`] per medium node, same node
/// ids, same DAG. Produced by [`lower`]; trusted for unchecked execution
/// only behind [`VerifiedKernel`].
#[derive(Debug, Clone)]
pub struct KernelProgram {
    /// Matrix order (`== plan.n`).
    pub n: usize,
    /// One program per plan node, index-aligned with `plan.nodes`.
    pub nodes: Vec<NodeProgram>,
}

impl KernelProgram {
    /// Total instruction count across all node programs.
    pub fn num_ops(&self) -> usize {
        self.nodes.iter().map(|p| p.ops.len()).sum()
    }

    /// Total external gathers across all node programs.
    pub fn num_gathers(&self) -> usize {
        self.nodes
            .iter()
            .map(|p| {
                p.ops
                    .iter()
                    .filter(|o| matches!(o, KOp::Gather { .. }))
                    .count()
            })
            .sum()
    }
}

/// Lower every medium node of `plan` into straight-line bytecode with all
/// indices baked. Pure data transform — the result is only trusted for
/// unchecked execution after [`verify`] accepts it
/// ([`VerifiedKernel::build`] does both).
pub fn lower(plan: &MgdPlan) -> KernelProgram {
    let nodes = plan
        .nodes
        .iter()
        .map(|nd| {
            let rows = nd.rows as usize;
            let mut ops = Vec::with_capacity(nd.ext.len() + nd.edge_val.len() + 4 * rows);
            for (i, &src_row) in nd.ext.iter().enumerate() {
                ops.push(KOp::Gather {
                    src_row,
                    dst: i as u32,
                });
            }
            for r in 0..rows {
                let lo = nd.edge_ptr[r] as usize;
                let hi = nd.edge_ptr[r + 1] as usize;
                for e in lo..hi {
                    let slot = nd.edge_slot[e];
                    let coeff = nd.edge_val[e];
                    if slot & LOCAL_BIT != 0 {
                        ops.push(KOp::MacLocal {
                            coeff,
                            src: slot & !LOCAL_BIT,
                        });
                    } else {
                        ops.push(KOp::MacExt { coeff, src: slot });
                    }
                }
                ops.push(KOp::LoadDiag { diag: nd.diag[r] });
                ops.push(KOp::Div {
                    row: nd.first_row + r as u32,
                });
                ops.push(KOp::StorePsum { dst: r as u32 });
                ops.push(KOp::StoreX {
                    row: nd.first_row + r as u32,
                });
            }
            NodeProgram {
                first_row: nd.first_row,
                rows: nd.rows,
                scratch_len: nd.ext.len() as u32,
                ops,
            }
        })
        .collect();
    KernelProgram { n: plan.n, nodes }
}

/// A [`KernelProgram`] proven safe by [`verify`], paired with the plan it
/// was lowered from. This type is the gate in front of the unchecked
/// interpreter: its only constructor runs the verifier, the interpreter
/// itself is crate-private, and the executor entry points
/// ([`execute_kernel`](crate::runtime::mgd_exec::execute_kernel),
/// [`execute_kernel_on_class`](crate::runtime::mgd_exec::execute_kernel_on_class))
/// accept only `&VerifiedKernel`.
pub struct VerifiedKernel {
    plan: Arc<MgdPlan>,
    program: KernelProgram,
}

impl VerifiedKernel {
    /// Lower `plan` and statically verify the result. The `Err` carries
    /// the verifier's rejection; callers treat it as "stay on the checked
    /// `mgd` tier", never as a fatal solve error.
    pub fn build(plan: &Arc<MgdPlan>) -> Result<Self> {
        let program = lower(plan);
        verify(&program, plan).context("kernel-IR verification")?;
        Ok(Self {
            plan: Arc::clone(plan),
            program,
        })
    }

    /// The plan the program was lowered from (node DAG, dependency
    /// counters and pool sizing still come from here).
    pub fn plan(&self) -> &Arc<MgdPlan> {
        &self.plan
    }

    /// The verified bytecode.
    pub fn program(&self) -> &KernelProgram {
        &self.program
    }
}

/// Seeded corruption kinds for `mgd check-ir --corrupt` and the rejection
/// tests: each targets one verifier obligation and must be rejected with
/// a distinct message (the PR-6 acceptance style).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptKind {
    /// Point a MAC at an out-of-window operand slot.
    Oob,
    /// Duplicate a `StoreX`, violating single-write per `x[row]`.
    DoubleWrite,
    /// Swap two adjacent MACs, breaking the CSR reduction order.
    CsrOrder,
    /// Drop a `Gather`, leaving a scratch slot read undefined.
    DeadSlot,
    /// Bake a zero diagonal into a `LoadDiag`.
    ZeroDiag,
    /// Re-point a `Gather` at the wrong source row, diverging from the
    /// plan's ICR gather list (the cross-node dependency set).
    Deps,
}

impl FromStr for CorruptKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "oob" => Self::Oob,
            "double-write" => Self::DoubleWrite,
            "csr-order" => Self::CsrOrder,
            "dead-slot" => Self::DeadSlot,
            "zero-diag" => Self::ZeroDiag,
            "deps" => Self::Deps,
            other => bail!(
                "unknown corruption {other:?} (expected \
                 oob|double-write|csr-order|dead-slot|zero-diag|deps)"
            ),
        })
    }
}

impl fmt::Display for CorruptKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Self::Oob => "oob",
            Self::DoubleWrite => "double-write",
            Self::CsrOrder => "csr-order",
            Self::DeadSlot => "dead-slot",
            Self::ZeroDiag => "zero-diag",
            Self::Deps => "deps",
        })
    }
}

/// Mutate `prog` with one seeded `kind` corruption (for `mgd check-ir
/// --corrupt` and the rejection tests). Errors if the program offers no
/// site for the kind — e.g. no node gathers two external rows for
/// [`CorruptKind::Deps`].
pub fn corrupt_program(prog: &mut KernelProgram, kind: CorruptKind) -> Result<()> {
    match kind {
        CorruptKind::Oob => {
            for np in &mut prog.nodes {
                for op in &mut np.ops {
                    if let KOp::MacExt { src, .. } | KOp::MacLocal { src, .. } = op {
                        *src = u32::MAX;
                        return Ok(());
                    }
                }
            }
            bail!("matrix too small to corrupt: no MAC to point out of its window");
        }
        CorruptKind::DoubleWrite => {
            for np in &mut prog.nodes {
                if let Some(pos) = np.ops.iter().position(|o| matches!(o, KOp::StoreX { .. })) {
                    let dup = np.ops[pos];
                    np.ops.insert(pos + 1, dup);
                    return Ok(());
                }
            }
            bail!("matrix too small to corrupt: no StoreX to duplicate");
        }
        CorruptKind::CsrOrder => {
            fn is_mac(op: &KOp) -> bool {
                matches!(op, KOp::MacExt { .. } | KOp::MacLocal { .. })
            }
            for np in &mut prog.nodes {
                for i in 0..np.ops.len().saturating_sub(1) {
                    // Adjacent MACs always belong to the same row (rows end
                    // in LoadDiag/Div/stores); an equal pair would swap into
                    // a no-op, so require a distinguishable pair.
                    if is_mac(&np.ops[i]) && is_mac(&np.ops[i + 1]) && np.ops[i] != np.ops[i + 1] {
                        np.ops.swap(i, i + 1);
                        return Ok(());
                    }
                }
            }
            bail!("matrix too small to corrupt: no row reduces two distinct edges");
        }
        CorruptKind::DeadSlot => {
            for np in &mut prog.nodes {
                // Drop the node's last gather: the plan references every
                // ext entry from at least one edge, so some MacExt now
                // reads the slot before anything defines it.
                if let Some(last) = np.ops.iter().rposition(|o| matches!(o, KOp::Gather { .. })) {
                    np.ops.remove(last);
                    return Ok(());
                }
            }
            bail!("matrix too small to corrupt: no Gather to drop");
        }
        CorruptKind::ZeroDiag => {
            for np in &mut prog.nodes {
                for op in &mut np.ops {
                    if let KOp::LoadDiag { diag } = op {
                        *diag = 0.0;
                        return Ok(());
                    }
                }
            }
            bail!("matrix too small to corrupt: no LoadDiag to zero");
        }
        CorruptKind::Deps => {
            for np in &mut prog.nodes {
                let gathers: Vec<usize> = np
                    .ops
                    .iter()
                    .enumerate()
                    .filter(|(_, o)| matches!(o, KOp::Gather { .. }))
                    .map(|(i, _)| i)
                    .collect();
                if gathers.len() >= 2 {
                    let KOp::Gather { src_row: wrong, .. } = np.ops[gathers[1]] else {
                        unreachable!("filtered to gathers above");
                    };
                    // The ext list is strictly ascending, so pointing the
                    // first gather at the second's row always diverges.
                    if let KOp::Gather { src_row, .. } = &mut np.ops[gathers[0]] {
                        *src_row = wrong;
                    }
                    return Ok(());
                }
            }
            bail!("matrix too small to corrupt: no node gathers two external rows");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::matrix::triangular::solve_serial;
    use crate::runtime::mgd_exec;
    use crate::runtime::mgd_plan::MgdPlanConfig;

    fn rhs_batch(n: usize, count: usize) -> Vec<Vec<f32>> {
        (0..count)
            .map(|k| (0..n).map(|i| ((i + 3 * k) % 9) as f32 - 4.0).collect())
            .collect()
    }

    /// Lowering is total and verified over the whole generator suite, and
    /// the op census matches the plan exactly: one gather per ext entry,
    /// one MAC per packed edge, and a fixed 4-op row epilogue.
    #[test]
    fn lowering_is_verified_across_generators() {
        for (name, m) in &gen::test_suite() {
            let plan = MgdPlan::build(m, MgdPlanConfig::default());
            let prog = lower(&plan);
            verify(&prog, &plan).unwrap_or_else(|e| panic!("{name}: rejected: {e:#}"));
            assert_eq!(prog.n, m.n);
            assert_eq!(prog.nodes.len(), plan.num_nodes());
            let edges: usize = plan.nodes.iter().map(|nd| nd.edge_val.len()).sum();
            let exts: usize = plan.nodes.iter().map(|nd| nd.ext.len()).sum();
            assert_eq!(prog.num_gathers(), exts, "{name}: gather census");
            assert_eq!(prog.num_ops(), exts + edges + 4 * m.n, "{name}: op census");
        }
    }

    /// Property test (tentpole acceptance): the verified interpreter is
    /// **bitwise identical** to the serial reference for all 8 generator
    /// families × threads {1, 2, 8} × RHS batches {1, 3, 11}.
    #[test]
    fn kir_interpreter_matches_reference() {
        for (name, m) in &gen::test_suite() {
            let plan = Arc::new(MgdPlan::build(m, MgdPlanConfig::default()));
            let kernel = VerifiedKernel::build(&plan)
                .unwrap_or_else(|e| panic!("{name}: verifier rejected lowered plan: {e:#}"));
            for threads in [1usize, 2, 8] {
                for count in [1usize, 3, 11] {
                    let bs = rhs_batch(m.n, count);
                    let (xs, stats) = mgd_exec::execute_kernel(&kernel, &bs, threads).unwrap();
                    assert_eq!(xs.len(), count);
                    assert_eq!(stats.nodes_executed, plan.num_nodes() as u64);
                    for (b, x) in bs.iter().zip(&xs) {
                        let want = solve_serial(m, b);
                        for i in 0..m.n {
                            assert_eq!(
                                x[i].to_bits(),
                                want[i].to_bits(),
                                "{name}: threads={threads} batch={count} row {i}: \
                                 {} != {}",
                                x[i],
                                want[i],
                            );
                        }
                    }
                }
            }
        }
    }

    /// Every seeded corruption kind is rejected, and each with its own
    /// distinct message (so `mgd check-ir --corrupt` failures are
    /// diagnosable). Some kinds need structure (two gathers in one node,
    /// two distinct edges in one row) that not every generator offers, so
    /// each kind scans the suite for its first viable site.
    #[test]
    fn corruption_kinds_are_rejected_with_distinct_messages() {
        let suite = gen::test_suite();
        let kinds: [(CorruptKind, &str); 6] = [
            (CorruptKind::Oob, "out of bounds"),
            (CorruptKind::DoubleWrite, "written twice"),
            (CorruptKind::CsrOrder, "CSR reduction order"),
            (CorruptKind::DeadSlot, "defines it"),
            (CorruptKind::ZeroDiag, "finite and nonzero"),
            (CorruptKind::Deps, "ICR gather list"),
        ];
        for (kind, needle) in kinds {
            let mut rejected = false;
            for (name, m) in &suite {
                let plan = MgdPlan::build(m, MgdPlanConfig::default());
                let mut prog = lower(&plan);
                if corrupt_program(&mut prog, kind).is_err() {
                    continue; // no site for this kind in this matrix
                }
                let err = verify(&prog, &plan)
                    .expect_err(&format!("{name}: verifier accepted '{kind}' corruption"));
                let msg = format!("{err:#}");
                assert!(
                    msg.contains(needle),
                    "{name}: '{kind}' rejection {msg:?} lacks needle {needle:?}"
                );
                rejected = true;
                break;
            }
            assert!(rejected, "no suite matrix offered a '{kind}' corruption site");
        }
    }

    #[test]
    fn corrupt_kind_parses_and_displays() {
        use CorruptKind::*;
        for kind in [Oob, DoubleWrite, CsrOrder, DeadSlot, ZeroDiag, Deps] {
            let s = kind.to_string();
            assert_eq!(s.parse::<CorruptKind>().unwrap(), kind, "{s}");
        }
        let err = "nope".parse::<CorruptKind>().unwrap_err();
        assert!(format!("{err}").contains("expected oob|double-write"));
    }
}
