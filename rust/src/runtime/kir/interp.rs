//! The unchecked interpreter tier: executes verifier-accepted
//! [`NodeProgram`]s with unchecked indexing.
//!
//! Reachable only through [`VerifiedKernel`](super::VerifiedKernel) (the
//! executor entry points in [`mgd_exec`](crate::runtime::mgd_exec) accept
//! nothing else), so every program running here has passed
//! [`verify`](super::verify()). Each `unsafe` block cites the verifier
//! lemma that discharges it — see the lemma list in `kir::verify`'s
//! module docs. The memory-ordering protocol is unchanged from the
//! checked `run_node` path: same Relaxed data accesses ordered by the
//! scheduler's Release/Acquire dependency counters (runtime/atomics.md).

use super::{KOp, NodeProgram};
use crate::runtime::sync::atomic::{AtomicU32, Ordering};

/// Execute one node program for every RHS in `bs`. Drop-in replacement
/// for `mgd_exec::run_node` on the same scheduler: identical arithmetic
/// in identical order (the verifier's CSR-order obligation), so results
/// stay bitwise equal to the serial reference.
///
/// Callers guarantee `b.len() == n` for every RHS and `x.len() == bs.len()
/// * n` (checked once per solve in `mgd_exec::execute_impl`); the
/// verifier guarantees every baked index below.
pub(crate) fn run_node_program<B: AsRef<[f32]>>(
    n: usize,
    node: &NodeProgram,
    bs: &[B],
    x: &[AtomicU32],
    scratch: &mut Vec<f32>,
    local: &mut Vec<f32>,
) {
    let scratch_len = node.scratch_len as usize;
    let rows = node.rows as usize;
    for (k, b) in bs.iter().enumerate() {
        let b = b.as_ref();
        debug_assert_eq!(b.len(), n);
        let xk = &x[k * n..(k + 1) * n];
        scratch.clear();
        scratch.resize(scratch_len, 0.0);
        local.clear();
        local.resize(rows, 0.0);
        // The three interpreter registers: the row accumulator, the
        // divisor loaded by LoadDiag, and the row result produced by Div.
        let mut acc = 0f32;
        let mut dreg = 0f32;
        let mut t = 0f32;
        for op in &node.ops {
            match *op {
                KOp::Gather { src_row, dst } => {
                    // SAFETY: kir::verify lemma gather-window — src_row < n
                    // == xk.len() and dst < scratch_len == scratch.len().
                    // relaxed: the Release decrement + Acquire fence on this
                    // node's dependency counter ordered the producers'
                    // stores (same protocol as run_node).
                    let v = unsafe { xk.get_unchecked(src_row as usize) }.load(Ordering::Relaxed);
                    // SAFETY: kir::verify lemma gather-window (dst half).
                    unsafe { *scratch.get_unchecked_mut(dst as usize) = f32::from_bits(v) };
                }
                KOp::MacExt { coeff, src } => {
                    // SAFETY: kir::verify lemmas mac-window + def-before-use
                    // — src < scratch_len and a Gather defined the slot.
                    acc += coeff * unsafe { *scratch.get_unchecked(src as usize) };
                }
                KOp::MacLocal { coeff, src } => {
                    // SAFETY: kir::verify lemmas mac-window + def-before-use
                    // — src < rows and an earlier row's StorePsum defined
                    // the slot.
                    acc += coeff * unsafe { *local.get_unchecked(src as usize) };
                }
                KOp::LoadDiag { diag } => dreg = diag,
                KOp::Div { row } => {
                    // SAFETY: kir::verify lemma row-window — row lies in the
                    // node's window and the window inside the order, so
                    // row < n == b.len(); lemma diag-nonzero keeps the
                    // divide finite (dreg was loaded by the row's LoadDiag).
                    t = (unsafe { *b.get_unchecked(row as usize) } - acc) / dreg;
                    acc = 0.0;
                }
                KOp::StorePsum { dst } => {
                    // SAFETY: kir::verify lemma psum-window (with
                    // def-before-use's single-write) — dst < rows ==
                    // local.len().
                    unsafe { *local.get_unchecked_mut(dst as usize) = t };
                }
                KOp::StoreX { row } => {
                    // SAFETY: kir::verify lemma row-window — row < n ==
                    // xk.len().
                    // relaxed: published to consumers by the Release
                    // decrement of their dependency counters in
                    // mgd_exec::complete (same protocol as run_node).
                    unsafe { xk.get_unchecked(row as usize) }.store(t.to_bits(), Ordering::Relaxed);
                }
            }
        }
    }
}
