//! Synchronization facade of the runtime and coordinator layers.
//!
//! Every lock, condvar, atomic and thread spawn that takes part in a
//! cross-thread protocol (`runtime/pool.rs`, `runtime/mgd_exec.rs`,
//! `coordinator/service.rs`, `coordinator/registry.rs`, ...) is imported
//! from this module instead of `std::sync` directly — `ci/lint_sync.py`
//! enforces the discipline. The payoff is that the protocols become
//! *model-checkable in-tree*:
//!
//! - Outside a model run the facade is a zero-cost passthrough: atomics,
//!   `Arc`, `RwLock`, `Barrier`, `mpsc` and `OnceLock` are plain std
//!   re-exports, and the wrapped [`Mutex`]/[`Condvar`] delegate to their
//!   std counterparts after one thread-local lookup.
//! - Inside [`model::explore`] the calling thread is a *virtual thread*
//!   of a mini-loom explorer: every `lock`, unlock, `wait`, `notify` and
//!   spawn becomes a scheduling point, and the explorer enumerates
//!   interleavings (bounded exhaustive DFS over the recorded choice
//!   points, then seeded-random schedules) looking for deadlocks, lost
//!   wakeups and property violations flagged via [`model::flag`]. Runs
//!   are deterministic: no wall clock, no OS randomness — only the
//!   schedule choices vary, so plain `cargo test` explores a bounded,
//!   reproducible set of schedules (deepened by the `model-check` cargo
//!   feature).
//!
//! Atomics are deliberately *not* instrumented: the three protocols
//! checked here (pool session lease, `ShardQueue` admission, `DrainGate`
//! drain) synchronize through the mutex/condvar pairs, and the atomic
//! fences are covered by the nightly Miri/TSan CI jobs instead. Spurious
//! condvar wakeups are not modeled; all in-tree waits sit in predicate
//! loops, which the model checker exercises via real notify races.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::Condvar as StdCondvar;
use std::sync::Mutex as StdMutex;
use std::time::Duration;

pub use std::sync::atomic;
pub use std::sync::{mpsc, Arc, Barrier, OnceLock, RwLock, Weak};
pub use std::sync::{LockResult, PoisonError, TryLockError};

/// Mutual exclusion with the `std::sync::Mutex` surface (the subset the
/// crate uses: `new`, `lock`, `into_inner`).
///
/// On a normal thread this is the std mutex plus one thread-local check.
/// On a virtual thread of [`model::explore`] the acquisition is arbitrated
/// by the explorer: the lock entry is a scheduling point, contention
/// blocks the virtual thread, and the real (uncontended) std lock is only
/// taken once the explorer grants logical ownership.
pub struct Mutex<T> {
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// Creates a new unlocked mutex.
    pub fn new(t: T) -> Mutex<T> {
        Mutex {
            inner: StdMutex::new(t),
        }
    }

    /// Consumes the mutex, returning the inner value.
    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }
}

impl<T> Mutex<T> {
    fn key(&self) -> usize {
        self as *const Mutex<T> as *const () as usize
    }

    fn wrap<'a>(
        &'a self,
        logical: bool,
        res: LockResult<std::sync::MutexGuard<'a, T>>,
    ) -> LockResult<MutexGuard<'a, T>> {
        match res {
            Ok(g) => Ok(MutexGuard {
                lock: self,
                logical,
                inner: Some(g),
            }),
            Err(p) => Err(PoisonError::new(MutexGuard {
                lock: self,
                logical,
                inner: Some(p.into_inner()),
            })),
        }
    }

    /// Takes the real lock after the explorer granted logical ownership;
    /// the std lock is uncontended at this point (`WouldBlock` is only a
    /// defensive fallback against non-virtual interference).
    fn relock(&self) -> LockResult<MutexGuard<'_, T>> {
        match self.inner.try_lock() {
            Ok(g) => self.wrap(true, Ok(g)),
            Err(TryLockError::Poisoned(p)) => self.wrap(true, Err(p)),
            Err(TryLockError::WouldBlock) => self.wrap(true, self.inner.lock()),
        }
    }

    /// Acquires the mutex, blocking until it is available. A scheduling
    /// point under a model run.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match model::current() {
            None => self.wrap(false, self.inner.lock()),
            Some(vt) => {
                vt.yield_point();
                vt.acquire_mutex(self.key());
                self.relock()
            }
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.inner.fmt(f)
    }
}

/// Guard returned by [`Mutex::lock`]; releases the lock on drop (a
/// scheduling point under a model run).
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    logical: bool,
    inner: Option<std::sync::MutexGuard<'a, T>>,
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("mutex guard already released")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("mutex guard already released")
    }
}

impl<T: fmt::Debug> fmt::Debug for MutexGuard<'_, T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        (**self).fmt(f)
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        drop(self.inner.take());
        if self.logical {
            if let Some(vt) = model::current() {
                vt.release_mutex(self.lock.key());
                vt.yield_point();
            }
        }
    }
}

/// Condition variable with the `std::sync::Condvar` surface (the subset
/// the crate uses: `new`, `wait`, `wait_timeout`, `notify_one`,
/// `notify_all`), model-instrumented like [`Mutex`].
pub struct Condvar {
    inner: StdCondvar,
}

impl Condvar {
    /// Creates a new condition variable.
    pub fn new() -> Condvar {
        Condvar {
            inner: StdCondvar::new(),
        }
    }

    fn key(&self) -> usize {
        self as *const Condvar as *const () as usize
    }

    /// Releases the guard, waits for a notification, reacquires the lock.
    ///
    /// Under a model run there is a scheduling point *before* the waiter
    /// registers (still holding the lock) — exactly the window a
    /// notify-outside-the-lock protocol needs to lose a wakeup, which is
    /// how the explorer catches that bug class.
    pub fn wait<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
    ) -> LockResult<MutexGuard<'a, T>> {
        match model::current() {
            None => {
                let lock = guard.lock;
                let g = guard.inner.take().expect("mutex guard already released");
                lock.wrap(false, self.inner.wait(g))
            }
            Some(vt) => {
                vt.yield_point();
                let lock = guard.lock;
                guard.logical = false;
                drop(guard.inner.take());
                drop(guard);
                vt.condvar_wait(self.key(), lock.key());
                vt.acquire_mutex(lock.key());
                lock.relock()
            }
        }
    }

    /// Like [`Condvar::wait`] but with a deadline: returns after a
    /// notification *or* once `dur` has elapsed, whichever comes first.
    ///
    /// Under a model run there is no wall clock, so the timeout degrades
    /// to a plain [`Condvar::wait`] (reported as not timed out). Model
    /// scenarios therefore must not rely on a deadline firing to make
    /// progress: an unnotified waiter stalls, which the explorer reports
    /// as a lost wakeup — exactly the signal we want from the checker.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match model::current() {
            None => {
                let lock = guard.lock;
                let g = guard.inner.take().expect("mutex guard already released");
                match self.inner.wait_timeout(g, dur) {
                    Ok((g, t)) => {
                        let timed = WaitTimeoutResult(t.timed_out());
                        match lock.wrap(false, Ok(g)) {
                            Ok(g) => Ok((g, timed)),
                            Err(p) => Err(PoisonError::new((p.into_inner(), timed))),
                        }
                    }
                    Err(p) => {
                        let (g, t) = p.into_inner();
                        let timed = WaitTimeoutResult(t.timed_out());
                        match lock.wrap(false, Err(PoisonError::new(g))) {
                            Ok(g) => Ok((g, timed)),
                            Err(p2) => Err(PoisonError::new((p2.into_inner(), timed))),
                        }
                    }
                }
            }
            Some(_) => match self.wait(guard) {
                Ok(g) => Ok((g, WaitTimeoutResult(false))),
                Err(p) => Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(false)))),
            },
        }
    }

    /// Wakes one waiter (FIFO under a model run). A scheduling point.
    pub fn notify_one(&self) {
        if let Some(vt) = model::current() {
            vt.notify(self.key(), false);
        }
        self.inner.notify_one();
    }

    /// Wakes all waiters. A scheduling point under a model run.
    pub fn notify_all(&self) {
        if let Some(vt) = model::current() {
            vt.notify(self.key(), true);
        }
        self.inner.notify_all();
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

/// Whether a [`Condvar::wait_timeout`] returned because its deadline
/// elapsed rather than because of a notification.
///
/// Mirrors `std::sync::WaitTimeoutResult` (which has no public
/// constructor, so the facade's model arm could not fabricate one).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    /// Returns `true` if the wait ended because the deadline elapsed.
    /// Always `false` under a model run (no wall clock is modeled).
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

pub mod thread {
    //! Facade over `std::thread` spawn/join (the subset the runtime
    //! uses), so worker pools spawned inside a [`super::model::explore`]
    //! scenario become virtual threads of the explorer instead of free
    //! running OS threads.

    use super::model;

    /// Mirror of `std::thread::Builder` (subset: `name` + `spawn`).
    pub struct Builder {
        inner: std::thread::Builder,
        name: Option<String>,
    }

    impl Builder {
        /// New builder with default settings.
        pub fn new() -> Builder {
            Builder {
                inner: std::thread::Builder::new(),
                name: None,
            }
        }

        /// Names the thread (OS name and the model's thread label).
        pub fn name(self, name: String) -> Builder {
            Builder {
                inner: self.inner.name(name.clone()),
                name: Some(name),
            }
        }

        /// Spawns the thread. Inside a model run the child registers as a
        /// virtual thread and only runs when the explorer schedules it;
        /// the spawn itself is a scheduling point.
        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match model::current() {
                None => Ok(JoinHandle {
                    inner: self.inner.spawn(f)?,
                    vt: None,
                }),
                Some(vt) => {
                    let label = self.name.unwrap_or_else(|| "vthread".to_string());
                    let tid = vt.register_child(label);
                    let ctl = std::sync::Arc::clone(&vt.ctl);
                    let h = self.inner.spawn(move || model::run_virtual(ctl, tid, f))?;
                    vt.yield_point();
                    Ok(JoinHandle {
                        inner: h,
                        vt: Some(model::Vt {
                            ctl: std::sync::Arc::clone(&vt.ctl),
                            tid,
                        }),
                    })
                }
            }
        }
    }

    impl Default for Builder {
        fn default() -> Builder {
            Builder::new()
        }
    }

    /// Mirror of `std::thread::JoinHandle`. Joining from a virtual thread
    /// first waits for the child's virtual exit (a scheduling point), then
    /// joins the real thread.
    pub struct JoinHandle<T> {
        inner: std::thread::JoinHandle<T>,
        vt: Option<model::Vt>,
    }

    impl<T> JoinHandle<T> {
        /// Waits for the thread to finish and returns its result.
        pub fn join(self) -> std::thread::Result<T> {
            if let Some(child) = &self.vt {
                if let Some(me) = model::current() {
                    me.block_on_join(child.tid);
                }
            }
            self.inner.join()
        }
    }

    /// Mirror of `std::thread::spawn` (panics if the OS refuses).
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }
}

pub mod model {
    //! The mini-loom explorer behind the facade.
    //!
    //! [`explore`] runs a scenario closure once per schedule as the root
    //! *virtual thread*. Virtual threads are real OS threads serialized by
    //! a token: exactly one runs at a time, and at every facade operation
    //! (lock, unlock, wait, notify, spawn, join) the running thread hands
    //! the token back and the explorer picks who continues. Choice points
    //! are recorded, so the explorer can replay a prefix and branch — a
    //! bounded exhaustive DFS over the interleaving tree — and a
    //! seeded-xorshift tail samples deeper schedules. All decisions are
    //! functions of the recorded schedule: no wall clock, no OS
    //! randomness, deterministic across runs.
    //!
    //! Detected failures: deadlock (no virtual thread runnable), lost
    //! wakeup (stall with a condvar waiter), scenario panics, scheduling
    //! step-bound overruns, and explicit [`flag`] calls. On failure the
    //! run is abandoned: parked virtual threads stay parked and their OS
    //! threads are detached (a handful of leaked parked threads per
    //! *failing* test is the price of never unwinding through foreign
    //! lock guards).

    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

    const MAX_THREADS: usize = 64;

    #[derive(Clone, Copy, PartialEq, Eq, Debug)]
    enum VtState {
        Runnable,
        Running,
        BlockedMutex(usize),
        BlockedCondvar(usize),
        BlockedJoin(usize),
        Exited,
    }

    struct SchedState {
        threads: Vec<VtState>,
        names: Vec<String>,
        mutexes: HashMap<usize, Option<usize>>,
        waiters: HashMap<usize, Vec<usize>>,
        running: Option<usize>,
        prefix: Vec<usize>,
        pos: usize,
        trace: Vec<(usize, usize)>,
        rng: u64,
        steps: usize,
        max_steps: usize,
        live: usize,
        failure: Option<String>,
    }

    pub(super) struct Ctl {
        st: Mutex<SchedState>,
        cv: Condvar,
    }

    /// Handle of one virtual thread (thread-local; cloned per facade op).
    #[derive(Clone)]
    pub(super) struct Vt {
        pub(super) ctl: Arc<Ctl>,
        pub(super) tid: usize,
    }

    thread_local! {
        static CURRENT: RefCell<Option<Vt>> = RefCell::new(None);
    }

    pub(super) fn current() -> Option<Vt> {
        CURRENT.with(|c| c.borrow().clone())
    }

    fn lock_ctl(ctl: &Ctl) -> MutexGuard<'_, SchedState> {
        ctl.st.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn xorshift(s: &mut u64) -> u64 {
        *s ^= *s << 13;
        *s ^= *s >> 7;
        *s ^= *s << 17;
        *s
    }

    fn fail(st: &mut SchedState, msg: String) {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.running = None;
    }

    fn describe_stall(st: &SchedState) -> String {
        let mut parts = Vec::new();
        let mut cv_wait = false;
        for (i, s) in st.threads.iter().enumerate() {
            let d = match s {
                VtState::BlockedMutex(k) => {
                    format!("'{}' blocked on mutex #{k:x}", st.names[i])
                }
                VtState::BlockedCondvar(k) => {
                    cv_wait = true;
                    format!("'{}' waiting on condvar #{k:x}", st.names[i])
                }
                VtState::BlockedJoin(t) => {
                    format!("'{}' joining '{}'", st.names[i], st.names[*t])
                }
                _ => continue,
            };
            parts.push(d);
        }
        if cv_wait {
            format!("lost wakeup or deadlock: {}", parts.join("; "))
        } else {
            format!("deadlock: {}", parts.join("; "))
        }
    }

    /// Picks the next runnable thread per the schedule. The caller must
    /// already have parked/retired the previously running thread.
    fn reschedule(st: &mut SchedState) {
        if st.failure.is_some() || st.live == 0 {
            return;
        }
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, s)| matches!(s, VtState::Runnable))
            .map(|(i, _)| i)
            .collect();
        if runnable.is_empty() {
            let msg = describe_stall(st);
            fail(st, msg);
            return;
        }
        st.steps += 1;
        if st.steps > st.max_steps {
            let msg = format!("step bound exceeded ({} scheduling points)", st.max_steps);
            fail(st, msg);
            return;
        }
        let n = runnable.len();
        let idx = if st.pos < st.prefix.len() {
            st.prefix[st.pos].min(n - 1)
        } else if st.rng != 0 {
            (xorshift(&mut st.rng) % n as u64) as usize
        } else {
            0
        };
        st.pos += 1;
        st.trace.push((idx, n));
        let t = runnable[idx];
        st.threads[t] = VtState::Running;
        st.running = Some(t);
    }

    /// Parks until the explorer hands this thread the token. After a
    /// failure `running` stays `None` forever, so parked threads never
    /// resume — the runner detaches them.
    fn wait_for_token<'a>(
        ctl: &'a Ctl,
        mut st: MutexGuard<'a, SchedState>,
        tid: usize,
    ) -> MutexGuard<'a, SchedState> {
        loop {
            if st.running == Some(tid) {
                return st;
            }
            st = ctl.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    impl Vt {
        /// One scheduling point: hand the token back, let the explorer
        /// pick (possibly this thread again), resume when granted.
        pub(super) fn yield_point(&self) {
            let mut st = lock_ctl(&self.ctl);
            st.threads[self.tid] = VtState::Runnable;
            st.running = None;
            reschedule(&mut st);
            self.ctl.cv.notify_all();
            let st = wait_for_token(&self.ctl, st, self.tid);
            drop(st);
        }

        pub(super) fn acquire_mutex(&self, key: usize) {
            let mut st = lock_ctl(&self.ctl);
            loop {
                let slot = st.mutexes.entry(key).or_insert(None);
                if slot.is_none() {
                    *slot = Some(self.tid);
                    drop(st);
                    return;
                }
                st.threads[self.tid] = VtState::BlockedMutex(key);
                st.running = None;
                reschedule(&mut st);
                self.ctl.cv.notify_all();
                st = wait_for_token(&self.ctl, st, self.tid);
            }
        }

        pub(super) fn release_mutex(&self, key: usize) {
            let mut st = lock_ctl(&self.ctl);
            st.mutexes.insert(key, None);
            for s in st.threads.iter_mut() {
                if *s == VtState::BlockedMutex(key) {
                    *s = VtState::Runnable;
                }
            }
        }

        /// Atomically releases `mutex_key`, registers as a waiter on
        /// `cv_key` and parks; returns (running, *not* holding the mutex)
        /// once notified and scheduled.
        pub(super) fn condvar_wait(&self, cv_key: usize, mutex_key: usize) {
            let mut st = lock_ctl(&self.ctl);
            st.mutexes.insert(mutex_key, None);
            for s in st.threads.iter_mut() {
                if *s == VtState::BlockedMutex(mutex_key) {
                    *s = VtState::Runnable;
                }
            }
            st.waiters.entry(cv_key).or_default().push(self.tid);
            st.threads[self.tid] = VtState::BlockedCondvar(cv_key);
            st.running = None;
            reschedule(&mut st);
            self.ctl.cv.notify_all();
            let st = wait_for_token(&self.ctl, st, self.tid);
            drop(st);
        }

        pub(super) fn notify(&self, cv_key: usize, all: bool) {
            self.yield_point();
            let mut st = lock_ctl(&self.ctl);
            let woken: Vec<usize> = match st.waiters.get_mut(&cv_key) {
                Some(q) if all => q.drain(..).collect(),
                Some(q) if !q.is_empty() => vec![q.remove(0)],
                _ => Vec::new(),
            };
            for t in woken {
                st.threads[t] = VtState::Runnable;
            }
        }

        pub(super) fn register_child(&self, name: String) -> usize {
            let mut st = lock_ctl(&self.ctl);
            if st.threads.len() >= MAX_THREADS {
                let msg = format!("more than {MAX_THREADS} virtual threads spawned");
                fail(&mut st, msg);
            }
            st.threads.push(VtState::Runnable);
            st.names.push(name);
            st.live += 1;
            st.threads.len() - 1
        }

        pub(super) fn block_on_join(&self, child: usize) {
            let mut st = lock_ctl(&self.ctl);
            while st.threads[child] != VtState::Exited {
                st.threads[self.tid] = VtState::BlockedJoin(child);
                st.running = None;
                reschedule(&mut st);
                self.ctl.cv.notify_all();
                st = wait_for_token(&self.ctl, st, self.tid);
            }
            drop(st);
        }
    }

    fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = p.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = p.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    }

    /// Body of every virtual thread: register in the TLS, wait for the
    /// first token, run, then retire and wake joiners.
    pub(super) fn run_virtual<F, T>(ctl: Arc<Ctl>, tid: usize, f: F) -> T
    where
        F: FnOnce() -> T,
    {
        let vt = Vt { ctl, tid };
        CURRENT.with(|c| *c.borrow_mut() = Some(vt.clone()));
        {
            let st = lock_ctl(&vt.ctl);
            let st = wait_for_token(&vt.ctl, st, tid);
            drop(st);
        }
        let result = catch_unwind(AssertUnwindSafe(f));
        let mut st = lock_ctl(&vt.ctl);
        if let Err(p) = &result {
            let msg = panic_message(p.as_ref());
            let name = st.names[tid].clone();
            fail(&mut st, format!("thread '{name}' panicked: {msg}"));
        }
        st.threads[tid] = VtState::Exited;
        st.live -= 1;
        st.running = None;
        for s in st.threads.iter_mut() {
            if *s == VtState::BlockedJoin(tid) {
                *s = VtState::Runnable;
            }
        }
        reschedule(&mut st);
        drop(st);
        vt.ctl.cv.notify_all();
        CURRENT.with(|c| *c.borrow_mut() = None);
        match result {
            Ok(v) => v,
            Err(p) => resume_unwind(p),
        }
    }

    /// Report a protocol/property violation from inside a scenario.
    ///
    /// On a virtual thread this records the failure and parks the caller
    /// (the run is abandoned) instead of panicking, so the violation
    /// never unwinds through foreign lock guards. Outside a model run it
    /// panics like a plain assertion.
    pub fn flag(msg: &str) {
        match current() {
            None => panic!("model property violated: {msg}"),
            Some(vt) => {
                let mut st = lock_ctl(&vt.ctl);
                fail(&mut st, format!("property violated: {msg}"));
                vt.ctl.cv.notify_all();
                let st = wait_for_token(&vt.ctl, st, vt.tid);
                drop(st);
            }
        }
    }

    /// Exploration budget of one [`explore`] call. All bounds are
    /// schedule/step counts — never wall-clock — so runs are
    /// deterministic.
    #[derive(Clone, Copy, Debug)]
    pub struct ModelConfig {
        /// Bound on exhaustively enumerated schedules (DFS over recorded
        /// choice points; the tree is truncated past this many runs).
        pub max_schedules: usize,
        /// Seeded-random schedules run after the exhaustive phase, to
        /// sample branches the truncated DFS never reached.
        pub random_schedules: usize,
        /// Seed of the xorshift generator driving the random phase.
        pub seed: u64,
        /// Bound on scheduling points within one schedule (runaway and
        /// livelock guard).
        pub max_steps: usize,
    }

    impl ModelConfig {
        /// Budget sized for plain `cargo test` (a few hundred schedules);
        /// the `model-check` cargo feature deepens it 8x for the nightly
        /// deep-exploration CI job.
        pub fn fast() -> ModelConfig {
            let deep = if cfg!(feature = "model-check") { 8 } else { 1 };
            ModelConfig {
                max_schedules: 256 * deep,
                random_schedules: 32 * deep,
                seed: 0x9e37_79b9_7f4a_7c15,
                max_steps: 50_000,
            }
        }
    }

    impl Default for ModelConfig {
        fn default() -> ModelConfig {
            ModelConfig::fast()
        }
    }

    /// Result of exploring one scenario.
    #[derive(Clone, Debug)]
    pub struct Outcome {
        /// Schedules actually executed.
        pub schedules: usize,
        /// True if the exhaustive phase hit `max_schedules` with branches
        /// left unexplored.
        pub truncated: bool,
        /// First violation found, if any (deadlock, lost wakeup, panic,
        /// [`flag`], step-bound overrun).
        pub failure: Option<String>,
    }

    impl Outcome {
        /// Panics if any schedule failed.
        pub fn assert_ok(&self) {
            if let Some(f) = &self.failure {
                panic!("model check failed after {} schedules: {f}", self.schedules);
            }
        }

        /// Panics unless some schedule failed with a message containing
        /// `needle` (used by the seeded-mutation tests that prove the
        /// checker has teeth).
        pub fn assert_fails_with(&self, needle: &str) {
            match &self.failure {
                None => panic!("model check passed all {} schedules", self.schedules),
                Some(f) => {
                    if !f.contains(needle) {
                        panic!("model failure {f:?} does not mention {needle:?}");
                    }
                }
            }
        }
    }

    fn next_prefix(trace: &[(usize, usize)]) -> Option<Vec<usize>> {
        for (i, &(idx, n)) in trace.iter().enumerate().rev() {
            if idx + 1 < n {
                let mut p: Vec<usize> = trace[..i].iter().map(|&(c, _)| c).collect();
                p.push(idx + 1);
                return Some(p);
            }
        }
        None
    }

    fn run_one<F>(
        cfg: &ModelConfig,
        prefix: Vec<usize>,
        rng: u64,
        scenario: Arc<F>,
    ) -> (Vec<(usize, usize)>, Option<String>)
    where
        F: Fn() + Send + Sync + 'static,
    {
        let ctl = Arc::new(Ctl {
            st: Mutex::new(SchedState {
                threads: vec![VtState::Runnable],
                names: vec!["root".to_string()],
                mutexes: HashMap::new(),
                waiters: HashMap::new(),
                running: None,
                prefix,
                pos: 0,
                trace: Vec::new(),
                rng,
                steps: 0,
                max_steps: cfg.max_steps,
                live: 1,
                failure: None,
            }),
            cv: Condvar::new(),
        });
        let ctl2 = Arc::clone(&ctl);
        let root = std::thread::Builder::new()
            .name("model-root".to_string())
            .spawn(move || run_virtual(ctl2, 0, move || scenario()))
            .expect("failed to spawn model root thread");
        {
            let mut st = lock_ctl(&ctl);
            reschedule(&mut st);
            drop(st);
            ctl.cv.notify_all();
        }
        let mut st = lock_ctl(&ctl);
        loop {
            if let Some(f) = st.failure.clone() {
                let trace = st.trace.clone();
                drop(st);
                drop(root);
                return (trace, Some(f));
            }
            if st.live == 0 {
                break;
            }
            st = ctl.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        let trace = st.trace.clone();
        drop(st);
        let _ = root.join();
        (trace, None)
    }

    /// Explores interleavings of `scenario`, which runs once per schedule
    /// as the root virtual thread (spawn more via
    /// [`crate::runtime::sync::thread`]). The scenario must be
    /// deterministic given the schedule: same facade-op sequence per
    /// thread, no wall-clock branches. Returns after the first failing
    /// schedule or once the budget is spent.
    pub fn explore<F>(cfg: ModelConfig, scenario: F) -> Outcome
    where
        F: Fn() + Send + Sync + 'static,
    {
        let scenario = Arc::new(scenario);
        let mut out = Outcome {
            schedules: 0,
            truncated: false,
            failure: None,
        };
        let mut prefix = Some(Vec::new());
        while let Some(p) = prefix.take() {
            if out.schedules >= cfg.max_schedules {
                out.truncated = true;
                break;
            }
            let (trace, failure) = run_one(&cfg, p, 0, Arc::clone(&scenario));
            out.schedules += 1;
            if failure.is_some() {
                out.failure = failure;
                return out;
            }
            prefix = next_prefix(&trace);
        }
        for i in 0..cfg.random_schedules {
            let salt = (i as u64).wrapping_mul(0x2545_f491_4f6c_dd1d);
            let seed = cfg.seed.wrapping_add(salt) | 1;
            let (_, failure) = run_one(&cfg, Vec::new(), seed, Arc::clone(&scenario));
            out.schedules += 1;
            if failure.is_some() {
                out.failure = failure;
                return out;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::model::{self, ModelConfig};
    use super::{thread, Arc, Condvar, Mutex};

    fn tiny() -> ModelConfig {
        ModelConfig {
            max_schedules: 200,
            random_schedules: 16,
            seed: 7,
            max_steps: 10_000,
        }
    }

    #[test]
    fn passthrough_outside_model_runs() {
        let m = Arc::new(Mutex::new(0usize));
        let cv = Arc::new(Condvar::new());
        let m2 = Arc::clone(&m);
        let cv2 = Arc::clone(&cv);
        let h = thread::spawn(move || {
            let mut g = m2.lock().unwrap();
            *g = 1;
            cv2.notify_all();
        });
        let mut g = m.lock().unwrap();
        while *g == 0 {
            g = cv.wait(g).unwrap();
        }
        assert_eq!(*g, 1);
        drop(g);
        h.join().unwrap();
        assert_eq!(m.lock().map(|g| *g).unwrap(), 1);
    }

    #[test]
    fn model_correct_handshake_passes_all_schedules() {
        let out = model::explore(tiny(), || {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            let setter = thread::spawn(move || {
                let (m, cv) = &*p2;
                let mut ready = m.lock().unwrap();
                *ready = true;
                cv.notify_all();
            });
            let (m, cv) = &*pair;
            let mut ready = m.lock().unwrap();
            while !*ready {
                ready = cv.wait(ready).unwrap();
            }
            drop(ready);
            setter.join().unwrap();
        });
        out.assert_ok();
        assert!(out.schedules > 1, "explorer found only one interleaving");
    }

    #[test]
    fn model_is_deterministic() {
        let run = || {
            model::explore(tiny(), || {
                let pair = Arc::new((Mutex::new(false), Condvar::new()));
                let p2 = Arc::clone(&pair);
                let setter = thread::spawn(move || {
                    let (m, cv) = &*p2;
                    *m.lock().unwrap() = true;
                    cv.notify_all();
                });
                let (m, cv) = &*pair;
                let mut ready = m.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
                drop(ready);
                setter.join().unwrap();
            })
        };
        let a = run();
        let b = run();
        a.assert_ok();
        assert_eq!(a.schedules, b.schedules);
        assert_eq!(a.truncated, b.truncated);
    }

    #[test]
    fn model_detects_lost_wakeup_of_unlocked_notify() {
        // Seeded protocol mutation: the setter publishes the flag and
        // notifies WITHOUT taking the lock — the classic lost-wakeup bug
        // the DrainGate fix closed. Some schedule must park the waiter
        // forever, and the explorer must say so.
        let out = model::explore(tiny(), || {
            let flagged = Arc::new(AtomicUsize::new(0));
            let pair = Arc::new((Mutex::new(()), Condvar::new()));
            let f2 = Arc::clone(&flagged);
            let p2 = Arc::clone(&pair);
            let setter = thread::spawn(move || {
                f2.store(1, Ordering::SeqCst);
                p2.1.notify_all();
            });
            let (m, cv) = &*pair;
            let mut g = m.lock().unwrap();
            while flagged.load(Ordering::SeqCst) == 0 {
                g = cv.wait(g).unwrap();
            }
            drop(g);
            setter.join().unwrap();
        });
        out.assert_fails_with("lost wakeup");
    }

    #[test]
    fn model_detects_abba_deadlock() {
        let out = model::explore(tiny(), || {
            let a = Arc::new(Mutex::new(0usize));
            let b = Arc::new(Mutex::new(0usize));
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t = thread::spawn(move || {
                let _gb = b2.lock().unwrap();
                let _ga = a2.lock().unwrap();
            });
            {
                let _ga = a.lock().unwrap();
                let _gb = b.lock().unwrap();
            }
            t.join().unwrap();
        });
        out.assert_fails_with("deadlock");
    }

    #[test]
    fn model_detects_cap_overshoot_of_unlocked_check() {
        // Seeded protocol mutation: a bounded counter that checks the cap
        // OUTSIDE the lock before incrementing inside it — two threads can
        // both pass the check and overshoot. `flag` must catch it.
        const CAP: usize = 1;
        let out = model::explore(tiny(), || {
            let depth = Arc::new(Mutex::new(0usize));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let d = Arc::clone(&depth);
                handles.push(thread::spawn(move || {
                    let full = *d.lock().unwrap() >= CAP;
                    if !full {
                        let mut g = d.lock().unwrap();
                        *g += 1;
                        if *g > CAP {
                            model::flag("queue depth exceeds cap");
                        }
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
        });
        out.assert_fails_with("depth exceeds cap");
    }

    #[test]
    fn model_reports_scenario_panics_as_failures() {
        let out = model::explore(
            ModelConfig {
                max_schedules: 4,
                random_schedules: 0,
                seed: 1,
                max_steps: 1_000,
            },
            || {
                let m = Mutex::new(7usize);
                let g = m.lock().unwrap();
                assert_eq!(*g, 8, "deliberate scenario failure");
            },
        );
        out.assert_fails_with("panicked");
    }
}
