//! Comparator implementations for the paper's evaluation:
//!
//! - [`coarse`] — the synchronization-free *coarse* dataflow run on the
//!   same accelerator (a node is the minimal scheduling unit), Fig. 9(a).
//! - [`fine`] — a DPU-v2-style *fine* dataflow model: binary-DAG conversion
//!   mapped onto tree-shaped PE arrays at 2× clock, Figs. 9(a)/11/12.
//! - [`cpu`] — serial and level-scheduled multithreaded solvers measured
//!   natively on this host (the MKL stand-in), Figs. 11/12, Table IV.
//! - [`gpu`] — an analytic synchronization-free GPU model calibrated to
//!   cuSPARSE's published behaviour, Figs. 11/12, Table IV.

pub mod coarse;
pub mod cpu;
pub mod fine;
pub mod gpu;
