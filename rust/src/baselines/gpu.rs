//! GPU baseline — an analytic model of the synchronization-free method
//! (cuSPARSE `csrsv_solve` class, Liu et al.).
//!
//! We have no CUDA device in this image, so the GPU comparator is a latency
//! model of the mechanism the paper identifies as the bottleneck (§II.A):
//! warp-per-node execution where each node spins on its dependencies
//! through L2, gathers its operands with poor locality (one useful word per
//! 32-word cache line), and then performs its MACs at warp width.
//!
//! Model: `finish(i) = max_{j∈preds(i)} finish(j) + t_dep + t_edge·⌈k/32⌉·32`
//! with a whole-solve floor of `total_bytes / bandwidth`, plus a fixed
//! kernel-launch latency. Constants are calibrated so the 245-benchmark
//! average lands near cuSPARSE's published ≈1 GOPS on an RTX 2080Ti
//! (Table IV) — see DESIGN.md "Substitutions".

use crate::graph::Dag;

/// Model constants.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Kernel-launch + tail latency (s).
    pub t_launch: f64,
    /// Dependent-chain step latency: spin-loop observation of a
    /// just-produced value through L2 (s).
    pub t_dep: f64,
    /// Per-32-wide-MAC-batch latency within a warp (s): one gather of a
    /// sparse cache line per lane.
    pub t_batch: f64,
    /// Effective memory bandwidth for the streaming floor (bytes/s).
    pub bandwidth: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self {
            t_launch: 4e-6,
            // ~L2-roundtrip-dominated dependent step on Turing.
            t_dep: 450e-9,
            // One gather+MAC batch per 32 edges.
            t_batch: 60e-9,
            // Sparse-access effective bandwidth ≪ 616 GB/s peak: one useful
            // word per line on the x gathers.
            bandwidth: 60e9,
        }
    }
}

/// Result of the GPU model.
#[derive(Debug, Clone, Copy)]
pub struct GpuResult {
    /// Modeled solve time (s).
    pub seconds: f64,
    /// Throughput in GOPS.
    pub gops: f64,
}

/// Evaluate the model on a DAG.
pub fn simulate(g: &Dag, model: &GpuModel) -> GpuResult {
    let n = g.n;
    // Critical path with per-node service times.
    let mut finish = vec![0f64; n];
    let mut crit: f64 = 0.0;
    for i in 0..n {
        let k = g.in_degree(i);
        let service = model.t_dep + (k.div_ceil(32).max(1)) as f64 * model.t_batch;
        let mut start: f64 = 0.0;
        for &p in g.preds(i) {
            start = start.max(finish[p as usize]);
        }
        finish[i] = start + service;
        crit = crit.max(finish[i]);
    }
    // Streaming floor: every nonzero's (value, colidx) plus the x and b
    // traffic, at sparse-effective bandwidth.
    let nnz = g.num_edges() + n;
    let bytes = (nnz * 8 + n * 8) as f64;
    let floor = bytes / model.bandwidth;
    let seconds = model.t_launch + crit.max(floor);
    let flops = (2 * nnz - n) as f64;
    GpuResult {
        seconds,
        gops: flops / seconds / 1e9,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    fn gops(m: &crate::matrix::CsrMatrix) -> f64 {
        simulate(&Dag::from_csr(m), &GpuModel::default()).gops
    }

    #[test]
    fn chain_is_terrible_on_gpu() {
        // Fully sequential: every node pays the dependent-step latency.
        let m = gen::chain(2000, GenSeed(1));
        assert!(gops(&m) < 0.1, "{}", gops(&m));
    }

    #[test]
    fn wide_dag_is_much_better() {
        let wide = gen::shallow(20000, 0.2, GenSeed(2));
        let deep = gen::chain(2000, GenSeed(1));
        assert!(gops(&wide) > 5.0 * gops(&deep));
    }

    #[test]
    fn typical_circuit_matrix_in_cusparse_range() {
        // Calibration guard: mid-size circuit-like DAGs should land in the
        // ~0.1–5 GOPS band the paper reports for the GPU.
        let m = gen::circuit(4000, 6, 0.8, GenSeed(3));
        let v = gops(&m);
        assert!((0.05..5.0).contains(&v), "{v}");
    }

    #[test]
    fn monotone_in_dep_latency() {
        let m = gen::banded(2000, 6, 0.5, GenSeed(4));
        let g = Dag::from_csr(&m);
        let fast = simulate(&g, &GpuModel { t_dep: 100e-9, ..Default::default() });
        let slow = simulate(&g, &GpuModel { t_dep: 900e-9, ..Default::default() });
        assert!(fast.gops > slow.gops);
    }
}
