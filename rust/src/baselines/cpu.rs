//! CPU baseline (the paper's MKL `mkl_sparse_s_trsv` stand-in).
//!
//! Two algorithm classes are measured natively on this host:
//!
//! - [`serial_gops`] — Algorithm 1, one thread (MKL's small-matrix path);
//! - [`level_scheduled`] — level scheduling with per-level barriers
//!   (Anderson/Saad), the classic multicore SpTRSV.
//!
//! Absolute numbers differ from the paper's Xeon E5-2698v4 (different
//! host), but the *shape* — sub-GOPS throughput dominated by dependency
//! stalls and synchronization — is what the comparison needs (DESIGN.md
//! "Substitutions").

use crate::graph::{Dag, Levels};
use crate::matrix::triangular::solve_serial;
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicUsize, Ordering};
use crate::runtime::sync::{Arc, Barrier};
use std::time::Instant;

/// Measured throughput of one CPU solver.
#[derive(Debug, Clone, Copy)]
pub struct CpuResult {
    /// Best-of-`reps` solve seconds.
    pub seconds: f64,
    /// Throughput in GOPS (binary ops / time).
    pub gops: f64,
}

fn flops(m: &CsrMatrix) -> f64 {
    (2 * m.nnz() - m.n) as f64
}

/// Serial forward substitution, best-of-`reps` wallclock.
pub fn serial_gops(m: &CsrMatrix, b: &[f32], reps: usize) -> CpuResult {
    let mut best = f64::MAX;
    let mut sink = 0f32;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let x = solve_serial(m, b);
        best = best.min(t0.elapsed().as_secs_f64());
        sink += x[m.n - 1];
    }
    std::hint::black_box(sink);
    CpuResult {
        seconds: best,
        gops: flops(m) / best / 1e9,
    }
}

/// Level-scheduled solve with `threads` worker threads and per-level
/// barriers. Returns both the measured throughput and the solution (so
/// tests can verify correctness).
pub fn level_scheduled(
    m: &CsrMatrix,
    b: &[f32],
    threads: usize,
    reps: usize,
) -> (CpuResult, Vec<f32>) {
    let g = Dag::from_csr(m);
    let lv = Levels::compute(&g);
    let threads = threads.max(1);
    let mut best = f64::MAX;
    let mut x_out = vec![0f32; m.n];
    for _ in 0..reps.max(1) {
        let x: Vec<f32> = vec![0f32; m.n];
        let x = Arc::new(XSlot(std::cell::UnsafeCell::new(x)));
        let barrier = Arc::new(Barrier::new(threads));
        let counter = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let x = Arc::clone(&x);
                let barrier = Arc::clone(&barrier);
                let counter = Arc::clone(&counter);
                let lv = &lv;
                let m = &m;
                let b = &b;
                scope.spawn(move || {
                    for l in 0..lv.num_levels() {
                        let nodes = lv.level(l);
                        // Dynamic chunking over the level.
                        loop {
                            // relaxed: chunk-claim ticket; rows are
                            // published by the level barrier, not by it.
                            let k = counter.fetch_add(8, Ordering::Relaxed);
                            if k >= nodes.len() {
                                break;
                            }
                            let hi = (k + 8).min(nodes.len());
                            // SAFETY: nodes within a level are disjoint rows
                            // whose inputs were finalized by prior-level
                            // barriers.
                            let xs = unsafe { &mut *x.0.get() };
                            for &i in &nodes[k..hi] {
                                let i = i as usize;
                                let ie = m.rowptr[i + 1] - 1;
                                let mut sum = 0f32;
                                for j in m.rowptr[i]..ie {
                                    sum += m.values[j] * xs[m.colidx[j] as usize];
                                }
                                xs[i] = (b[i] - sum) / m.values[ie];
                            }
                        }
                        let w = barrier.wait();
                        if w.is_leader() {
                            // relaxed: reset between the two barriers; no
                            // worker reads it until the second wait.
                            counter.store(0, Ordering::Relaxed);
                        }
                        barrier.wait();
                    }
                });
            }
        });
        best = best.min(t0.elapsed().as_secs_f64());
        x_out = Arc::try_unwrap(x).map(|c| c.0.into_inner()).unwrap_or_default();
    }
    (
        CpuResult {
            seconds: best,
            gops: flops(m) / best / 1e9,
        },
        x_out,
    )
}

/// Interior-mutable solution buffer shared across level workers.
/// Levels are data-race-free by construction (disjoint rows per level,
/// barriers between levels).
struct XSlot(std::cell::UnsafeCell<Vec<f32>>);
// SAFETY: workers only touch disjoint rows within a level (the chunk
// counter partitions them) and a barrier separates levels, so no two
// threads ever access the same element without a happens-before edge.
unsafe impl Sync for XSlot {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;

    #[test]
    fn serial_gops_positive() {
        let m = gen::circuit(2000, 5, 0.8, GenSeed(1));
        let b = vec![1.0f32; m.n];
        let r = serial_gops(&m, &b, 3);
        assert!(r.gops > 0.0 && r.seconds > 0.0);
    }

    #[test]
    fn level_scheduled_is_correct() {
        let m = gen::grid2d(30, 30, true, GenSeed(2));
        let b: Vec<f32> = (0..m.n).map(|i| (i % 9) as f32 - 4.0).collect();
        for threads in [1, 2, 4] {
            let (_, x) = level_scheduled(&m, &b, threads, 1);
            assert_close_to_reference(&m, &b, &x, 1e-3);
        }
    }

    #[test]
    fn level_scheduled_chain_correct() {
        // Degenerate: n levels of width 1.
        let m = gen::chain(200, GenSeed(3));
        let b = vec![2.0f32; m.n];
        let (_, x) = level_scheduled(&m, &b, 4, 1);
        assert_close_to_reference(&m, &b, &x, 1e-3);
    }

    #[test]
    fn single_thread_level_matches_serial_result() {
        let m = gen::circuit(500, 5, 0.8, GenSeed(4));
        let b = vec![1.0f32; m.n];
        let (_, x) = level_scheduled(&m, &b, 1, 1);
        assert_close_to_reference(&m, &b, &x, 1e-4);
    }
}
