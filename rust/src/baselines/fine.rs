//! The fine dataflow baseline — a DPU-v2-style model (paper §II.C, Fig. 3).
//!
//! The coarse DAG is converted into a *binary DAG*: a row with `k`
//! off-diagonal entries becomes `k` multiply nodes, a `k−1`-node balanced
//! add-reduction, one subtract and one reciprocal-multiply — `2k+1` fine
//! nodes, `2·nnz − n` in total (the paper's "binary nodes").
//!
//! The binary DAG is scheduled onto `T` tree-shaped PE arrays of depth `D`
//! (DPU-v2's default 56 PEs = 8 trees × 7 PEs). Each cycle a tree executes
//! one *block*: a connected ready subgraph of at most `2^D − 1` nodes
//! (combinational chaining inside the tree); the block's top value is
//! written back to the register files. Per the paper's fairness rule the
//! fine PEs perform one basic op per cycle but run at **2× clock**.
//!
//! Simplifications (favourable to the fine baseline — documented in
//! DESIGN.md): register banks are idealized (no conflicts), block formation
//! is greedy without lookahead.

use crate::graph::Dag;
use anyhow::{bail, Result};

/// Tree-array configuration (DPU-v2 default: 8 trees of depth 3).
#[derive(Debug, Clone, Copy)]
pub struct FineConfig {
    /// Number of tree-shaped PE arrays.
    pub trees: usize,
    /// Depth of each tree (PEs per tree = 2^depth − 1).
    pub depth: usize,
    /// Clock in Hz (paper: DPU-v2 at 300 MHz = 2× this work).
    pub clock_hz: f64,
    /// Cycles before a block's outputs are consumable by a later block
    /// (pipeline + register-file writeback; Fig. 6's example has 9 blocks
    /// costing 19 cycles ≈ 2 cycles between dependent blocks).
    pub pipeline_latency: u64,
    /// External operand fetches per tree per cycle: every leaf value a
    /// block consumes from the register files occupies a bank port. The
    /// paper blames exactly this traffic ("the increased number of nodes
    /// exacerbates bank conflicts") for DPU-v2's inefficiency on
    /// SpTRSV-like DAGs.
    pub operand_ports: usize,
}

impl Default for FineConfig {
    fn default() -> Self {
        Self {
            trees: 8,
            depth: 3,
            clock_hz: 300e6,
            pipeline_latency: 2,
            operand_ports: 3,
        }
    }
}

/// Result of a fine-dataflow run.
#[derive(Debug, Clone)]
pub struct FineResult {
    /// Cycles at the fine clock.
    pub cycles: u64,
    /// Binary nodes executed (== 2·nnz − n).
    pub fine_nodes: u64,
    /// Register-file writebacks (one per block).
    pub writebacks: u64,
    /// Blocks executed.
    pub blocks: u64,
}

impl FineResult {
    /// Throughput in GOPS (`flops` = binary nodes, each one basic op).
    pub fn gops(&self, cfg: &FineConfig) -> f64 {
        self.fine_nodes as f64 / (self.cycles as f64 / cfg.clock_hz) / 1e9
    }
}

/// Internal binary-DAG node.
#[derive(Debug, Clone, Copy)]
struct BNode {
    /// Remaining unsolved inputs (0, 1 or 2).
    pending: u8,
    /// Dynamic-input arity (initial `pending`): register-file fetches the
    /// node needs when all its inputs come from outside its block.
    arity: u8,
    /// Unique internal consumer, or `u32::MAX` for x-producing nodes whose
    /// consumers are the mul nodes of later rows (fan-out).
    consumer: u32,
}

/// Build the binary DAG and simulate the tree scheduler.
pub fn simulate(g: &Dag, cfg: &FineConfig) -> Result<FineResult> {
    let n = g.n;
    // --- Build the binary DAG. ---
    // Node numbering per row i: k muls, then the add-reduction in layers,
    // then sub, then final mul (the x producer).
    let mut nodes: Vec<BNode> = Vec::with_capacity(2 * g.num_edges() + n);
    // Per coarse node: the binary node producing x_i.
    let mut x_node = vec![0u32; n];
    // Fan-out lists from x producers to mul nodes, filled after numbering.
    let mut mul_of_edge: Vec<(u32, u32)> = Vec::with_capacity(g.num_edges()); // (src, mul node)
    for i in 0..n {
        let k = g.in_degree(i);
        let mut layer: Vec<u32> = Vec::with_capacity(k.max(1));
        for &s in g.preds(i) {
            let id = nodes.len() as u32;
            // mul: inputs = L (constant) and x_s → 1 dynamic input.
            nodes.push(BNode {
                pending: 1,
                arity: 1,
                consumer: u32::MAX,
            });
            mul_of_edge.push((s, id));
            layer.push(id);
        }
        // Balanced add reduction.
        while layer.len() > 1 {
            let mut next = Vec::with_capacity(layer.len().div_ceil(2));
            let mut it = layer.chunks(2);
            for pair in &mut it {
                if pair.len() == 2 {
                    let id = nodes.len() as u32;
                    nodes.push(BNode {
                        pending: 2,
                        arity: 2,
                        consumer: u32::MAX,
                    });
                    nodes[pair[0] as usize].consumer = id;
                    nodes[pair[1] as usize].consumer = id;
                    next.push(id);
                } else {
                    next.push(pair[0]);
                }
            }
            layer = next;
        }
        // sub (b_i − acc): dynamic input = reduction top (or none for k=0,
        // in which case the paper's count has a single node: fold sub+mul).
        let xid = if k == 0 {
            let id = nodes.len() as u32;
            nodes.push(BNode {
                pending: 0,
                arity: 0,
                consumer: u32::MAX,
            });
            id
        } else {
            let sub = nodes.len() as u32;
            nodes.push(BNode {
                pending: 1,
                arity: 1,
                consumer: u32::MAX,
            });
            nodes[layer[0] as usize].consumer = sub;
            let fin = nodes.len() as u32;
            nodes.push(BNode {
                pending: 1,
                arity: 1,
                consumer: u32::MAX,
            });
            nodes[sub as usize].consumer = fin;
            fin
        };
        x_node[i] = xid;
    }
    let total = nodes.len() as u64;
    let expect = 2 * (g.num_edges() as u64 + n as u64) - n as u64;
    if total != expect {
        bail!("binary DAG has {total} nodes, expected {expect}");
    }
    // Fan-out: x producer → mul nodes of consuming rows.
    let mut fanout_ptr = vec![0usize; n + 1];
    for &(s, _) in &mul_of_edge {
        fanout_ptr[s as usize + 1] += 1;
    }
    for i in 0..n {
        fanout_ptr[i + 1] += fanout_ptr[i];
    }
    let mut fanout = vec![0u32; mul_of_edge.len()];
    {
        let mut cursor = fanout_ptr.clone();
        for &(s, mulid) in &mul_of_edge {
            fanout[cursor[s as usize]] = mulid;
            cursor[s as usize] += 1;
        }
    }

    // --- Tree scheduler. ---
    let block_cap = (1usize << cfg.depth) - 1;
    let mut ready: Vec<u32> = (0..nodes.len() as u32)
        .filter(|&id| nodes[id as usize].pending == 0)
        .collect();
    let mut done = vec![false; nodes.len()];
    let mut executed = 0u64;
    let mut cycles = 0u64;
    let mut blocks = 0u64;
    let mut writebacks = 0u64;
    let mut completed_x: Vec<u32> = Vec::new();
    // Map from x-producer binary node to coarse node for fan-out resolution.
    let mut coarse_of_x = vec![u32::MAX; nodes.len()];
    for i in 0..n {
        coarse_of_x[x_node[i] as usize] = i as u32;
    }
    let mut in_block = vec![false; nodes.len()];
    // Results of a block become visible `pipeline_latency` cycles later.
    let lat = cfg.pipeline_latency.max(1) as usize;
    let mut delay_buf: std::collections::VecDeque<Vec<u32>> =
        std::collections::VecDeque::with_capacity(lat);
    while executed < total {
        if cycles > 8 * total * lat as u64 + 64 {
            bail!("fine dataflow did not converge");
        }
        let mut newly_done: Vec<u32> = Vec::new();
        for _tree in 0..cfg.trees {
            // Build one block from the ready pool (LIFO: favours chains).
            let Some(seed) = ready.pop() else { break };
            let mut block: Vec<u32> = vec![seed];
            in_block[seed as usize] = true;
            let mut top = seed;
            // Every dynamic input consumed from outside the block costs one
            // register-file port; the tree has `operand_ports` of them.
            let mut fetches = nodes[seed as usize].arity as usize;
            while block.len() < block_cap {
                let c = nodes[top as usize].consumer;
                if c == u32::MAX {
                    break;
                }
                let cn = nodes[c as usize];
                // The consumer joins if its other inputs are already done or
                // inside the block: pending counts only not-done inputs; one
                // of them is `top` (in block).
                let outside_pending = cn.pending as usize - 1;
                let done_inputs = cn.arity as usize - cn.pending as usize;
                if outside_pending == 0 {
                    // Remaining done inputs are external RF fetches.
                    if fetches + done_inputs > cfg.operand_ports {
                        break;
                    }
                    fetches += done_inputs;
                    block.push(c);
                    in_block[c as usize] = true;
                    top = c;
                } else if outside_pending == 1 {
                    // Try to pull the sibling from the ready pool.
                    if let Some(pos) = ready
                        .iter()
                        .rposition(|&r| nodes[r as usize].consumer == c)
                    {
                        let sib_arity = nodes[ready[pos] as usize].arity as usize;
                        if fetches + sib_arity + done_inputs > cfg.operand_ports
                            || block.len() + 2 > block_cap
                        {
                            break;
                        }
                        fetches += sib_arity + done_inputs;
                        let sib = ready.swap_remove(pos);
                        block.push(sib);
                        in_block[sib as usize] = true;
                        block.push(c);
                        in_block[c as usize] = true;
                        top = c;
                    } else {
                        break;
                    }
                } else {
                    break;
                }
                if block.len() >= block_cap {
                    break;
                }
            }
            blocks += 1;
            writebacks += 1; // the block's top value goes back to the RF
            for &id in &block {
                done[id as usize] = true;
                newly_done.push(id);
            }
            executed += block.len() as u64;
        }
        // Results become visible after the pipeline latency.
        delay_buf.push_back(newly_done);
        let visible = if delay_buf.len() >= lat {
            delay_buf.pop_front().unwrap()
        } else {
            Vec::new()
        };
        for &id in &visible {
            in_block[id as usize] = false;
            let c = nodes[id as usize].consumer;
            if c != u32::MAX && !done[c as usize] {
                let cn = &mut nodes[c as usize];
                cn.pending -= 1;
                if cn.pending == 0 {
                    ready.push(c);
                }
            }
            let coarse = coarse_of_x[id as usize];
            if coarse != u32::MAX {
                completed_x.push(coarse);
            }
        }
        for &cx in &completed_x {
            for k in fanout_ptr[cx as usize]..fanout_ptr[cx as usize + 1] {
                let mulid = fanout[k] as usize;
                nodes[mulid].pending -= 1;
                if nodes[mulid].pending == 0 {
                    ready.push(mulid as u32);
                }
            }
        }
        completed_x.clear();
        cycles += 1;
    }
    Ok(FineResult {
        cycles,
        fine_nodes: total,
        writebacks,
        blocks,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    fn run(m: &CsrMatrix) -> FineResult {
        simulate(&Dag::from_csr(m), &FineConfig::default()).unwrap()
    }

    #[test]
    fn binary_node_count_matches_paper_formula() {
        let m = gen::circuit(300, 5, 0.8, GenSeed(1));
        let r = run(&m);
        assert_eq!(r.fine_nodes as usize, 2 * m.nnz() - m.n);
    }

    #[test]
    fn fig1_completes() {
        let m = CsrMatrix::paper_fig1();
        let r = run(&m);
        assert_eq!(r.fine_nodes as usize, 2 * m.nnz() - m.n);
        assert!(r.cycles >= 5);
    }

    #[test]
    fn chain_is_serial_with_double_nodes() {
        let m = gen::chain(40, GenSeed(2));
        let r = run(&m);
        // Fully sequential binary chain: roughly one node per cycle except
        // where blocks chain mul→sub→final inside one tree pass.
        assert!(r.cycles >= 40, "{}", r.cycles);
    }

    #[test]
    fn blocks_bounded_by_capacity() {
        let m = gen::grid2d(15, 15, true, GenSeed(3));
        let r = run(&m);
        assert!(r.fine_nodes <= r.blocks * 7);
        assert_eq!(r.blocks, r.writebacks);
    }

    #[test]
    fn gops_positive_and_below_peak() {
        let m = gen::banded(500, 6, 0.6, GenSeed(4));
        let cfg = FineConfig::default();
        let r = simulate(&Dag::from_csr(&m), &cfg).unwrap();
        let g = r.gops(&cfg);
        // 56 PEs × 300 MHz = 16.8 GOPS peak (Table IV).
        assert!(g > 0.0 && g <= 16.8 + 1e-9, "{g}");
    }

    #[test]
    fn medium_beats_fine_on_cdu_heavy_dag() {
        // High-in-degree (hub) rows generate many intermediate fine nodes
        // and writebacks — the regime where the paper's medium dataflow
        // wins (Fig. 9(a): add20 / ACTIVSg2000 / dw2048 analogs).
        use crate::compiler::{schedule_only, CompilerConfig};
        let m = gen::circuit(1500, 8, 0.7, GenSeed(5));
        let medium = schedule_only(&m, &CompilerConfig::default()).unwrap();
        let fine_cfg = FineConfig::default();
        let fine = simulate(&Dag::from_csr(&m), &fine_cfg).unwrap();
        let arch = crate::arch::ArchConfig::default();
        let flops = (2 * m.nnz() - m.n) as u64;
        let medium_gops =
            flops as f64 / (medium.stats.cycles as f64 / arch.clock_hz) / 1e9;
        let fine_gops = fine.gops(&fine_cfg);
        assert!(
            medium_gops > fine_gops,
            "medium {medium_gops} vs fine {fine_gops}"
        );
    }
}
