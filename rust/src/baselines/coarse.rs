//! The coarse dataflow baseline (paper §V.B).
//!
//! "The coarse dataflow represents the dataflow used by the
//! synchronization-free method. For a fair comparison, the coarse dataflow
//! is implemented on our architecture, excluding the effect of cache misses
//! and thread synchronizations on GPUs."
//!
//! A coarse node is the minimal *task scheduling* unit: a CU may only start
//! a node once **all** of its predecessors are solved, then it computes the
//! node's edges one per cycle plus the final self-update. Node→CU
//! allocation is identical to the medium dataflow (topological
//! round-robin), ports are idealized — exactly the paper's fig. 9(a)
//! comparison setup.

use crate::compiler::allocation::Allocation;
use crate::graph::Dag;
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// Cycle count and utilization of a coarse-dataflow run.
#[derive(Debug, Clone)]
pub struct CoarseResult {
    /// Total cycles.
    pub cycles: u64,
    /// Executed op slots (== nnz).
    pub exec: u64,
    /// Blocked CU-cycles.
    pub blocked: u64,
}

impl CoarseResult {
    /// PE utilization.
    pub fn utilization(&self, num_cus: usize) -> f64 {
        self.exec as f64 / (self.cycles.max(1) as f64 * num_cus as f64)
    }

    /// Throughput in GOPS at `clock_hz` for `flops` binary ops.
    pub fn gops(&self, clock_hz: f64, flops: u64) -> f64 {
        flops as f64 / (self.cycles as f64 / clock_hz) / 1e9
    }
}

/// Simulate the coarse dataflow cycle count.
pub fn simulate(g: &Dag, alloc: &Allocation) -> Result<CoarseResult> {
    let n = g.n;
    let num_cus = alloc.tasks.len();
    // Per node: number of unsolved predecessors.
    let mut unsolved_preds: Vec<u32> = (0..n).map(|i| g.in_degree(i) as u32).collect();
    let mut solved = vec![false; n];
    // Per CU: fully-ready unstarted nodes (ascending id = task order).
    let mut ready: Vec<BTreeSet<u32>> = vec![BTreeSet::new(); num_cus];
    for i in 0..n {
        if unsolved_preds[i] == 0 {
            ready[alloc.cu_of[i] as usize].insert(i as u32);
        }
    }
    // Per CU: (node, remaining ops) of the node in flight.
    let mut in_flight: Vec<Option<(u32, u32)>> = vec![None; num_cus];
    let mut done = 0usize;
    let mut cycles = 0u64;
    let mut exec = 0u64;
    let mut blocked = 0u64;
    while done < n {
        if cycles > 4 * (g.num_edges() as u64 + n as u64) + 16 {
            bail!("coarse dataflow did not converge");
        }
        let mut solved_now: Vec<u32> = Vec::new();
        for cu in 0..num_cus {
            if in_flight[cu].is_none() {
                if let Some(&u) = ready[cu].iter().next() {
                    ready[cu].remove(&u);
                    // ops = edges + final.
                    in_flight[cu] = Some((u, g.in_degree(u as usize) as u32 + 1));
                }
            }
            match in_flight[cu].as_mut() {
                None => blocked += 1,
                Some((node, remaining)) => {
                    exec += 1;
                    *remaining -= 1;
                    if *remaining == 0 {
                        solved_now.push(*node);
                        in_flight[cu] = None;
                    }
                }
            }
        }
        for &j in &solved_now {
            solved[j as usize] = true;
            done += 1;
            for &dst in g.succs(j as usize) {
                unsolved_preds[dst as usize] -= 1;
                if unsolved_preds[dst as usize] == 0 {
                    ready[alloc.cu_of[dst as usize] as usize].insert(dst);
                }
            }
        }
        cycles += 1;
    }
    Ok(CoarseResult {
        cycles,
        exec,
        blocked,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::allocation::{allocate, AllocationPolicy};
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::CsrMatrix;

    fn run(m: &CsrMatrix, cus: usize) -> CoarseResult {
        let g = Dag::from_csr(m);
        let alloc = allocate(&g, cus, AllocationPolicy::RoundRobin);
        simulate(&g, &alloc).unwrap()
    }

    #[test]
    fn exec_slots_equal_nnz() {
        let m = gen::circuit(300, 5, 0.8, GenSeed(1));
        let r = run(&m, 16);
        assert_eq!(r.exec as usize, m.nnz());
    }

    #[test]
    fn chain_is_fully_serial() {
        let m = gen::chain(30, GenSeed(2));
        let r = run(&m, 8);
        // Node 0: 1 op; others: 2 ops each, strictly sequential.
        assert_eq!(r.cycles, 1 + 29 * 2);
    }

    #[test]
    fn wide_dag_gets_parallel_speedup() {
        let m = gen::shallow(2000, 0.2, GenSeed(3));
        let r1 = run(&m, 1);
        let r64 = run(&m, 64);
        assert!(r64.cycles * 16 < r1.cycles, "{} vs {}", r64.cycles, r1.cycles);
    }

    #[test]
    fn coarse_never_beats_medium() {
        // The medium dataflow starts edges as soon as any dependency is
        // ready; coarse must wait for all. On CDU-heavy DAGs medium wins.
        use crate::compiler::{schedule_only, CompilerConfig};
        let m = gen::banded(400, 8, 0.6, GenSeed(4));
        let cfg = CompilerConfig {
            arch: crate::arch::ArchConfig {
                log2_cus: 4,
                ..Default::default()
            },
            ..CompilerConfig::default()
        };
        let medium = schedule_only(&m, &cfg).unwrap();
        let coarse = run(&m, 16);
        assert!(
            medium.stats.cycles <= coarse.cycles,
            "medium {} vs coarse {}",
            medium.stats.cycles,
            coarse.cycles
        );
    }

    #[test]
    fn accounting_sums() {
        let m = gen::grid2d(20, 20, false, GenSeed(5));
        let r = run(&m, 16);
        assert_eq!(r.exec + r.blocked, r.cycles * 16);
    }
}
