//! # MGD-SpTRSV
//!
//! Reproduction of *"Efficient Hardware Accelerator Based on Medium
//! Granularity Dataflow for SpTRSV"* (Chen, Yang, Lu — IEEE TVLSI 2024).
//!
//! The library is organized as the paper's hardware/software codesign:
//!
//! - [`matrix`] — sparse triangular matrix substrate (CSR/CSC, generators,
//!   MatrixMarket IO, reference solvers).
//! - [`graph`] — the DAG view of a triangular matrix (levels, CDU statistics,
//!   peak-throughput model).
//! - [`compiler`] — the paper's custom compiler: coarse-node allocation,
//!   medium-granularity dataflow scheduling, partial-sum caching, intra-node
//!   edge-computation reordering (ICR), bank coloring, register allocation,
//!   and bit-accurate instruction encoding.
//! - [`sim`] — a cycle-accurate simulator of the 2^N-CU VLIW accelerator
//!   (CUs, crossbar interconnects, software-managed memories, energy model).
//! - [`baselines`] — coarse dataflow, fine dataflow (DPU-v2 model), CPU and
//!   GPU comparators.
//! - [`runtime`] — PJRT (via the `xla` crate) loader/executor for the
//!   AOT-compiled JAX/Pallas level kernels in `artifacts/`.
//! - [`coordinator`] — the L3 solve service: multi-RHS batching over the
//!   numeric runtime plus per-solve accelerator metrics.
//! - [`bench_harness`] — regenerates every table and figure of the paper's
//!   evaluation (see DESIGN.md §3).
//!
//! ## Quickstart
//!
//! ```no_run
//! use mgd_sptrsv::matrix::gen::{self, GenSeed};
//! use mgd_sptrsv::compiler::{CompilerConfig, compile};
//! use mgd_sptrsv::sim::Accelerator;
//!
//! let m = gen::circuit(500, 6, 0.8, GenSeed(42));
//! let prog = compile(&m, &CompilerConfig::default()).unwrap();
//! let b = vec![1.0f32; m.n];
//! let mut acc = Accelerator::new(prog.arch);
//! let run = acc.run(&prog, &b).unwrap();
//! let x_ref = mgd_sptrsv::matrix::triangular::solve_serial(&m, &b);
//! for (a, r) in run.x.iter().zip(&x_ref) {
//!     assert!((a - r).abs() <= 1e-3 * r.abs().max(1.0));
//! }
//! ```

pub mod arch;
pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod compiler;
pub mod coordinator;
pub mod graph;
pub mod matrix;
pub mod runtime;
pub mod sim;
pub mod util;
