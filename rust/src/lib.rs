//! # MGD-SpTRSV
//!
//! Reproduction of *"Efficient Hardware Accelerator Based on Medium
//! Granularity Dataflow for SpTRSV"* (Chen, Yang, Lu — IEEE TVLSI 2024).
//!
//! The library is organized as the paper's hardware/software codesign:
//!
//! - [`matrix`] — sparse triangular matrix substrate (CSR/CSC, generators,
//!   MatrixMarket IO, reference solvers).
//! - [`graph`] — the DAG view of a triangular matrix (levels, CDU statistics,
//!   peak-throughput model).
//! - [`compiler`] — the paper's custom compiler: coarse-node allocation,
//!   medium-granularity dataflow scheduling, partial-sum caching, intra-node
//!   edge-computation reordering (ICR), bank coloring, register allocation,
//!   and bit-accurate instruction encoding.
//! - [`sim`] — a cycle-accurate simulator of the 2^N-CU VLIW accelerator
//!   (CUs, crossbar interconnects, software-managed memories, energy model).
//! - [`baselines`] — coarse dataflow, fine dataflow (DPU-v2 model), CPU and
//!   GPU comparators.
//! - [`runtime`] — the pluggable numeric serve path: a `SolverBackend`
//!   trait over a shared plan (`LevelSolver`, which also carries a cached
//!   medium-granularity `MgdPlan`). The default `NativeBackend` is pure
//!   Rust with a scheduler seam (`--scheduler level|mgd|auto`): the
//!   barriered *level* executor is retained as the simple/reference
//!   scheduler, while the *mgd* scheduler runs the paper's
//!   medium-granularity dataflow at serve time — barrier-free node
//!   scheduling with work-stealing deques, atomic dependency counters
//!   (Release/Acquire protocol in `runtime/atomics.md`), node-local
//!   partial sums and ICR-ordered gathers — bitwise-identical to the
//!   serial reference at any thread count; `auto` picks per matrix from
//!   level-width statistics. MGD workers live in a **persistent pool**
//!   (`runtime/pool.rs`): spawned once per backend, parked on a condvar
//!   between solves, shared across every solve and matrix the backend
//!   serves — no per-solve thread spawns on the serve path. Pool
//!   sessions **overlap**: each solve leases at most its plan's
//!   `par_width` workers and leftover workers serve other sessions
//!   concurrently, with the overlap counted in
//!   `MgdPoolStats::{concurrent_sessions, peak_concurrency}`. Leases are
//!   **class-aware**: a configurable count of workers is reserved for
//!   `RequestClass::Latency` sessions, so bulk floods can never lease
//!   the pool dry. An optional PJRT loader/executor for the
//!   AOT-compiled JAX/Pallas level kernels in `artifacts/` sits behind
//!   the `pjrt` cargo feature.
//! - [`coordinator`] — the L3 serving runtime: a sharded, multi-matrix
//!   `ShardedSolveService` over a `MatrixRegistry`. Each matrix is
//!   registered by key and compiled/simulated/planned exactly once;
//!   requests (`SolveRequest { matrix_key, b, reply, class }`) route to
//!   the shard owning their matrix, where workers batch same-matrix,
//!   same-class requests through the backend's multi-RHS path. Matrices are dynamic:
//!   `evict(key)` drains a key's in-flight requests and retires it, and
//!   `swap(key, m)` hot-swaps a key's matrix atomically while requests
//!   keep flowing. Admission is **bounded and class-aware**: each shard
//!   holds two queue lanes (latency drained before bulk) capped by
//!   `queue_cap`, an `AdmissionPolicy` (`block|shed|by-class`) decides
//!   what a full lane does, `try_route` reports the verdict without
//!   parking, and `SolveHandle::wait_timeout` gives callers deadlines.
//!   Completion is **waker-based** (`coordinator/completion.rs`): replies
//!   land in one-shot completion cells, so a `SolveHandle` can block,
//!   poll with a registered waker, fire an `on_ready` callback, or
//!   convert to a zero-dependency `Future` — no parked OS thread per
//!   in-flight request. Streaming clients open a `SolveSession`
//!   (`coordinator/session.rs`): key lineage and class pinned once, RHS
//!   pipelined with a bounded in-session depth, hot swaps observed as
//!   epoch boundaries.
//!   Per-shard counters aggregate into service-wide `ServingStats`
//!   (pool-session concurrency, per-class admitted/shed counts, queue
//!   depth high-water mark). Backend construction failures fail startup,
//!   unknown keys and shed requests get an immediate error reply, and
//!   solver errors are replied to the requester.
//!   `SolveService` is the single-matrix facade over the same machinery.
//! - [`bench_harness`] — regenerates every table and figure of the paper's
//!   evaluation (see DESIGN.md §3), plus a native-vs-PJRT backend
//!   comparison table (`mgd bench backends`), a level-vs-mgd scheduler
//!   comparison (`mgd bench schedulers`, emits `BENCH_schedulers.json`),
//!   a persistent-pool vs per-solve-spawn serving comparison
//!   (`mgd bench serving`, emits `BENCH_serving.json`), and an
//!   overlapped-vs-serialized pool-session comparison
//!   (`mgd bench concurrency`, emits `BENCH_concurrency.json`), a
//!   latency-tail-under-bulk-flood admission comparison
//!   (`mgd bench admission`, emits `BENCH_admission.json`), and a
//!   pipelined-session vs call-per-solve streaming comparison
//!   (`mgd bench streaming`, emits `BENCH_streaming.json`). CI gates
//!   the headline ratios against `ci/bench_baselines/`.
//!
//! ## Cargo features
//!
//! - `pjrt` (off by default): compiles the PJRT client wrapper and the
//!   `PjrtBackend`. The default build is pure Rust — no XLA toolchain, no
//!   prebuilt HLO artifacts, zero FFI. With the feature on, backend
//!   selection (`BackendKind::Auto`) still falls back to native unless the
//!   artifacts actually load, and builds without the toolchain link
//!   against the in-tree `xla_shim` stub so `--features pjrt` always
//!   compiles.
//!
//! ## Quickstart
//!
//! ```no_run
//! use mgd_sptrsv::matrix::gen::{self, GenSeed};
//! use mgd_sptrsv::compiler::{CompilerConfig, compile};
//! use mgd_sptrsv::sim::Accelerator;
//!
//! let m = gen::circuit(500, 6, 0.8, GenSeed(42));
//! let prog = compile(&m, &CompilerConfig::default()).unwrap();
//! let b = vec![1.0f32; m.n];
//! let mut acc = Accelerator::new(prog.arch);
//! let run = acc.run(&prog, &b).unwrap();
//! let x_ref = mgd_sptrsv::matrix::triangular::solve_serial(&m, &b);
//! for (a, r) in run.x.iter().zip(&x_ref) {
//!     assert!((a - r).abs() <= 1e-3 * r.abs().max(1.0));
//! }
//! ```

// Public API must be documented: combined with the CI rustdoc job
// (`RUSTDOCFLAGS="-D warnings" cargo doc --no-deps`) and clippy's
// `-D warnings`, an undocumented public item or a broken intra-doc link
// fails the build.
#![warn(missing_docs)]
// Every unsafe operation must sit in its own `unsafe {}` block inside an
// unsafe fn, each carrying the `// SAFETY:` comment `ci/lint_sync.py`
// enforces — the safety argument is per-operation, never inherited from
// the enclosing function signature.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod arch;
pub mod baselines;
pub mod bench_harness;
pub mod cli;
pub mod compiler;
pub mod coordinator;
pub mod graph;
pub mod matrix;
pub mod runtime;
pub mod sim;
pub mod util;
