//! Per-CU simulator state: the PE (cascaded fp multiplier + adder), the
//! feedback DFF, the local psum register file, the stream/RHS FIFO heads
//! and the data-memory append log (Fig. 4(b)).

use anyhow::{bail, ensure, Result};

/// One compute unit's architectural state.
#[derive(Debug, Clone)]
pub struct CuSim {
    /// Feedback register (the psum DFF).
    pub feedback: f32,
    /// Output register: the value produced last cycle, and whether it was a
    /// solution (`ct = 0`) that downstream PEs may consume by forwarding.
    pub out_solution: Option<f32>,
    /// psum register file (data + valid bits).
    psum_data: Vec<f32>,
    psum_valid: Vec<bool>,
    /// Stream-memory FIFO head (L values and reciprocal diagonals).
    pub l_ptr: usize,
    /// RHS FIFO head.
    pub b_ptr: usize,
    /// Data-memory append log (solutions in solve order).
    pub dm: Vec<f32>,
}

impl CuSim {
    /// Fresh CU with a `psum_words`-entry psum RF.
    pub fn new(psum_words: usize) -> Self {
        Self {
            feedback: 0.0,
            out_solution: None,
            psum_data: vec![0.0; psum_words],
            psum_valid: vec![false; psum_words],
            l_ptr: 0,
            b_ptr: 0,
            dm: Vec::new(),
        }
    }

    /// Read (and release) a parked partial sum.
    pub fn psum_read(&mut self, addr: usize) -> Result<f32> {
        ensure!(
            addr < self.psum_data.len() && self.psum_valid[addr],
            "psum read of invalid address {addr}"
        );
        self.psum_valid[addr] = false;
        Ok(self.psum_data[addr])
    }

    /// Park a partial sum at the priority encoder's lowest free address
    /// (hardware auto-generates the write address — Fig. 5(c)).
    pub fn psum_park(&mut self, value: f32) -> Result<usize> {
        match self.psum_valid.iter().position(|v| !v) {
            Some(a) => {
                self.psum_data[a] = value;
                self.psum_valid[a] = true;
                Ok(a)
            }
            None => bail!("psum register file overflow"),
        }
    }

    /// Occupied psum slots.
    pub fn psum_occupancy(&self) -> usize {
        self.psum_valid.iter().filter(|&&v| v).count()
    }

    /// The PE datapath (paper eq. 2): a serial fp32 multiply → add pair.
    ///
    /// - `ct = 1`: `psum + l * x`
    /// - `ct = 0`: `(b − psum) * l` where `l` is the compiler-computed
    ///   reciprocal diagonal.
    pub fn pe(ct: bool, psum: f32, l: f32, x_or_b: f32) -> f32 {
        if ct {
            psum + l * x_or_b
        } else {
            (x_or_b - psum) * l
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pe_mac_mode() {
        assert_eq!(CuSim::pe(true, 1.0, 2.0, 3.0), 7.0);
    }

    #[test]
    fn pe_final_mode() {
        // (b - psum) * recip = (10 - 4) * 0.5 = 3
        assert_eq!(CuSim::pe(false, 4.0, 0.5, 10.0), 3.0);
    }

    #[test]
    fn psum_park_resume() {
        let mut cu = CuSim::new(2);
        assert_eq!(cu.psum_park(1.5).unwrap(), 0);
        assert_eq!(cu.psum_park(2.5).unwrap(), 1);
        assert!(cu.psum_park(3.0).is_err());
        assert_eq!(cu.psum_read(0).unwrap(), 1.5);
        assert_eq!(cu.psum_occupancy(), 1);
        // Freed slot is reused first (priority encoder).
        assert_eq!(cu.psum_park(9.0).unwrap(), 0);
    }

    #[test]
    fn psum_invalid_read_detected() {
        let mut cu = CuSim::new(2);
        assert!(cu.psum_read(0).is_err());
    }
}
