//! The whole-accelerator cycle loop.
//!
//! Per-cycle ordering contract (shared with the compiler's emission mirror,
//! see `compiler::program`):
//!
//! 1. operand reads observe start-of-cycle register-file state (the input
//!    crossbar routes bank readouts and last-cycle forwards);
//! 2. PEs execute; psum RF reads release before parks land
//!    (read-before-write);
//! 3. `R_vs` read releases free `x_i` addresses;
//! 4. spill evictions free addresses;
//! 5. output-crossbar writes land at each bank's priority-encoder address.

use super::cu::CuSim;
use super::interconnect::XiBanks;
use crate::arch::ArchConfig;
use crate::compiler::isa::{NopKind, PsumSrc, XiSrc};
use crate::compiler::Program;
use anyhow::{bail, ensure, Context, Result};

/// Activity counters measured by the simulator (inputs to the energy model
/// and the Fig. 10 instruction breakdown).
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Total cycles executed.
    pub cycles: u64,
    /// Executed op slots.
    pub exec: u64,
    /// MAC ops.
    pub macs: u64,
    /// Final (solve) ops.
    pub finals: u64,
    /// Nop cycles by kind.
    pub bnop: u64,
    /// psum-capacity nops.
    pub pnop: u64,
    /// dependency nops.
    pub dnop: u64,
    /// load-imbalance nops.
    pub lnop: u64,
    /// Distinct `x_i` bank readouts (broadcast counted once).
    pub xi_reads: u64,
    /// `x_i` bank writes.
    pub xi_writes: u64,
    /// Operand consumptions served by forwarding.
    pub forwards: u64,
    /// psum RF reads.
    pub psum_reads: u64,
    /// psum RF writes (parks).
    pub psum_writes: u64,
    /// Data-memory writes (one per solved node).
    pub dm_writes: u64,
    /// Data-memory reads (spill reloads).
    pub dm_reads: u64,
    /// Stream-memory words consumed (L values + reciprocal diagonals).
    pub stream_reads: u64,
    /// RHS words consumed.
    pub b_reads: u64,
    /// Peak `x_i` RF occupancy across all banks.
    pub max_xi_occupancy: usize,
    /// Peak psum RF occupancy of any CU.
    pub max_psum_occupancy: usize,
}

impl RunStats {
    /// PE utilization (paper reports up to 75.3%).
    pub fn utilization(&self, num_cus: usize) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.exec as f64 / (self.cycles as f64 * num_cus as f64)
    }

    /// The double-entry check: simulated counts must equal the compiler's
    /// predicted schedule statistics exactly.
    pub fn verify_against(&self, p: &crate::compiler::SchedStats) -> Result<()> {
        ensure!(self.cycles == p.cycles, "cycles {} != predicted {}", self.cycles, p.cycles);
        ensure!(self.exec == p.exec, "exec {} != predicted {}", self.exec, p.exec);
        ensure!(self.macs == p.macs, "macs {} != predicted {}", self.macs, p.macs);
        ensure!(self.finals == p.finals, "finals {} != predicted {}", self.finals, p.finals);
        ensure!(self.bnop == p.bnop, "bnop {} != predicted {}", self.bnop, p.bnop);
        ensure!(self.pnop == p.pnop, "pnop {} != predicted {}", self.pnop, p.pnop);
        ensure!(self.dnop == p.dnop, "dnop {} != predicted {}", self.dnop, p.dnop);
        ensure!(self.lnop == p.lnop, "lnop {} != predicted {}", self.lnop, p.lnop);
        ensure!(
            self.forwards == p.forwards,
            "forwards {} != predicted {}",
            self.forwards,
            p.forwards
        );
        Ok(())
    }
}

/// Result of one simulated solve.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// The solution vector, scattered from the data-memory logs.
    pub x: Vec<f32>,
    /// Measured activity.
    pub stats: RunStats,
}

impl RunResult {
    /// Solve latency in seconds at the architecture clock.
    pub fn seconds(&self, arch: &ArchConfig) -> f64 {
        self.stats.cycles as f64 * arch.clock_period()
    }

    /// Throughput in GOPS for a program with `flops` binary operations.
    pub fn gops(&self, arch: &ArchConfig, flops: u64) -> f64 {
        flops as f64 / self.seconds(arch) / 1e9
    }
}

/// The accelerator instance.
#[derive(Debug)]
pub struct Accelerator {
    arch: ArchConfig,
}

impl Accelerator {
    /// Build an accelerator with the given configuration.
    pub fn new(arch: ArchConfig) -> Self {
        Self { arch }
    }

    /// Execute a compiled program against a right-hand side.
    pub fn run(&mut self, prog: &Program, b: &[f32]) -> Result<RunResult> {
        ensure!(
            prog.arch == self.arch,
            "program compiled for a different architecture"
        );
        ensure!(b.len() == prog.n, "rhs length {} != n {}", b.len(), prog.n);
        let p = prog.num_cus();
        let cycles = prog.instrs.first().map_or(0, Vec::len);
        for row in &prog.instrs {
            ensure!(row.len() == cycles, "ragged instruction streams");
        }
        // Gather per-CU RHS FIFOs (the stream memory is compiler-reordered).
        let b_stream: Vec<Vec<f32>> = prog
            .solve_order
            .iter()
            .map(|order| order.iter().map(|&i| b[i as usize]).collect())
            .collect();
        let mut cus: Vec<CuSim> = (0..p)
            .map(|_| CuSim::new(self.arch.psum_words as usize))
            .collect();
        let mut banks = XiBanks::new(p, self.arch.xi_words());
        let mut stats = RunStats {
            cycles: cycles as u64,
            ..RunStats::default()
        };

        // Per-cycle scratch.
        let mut x_operand: Vec<f32> = vec![0.0; p];
        let mut pending_release: Vec<(usize, usize)> = Vec::new();
        let mut pending_evict: Vec<(usize, usize)> = Vec::new();
        let mut pending_write: Vec<(usize, f32)> = Vec::new();
        let mut new_out: Vec<Option<f32>> = vec![None; p];

        for t in 0..cycles {
            banks.begin_cycle();
            pending_release.clear();
            pending_evict.clear();
            pending_write.clear();
            // --- Phase A: operand fetch (start-of-cycle state). ---
            for cu in 0..p {
                let ins = &prog.instrs[cu][t];
                if ins.block || !ins.ct {
                    continue;
                }
                x_operand[cu] = match ins.xi_src {
                    XiSrc::Bank => {
                        let v = banks
                            .read(ins.in_sel as usize, ins.xi_raddr as usize)
                            .with_context(|| format!("cu {cu} cycle {t}"))?;
                        if ins.xi_release {
                            pending_release.push((ins.in_sel as usize, ins.xi_raddr as usize));
                        }
                        v
                    }
                    XiSrc::Forward => {
                        let src_cu = ins.in_sel as usize;
                        stats.forwards += 1;
                        cus[src_cu].out_solution.with_context(|| {
                            format!("cu {cu} cycle {t}: forward from cu {src_cu} with no solution")
                        })?
                    }
                    XiSrc::Dm => {
                        let owner = ins.dm_owner as usize;
                        let addr = ins.dm_raddr as usize;
                        stats.dm_reads += 1;
                        ensure!(
                            addr < cus[owner].dm.len(),
                            "cu {cu} cycle {t}: dm read past log ({addr})"
                        );
                        cus[owner].dm[addr]
                    }
                };
            }
            stats.xi_reads += banks.reads_this_cycle() as u64;
            // --- Phase B: execute. ---
            for cu_idx in 0..p {
                let ins = &prog.instrs[cu_idx][t];
                if ins.block {
                    match ins.nop {
                        NopKind::Bnop => stats.bnop += 1,
                        NopKind::Pnop => stats.pnop += 1,
                        NopKind::Dnop => stats.dnop += 1,
                        NopKind::Lnop => stats.lnop += 1,
                    }
                    continue;
                }
                let cu = &mut cus[cu_idx];
                let fb_old = cu.feedback;
                // psum read releases before the park lands.
                let psum_rf_val = if ins.psum_read {
                    stats.psum_reads += 1;
                    Some(cu.psum_read(ins.psum_raddr as usize)?)
                } else {
                    None
                };
                if ins.psum_write {
                    stats.psum_writes += 1;
                    cu.psum_park(fb_old)
                        .with_context(|| format!("cu {cu_idx} cycle {t}"))?;
                }
                stats.max_psum_occupancy = stats.max_psum_occupancy.max(cu.psum_occupancy());
                let psum_in = match ins.psum_src {
                    PsumSrc::Feedback => fb_old,
                    PsumSrc::Zero => 0.0,
                    PsumSrc::ReadRf => {
                        psum_rf_val.context("ReadRf without psum_read")?
                    }
                };
                ensure!(
                    cu.l_ptr < prog.l_stream[cu_idx].len(),
                    "cu {cu_idx} stream underrun at cycle {t}"
                );
                let l = prog.l_stream[cu_idx][cu.l_ptr];
                cu.l_ptr += 1;
                stats.stream_reads += 1;
                stats.exec += 1;
                let out = if ins.ct {
                    stats.macs += 1;
                    CuSim::pe(true, psum_in, l, x_operand[cu_idx])
                } else {
                    stats.finals += 1;
                    stats.b_reads += 1;
                    ensure!(
                        cu.b_ptr < b_stream[cu_idx].len(),
                        "cu {cu_idx} rhs underrun at cycle {t}"
                    );
                    let bv = b_stream[cu_idx][cu.b_ptr];
                    cu.b_ptr += 1;
                    CuSim::pe(false, psum_in, l, bv)
                };
                cu.feedback = out;
                if ins.ct {
                    new_out[cu_idx] = None;
                } else {
                    new_out[cu_idx] = Some(out);
                    if ins.dm_write {
                        stats.dm_writes += 1;
                        cu.dm.push(out);
                    }
                    if ins.xi_write {
                        if ins.evict {
                            pending_evict.push((ins.out_sel as usize, ins.evict_addr as usize));
                        }
                        pending_write.push((ins.out_sel as usize, out));
                    }
                }
            }
            // --- Phases C/D/E: releases, evictions, writes. ---
            for &(bank, addr) in &pending_release {
                banks.release(bank, addr);
            }
            for &(bank, addr) in &pending_evict {
                banks
                    .evict(bank, addr)
                    .with_context(|| format!("cycle {t}"))?;
            }
            for &(bank, v) in &pending_write {
                stats.xi_writes += 1;
                banks
                    .write(bank, v)
                    .with_context(|| format!("cycle {t}"))?;
            }
            stats.max_xi_occupancy = stats.max_xi_occupancy.max(banks.occupancy());
            // Output registers become visible to the next cycle's forwards.
            for cu_idx in 0..p {
                let ins = &prog.instrs[cu_idx][t];
                if !ins.block {
                    cus[cu_idx].out_solution = new_out[cu_idx];
                }
                // A blocked CU retains its previous output register — but a
                // forward is only ever scheduled for the cycle right after
                // the solve, so stale values are never consumed.
            }
        }
        // --- Drain checks. ---
        for (cu_idx, cu) in cus.iter().enumerate() {
            ensure!(
                cu.l_ptr == prog.l_stream[cu_idx].len(),
                "cu {cu_idx}: {} stream words unconsumed",
                prog.l_stream[cu_idx].len() - cu.l_ptr
            );
            ensure!(
                cu.b_ptr == b_stream[cu_idx].len(),
                "cu {cu_idx}: rhs words unconsumed"
            );
            ensure!(
                cu.dm.len() == prog.solve_order[cu_idx].len(),
                "cu {cu_idx}: dm log incomplete"
            );
        }
        // Scatter the solution from the data-memory logs.
        let mut x = vec![0f32; prog.n];
        let mut written = vec![false; prog.n];
        for (cu_idx, order) in prog.solve_order.iter().enumerate() {
            for (k, &node) in order.iter().enumerate() {
                ensure!(!written[node as usize], "node {node} solved twice");
                written[node as usize] = true;
                x[node as usize] = cus[cu_idx].dm[k];
            }
        }
        if let Some(miss) = written.iter().position(|&w| !w) {
            bail!("node {miss} never solved");
        }
        Ok(RunResult { x, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompilerConfig};
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use crate::matrix::CsrMatrix;

    fn small_arch(log2_cus: u32) -> ArchConfig {
        ArchConfig {
            log2_cus,
            ..ArchConfig::default()
        }
    }

    fn roundtrip(m: &CsrMatrix, cfg: &CompilerConfig) -> RunResult {
        let prog = compile(m, cfg).unwrap();
        let b: Vec<f32> = (0..m.n).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let mut acc = Accelerator::new(cfg.arch);
        let run = acc.run(&prog, &b).unwrap();
        assert_close_to_reference(m, &b, &run.x, 1e-3);
        run.stats.verify_against(&prog.predicted).unwrap();
        run
    }

    #[test]
    fn fig1_numerics_and_cycles() {
        let cfg = CompilerConfig {
            arch: small_arch(2),
            ..CompilerConfig::default()
        };
        roundtrip(&CsrMatrix::paper_fig1(), &cfg);
    }

    #[test]
    fn generator_suite_roundtrip() {
        let cfg = CompilerConfig {
            arch: small_arch(4),
            ..CompilerConfig::default()
        };
        for m in [
            gen::chain(40, GenSeed(1)),
            gen::banded(200, 5, 0.6, GenSeed(2)),
            gen::circuit(400, 5, 0.8, GenSeed(3)),
            gen::grid2d(15, 15, true, GenSeed(4)),
            gen::power_law(300, 1.2, 60, GenSeed(5)),
        ] {
            roundtrip(&m, &cfg);
        }
    }

    #[test]
    fn default_64cu_arch_roundtrip() {
        let cfg = CompilerConfig::default();
        roundtrip(&gen::circuit(1500, 6, 0.8, GenSeed(6)), &cfg);
    }

    #[test]
    fn spilling_config_roundtrip() {
        // Tiny x_i RF: forces evictions and dm reloads; numerics must hold.
        let cfg = CompilerConfig {
            arch: ArchConfig {
                log2_cus: 3,
                log2_xi_words: 2,
                ..ArchConfig::default()
            },
            ..CompilerConfig::default()
        };
        let run = roundtrip(&gen::circuit(500, 6, 0.5, GenSeed(7)), &cfg);
        assert!(run.stats.dm_reads > 0, "expected spill reloads");
    }

    #[test]
    fn no_icr_no_coloring_roundtrip() {
        let cfg = CompilerConfig {
            arch: small_arch(4),
            use_icr: false,
            use_coloring: false,
            ..CompilerConfig::default()
        };
        roundtrip(&gen::factor_like(300, 6, 3, GenSeed(8)), &cfg);
    }

    #[test]
    fn no_forwarding_roundtrip() {
        let cfg = CompilerConfig {
            arch: small_arch(4),
            forwarding: false,
            ..CompilerConfig::default()
        };
        let run = roundtrip(&gen::banded(250, 4, 0.7, GenSeed(9)), &cfg);
        assert_eq!(run.stats.forwards, 0);
    }

    #[test]
    fn psum_zero_roundtrip() {
        let cfg = CompilerConfig {
            arch: ArchConfig {
                log2_cus: 4,
                psum_words: 0,
                ..ArchConfig::default()
            },
            ..CompilerConfig::default()
        };
        let run = roundtrip(&gen::circuit(300, 5, 0.8, GenSeed(10)), &cfg);
        assert_eq!(run.stats.psum_writes, 0);
    }

    #[test]
    fn utilization_in_range() {
        let cfg = CompilerConfig::default();
        let run = roundtrip(&gen::grid2d(40, 40, true, GenSeed(11)), &cfg);
        let u = run.stats.utilization(64);
        assert!(u > 0.05 && u <= 1.0, "utilization {u}");
    }

    #[test]
    fn rejects_wrong_arch() {
        let cfg = CompilerConfig::default();
        let prog = compile(&gen::chain(10, GenSeed(12)), &cfg).unwrap();
        let mut acc = Accelerator::new(small_arch(3));
        assert!(acc.run(&prog, &vec![1.0; 10]).is_err());
    }

    #[test]
    fn rejects_wrong_rhs_len() {
        let cfg = CompilerConfig::default();
        let prog = compile(&gen::chain(10, GenSeed(13)), &cfg).unwrap();
        let mut acc = Accelerator::new(cfg.arch);
        assert!(acc.run(&prog, &vec![1.0; 9]).is_err());
    }
}
