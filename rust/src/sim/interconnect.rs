//! The banked `x_i` register file behind the input/output crossbars.
//!
//! Each CU owns one bank; any CU reads any bank through the input crossbar
//! and any solving CU writes any bank through the output crossbar. Each
//! bank has one read and one write port per cycle; same-address reads in
//! the same cycle share the readout (broadcast). The simulator *checks*
//! these port limits — a violation means the compiler emitted an illegal
//! schedule.

use anyhow::{bail, ensure, Result};

/// One register-file bank with valid bits and a priority-encoder write port.
#[derive(Debug, Clone)]
pub struct Bank {
    data: Vec<f32>,
    valid: Vec<bool>,
}

impl Bank {
    /// Create an empty bank with `words` addresses.
    pub fn new(words: usize) -> Self {
        Self {
            data: vec![0.0; words],
            valid: vec![false; words],
        }
    }

    /// Read `addr`; errors if the address is not valid.
    pub fn read(&self, addr: usize) -> Result<f32> {
        ensure!(self.valid[addr], "read of invalid RF address {addr}");
        Ok(self.data[addr])
    }

    /// Release an address (idempotent within a cycle's broadcast group).
    pub fn release(&mut self, addr: usize) {
        self.valid[addr] = false;
    }

    /// Priority encoder: the lowest free address, if any.
    pub fn lowest_free(&self) -> Option<usize> {
        self.valid.iter().position(|v| !v)
    }

    /// Write through the priority encoder; errors when full.
    pub fn write_auto(&mut self, value: f32) -> Result<usize> {
        match self.lowest_free() {
            Some(a) => {
                self.data[a] = value;
                self.valid[a] = true;
                Ok(a)
            }
            None => bail!("register-file bank overflow"),
        }
    }

    /// Number of live values (occupancy, for stats).
    pub fn occupancy(&self) -> usize {
        self.valid.iter().filter(|&&v| v).count()
    }
}

/// All banks plus per-cycle port accounting.
#[derive(Debug, Clone)]
pub struct XiBanks {
    banks: Vec<Bank>,
    // Per-cycle port state, reset by `begin_cycle`.
    read_addr: Vec<Option<usize>>,
    wrote: Vec<bool>,
}

impl XiBanks {
    /// `num_banks` banks of `words` addresses each.
    pub fn new(num_banks: usize, words: usize) -> Self {
        Self {
            banks: (0..num_banks).map(|_| Bank::new(words)).collect(),
            read_addr: vec![None; num_banks],
            wrote: vec![false; num_banks],
        }
    }

    /// Reset per-cycle port accounting.
    pub fn begin_cycle(&mut self) {
        self.read_addr.iter_mut().for_each(|r| *r = None);
        self.wrote.iter_mut().for_each(|w| *w = false);
    }

    /// Read through the input crossbar, enforcing the 1-read-port limit
    /// (same-address reads broadcast for free).
    pub fn read(&mut self, bank: usize, addr: usize) -> Result<f32> {
        match self.read_addr[bank] {
            None => self.read_addr[bank] = Some(addr),
            Some(prev) if prev == addr => {} // broadcast share
            Some(prev) => bail!(
                "bank {bank} read-port conflict: addresses {prev} and {addr} in one cycle"
            ),
        }
        self.banks[bank].read(addr)
    }

    /// Release an address after its last read (`R_vs`).
    pub fn release(&mut self, bank: usize, addr: usize) {
        self.banks[bank].release(addr);
    }

    /// Evict (spill-release) an address ahead of a write.
    pub fn evict(&mut self, bank: usize, addr: usize) -> Result<()> {
        ensure!(
            self.banks[bank].valid[addr],
            "evict of already-free address {addr} in bank {bank}"
        );
        self.banks[bank].release(addr);
        Ok(())
    }

    /// Write through the output crossbar, enforcing the 1-write-port limit.
    /// Returns the priority-encoder address.
    pub fn write(&mut self, bank: usize, value: f32) -> Result<usize> {
        ensure!(!self.wrote[bank], "bank {bank} write-port conflict");
        self.wrote[bank] = true;
        self.banks[bank].write_auto(value)
    }

    /// Total live values across banks.
    pub fn occupancy(&self) -> usize {
        self.banks.iter().map(Bank::occupancy).sum()
    }

    /// Distinct bank readouts this cycle (for energy accounting).
    pub fn reads_this_cycle(&self) -> usize {
        self.read_addr.iter().filter(|r| r.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut b = XiBanks::new(2, 4);
        b.begin_cycle();
        let a = b.write(0, 3.5).unwrap();
        assert_eq!(a, 0);
        b.begin_cycle();
        assert_eq!(b.read(0, 0).unwrap(), 3.5);
    }

    #[test]
    fn priority_encoder_reuses_lowest() {
        let mut b = Bank::new(4);
        assert_eq!(b.write_auto(1.0).unwrap(), 0);
        assert_eq!(b.write_auto(2.0).unwrap(), 1);
        b.release(0);
        assert_eq!(b.write_auto(3.0).unwrap(), 0);
    }

    #[test]
    fn read_port_conflict_detected() {
        let mut b = XiBanks::new(1, 4);
        b.begin_cycle();
        b.write(0, 1.0).unwrap();
        b.begin_cycle();
        b.write(0, 2.0).unwrap();
        b.begin_cycle();
        assert!(b.read(0, 0).is_ok());
        assert!(b.read(0, 0).is_ok()); // broadcast of same address
        assert!(b.read(0, 1).is_err()); // second distinct address
    }

    #[test]
    fn write_port_conflict_detected() {
        let mut b = XiBanks::new(1, 4);
        b.begin_cycle();
        assert!(b.write(0, 1.0).is_ok());
        assert!(b.write(0, 2.0).is_err());
    }

    #[test]
    fn overflow_detected() {
        let mut b = Bank::new(2);
        b.write_auto(1.0).unwrap();
        b.write_auto(2.0).unwrap();
        assert!(b.write_auto(3.0).is_err());
    }

    #[test]
    fn invalid_read_detected() {
        let b = Bank::new(2);
        assert!(b.read(0).is_err());
    }
}
