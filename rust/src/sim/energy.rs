//! Area/power/energy model calibrated to the paper's synthesis results
//! (Table II: TSMC 28 nm, 64 CUs, 150 MHz).
//!
//! The paper reports per-component area and power at full activity. We take
//! those numbers as coefficients and scale each component's dynamic power by
//! the activity the simulator measured (events per CU-cycle), keeping
//! always-on components (control, pipeline registers, instruction fetch) at
//! unit activity. This reproduces the paper's 156 mW at full utilization by
//! construction and yields activity-proportional energy for Table IV's
//! GOPS/W comparison.

use super::accel::RunStats;
use crate::arch::ArchConfig;

/// One row of Table II.
#[derive(Debug, Clone, Copy)]
pub struct Component {
    /// Component name as printed in Table II.
    pub name: &'static str,
    /// Area in mm².
    pub area_mm2: f64,
    /// Power in mW at full activity (150 MHz, 28 nm).
    pub power_mw: f64,
    /// Whether the component burns its power every cycle regardless of
    /// activity (clock tree / control / fetch).
    pub always_on: bool,
}

/// The paper's Table II breakdown.
pub const PAPER_TABLE2: &[Component] = &[
    Component { name: "PEs", area_mm2: 0.07, power_mw: 16.00, always_on: false },
    Component { name: "Fifos", area_mm2: 0.16, power_mw: 28.22, always_on: false },
    Component { name: "Pipelining registers", area_mm2: 0.02, power_mw: 6.85, always_on: true },
    Component { name: "Input interconnect", area_mm2: 0.04, power_mw: 9.65, always_on: false },
    Component { name: "Output interconnect", area_mm2: 0.04, power_mw: 8.36, always_on: false },
    Component { name: "Register file", area_mm2: 0.28, power_mw: 29.86, always_on: false },
    Component { name: "Control units", area_mm2: 0.02, power_mw: 5.41, always_on: true },
    Component { name: "Multiplexers", area_mm2: 0.00, power_mw: 1.85, always_on: true },
    Component { name: "Data memory", area_mm2: 0.11, power_mw: 7.07, always_on: false },
    Component { name: "Instruction memory", area_mm2: 0.64, power_mw: 17.09, always_on: true },
    Component { name: "Stream memory", area_mm2: 0.72, power_mw: 25.86, always_on: false },
];

/// The energy model: Table II coefficients for a reference configuration.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    components: Vec<Component>,
    /// CU count of the reference design the coefficients describe.
    reference_cus: usize,
}

/// Activity-scaled power/energy estimate for one run.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Average power in watts.
    pub avg_power_w: f64,
    /// Total energy in joules.
    pub energy_j: f64,
    /// Per-component average power (name, watts, activity).
    pub per_component: Vec<(&'static str, f64, f64)>,
    /// Total die area in mm² (static, from Table II).
    pub area_mm2: f64,
}

impl EnergyModel {
    /// The paper's 28 nm / 64-CU / 150 MHz design point.
    pub fn paper_28nm() -> Self {
        Self {
            components: PAPER_TABLE2.to_vec(),
            reference_cus: 64,
        }
    }

    /// Total area of the modeled design (Table II bottom row: 2.11 mm²).
    pub fn total_area_mm2(&self) -> f64 {
        self.components.iter().map(|c| c.area_mm2).sum()
    }

    /// Peak power (all activities = 1; Table II bottom row: 156.21 mW).
    pub fn peak_power_w(&self) -> f64 {
        self.components.iter().map(|c| c.power_mw).sum::<f64>() / 1e3
    }

    /// Estimate average power and energy for a measured run.
    pub fn estimate(&self, stats: &RunStats, arch: &ArchConfig) -> EnergyReport {
        let p = arch.num_cus() as f64;
        let cycles = stats.cycles.max(1) as f64;
        let slots = cycles * p;
        // Scale coefficients if the simulated design has a different CU
        // count than the reference synthesis (linear in CU count; memories
        // kept constant).
        let cu_scale = p / self.reference_cus as f64;
        let act = |events: u64| (events as f64 / slots).min(1.0);
        let mut per_component = Vec::new();
        let mut total_w = 0.0;
        for c in &self.components {
            let activity = if c.always_on {
                1.0
            } else {
                match c.name {
                    "PEs" => act(stats.exec),
                    // Stream FIFOs move one word per executed op.
                    "Fifos" | "Stream memory" => act(stats.stream_reads + stats.b_reads),
                    // One input-crossbar traversal per consumed operand.
                    "Input interconnect" => act(stats.macs),
                    // One output-crossbar traversal per bank write/forward.
                    "Output interconnect" => act(stats.xi_writes + stats.forwards),
                    "Register file" => act(
                        stats.xi_reads + stats.xi_writes + stats.psum_reads + stats.psum_writes,
                    ),
                    "Data memory" => act(stats.dm_writes + stats.dm_reads),
                    _ => 1.0,
                }
            };
            let scale = match c.name {
                // Shared memories do not grow with CU count in our model.
                "Data memory" | "Instruction memory" | "Stream memory" => 1.0,
                _ => cu_scale,
            };
            let w = c.power_mw / 1e3 * activity * scale;
            per_component.push((c.name, w, activity));
            total_w += w;
        }
        let time_s = cycles * arch.clock_period();
        EnergyReport {
            avg_power_w: total_w,
            energy_j: total_w * time_s,
            per_component,
            area_mm2: self.total_area_mm2(),
        }
    }
}

impl EnergyReport {
    /// Energy efficiency in GOPS/W for a run that performed `flops` binary
    /// ops over `cycles` at `arch`'s clock.
    pub fn gops_per_watt(&self, gops: f64) -> f64 {
        if self.avg_power_w == 0.0 {
            return 0.0;
        }
        gops / self.avg_power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_totals_match_paper() {
        let m = EnergyModel::paper_28nm();
        assert!((m.total_area_mm2() - 2.10).abs() < 0.02, "{}", m.total_area_mm2());
        assert!((m.peak_power_w() - 0.15622).abs() < 1e-4, "{}", m.peak_power_w());
    }

    #[test]
    fn idle_run_burns_only_always_on() {
        let m = EnergyModel::paper_28nm();
        let stats = RunStats {
            cycles: 1000,
            ..RunStats::default()
        };
        let arch = ArchConfig::default();
        let rep = m.estimate(&stats, &arch);
        // Always-on: pipeline 6.85 + control 5.41 + mux 1.85 + imem 17.09.
        let expect = (6.85 + 5.41 + 1.85 + 17.09) / 1e3;
        assert!((rep.avg_power_w - expect).abs() < 1e-6, "{}", rep.avg_power_w);
    }

    #[test]
    fn full_activity_approaches_peak() {
        let m = EnergyModel::paper_28nm();
        let arch = ArchConfig::default();
        let slots = 1000 * 64;
        let stats = RunStats {
            cycles: 1000,
            exec: slots,
            macs: slots,
            finals: 0,
            xi_reads: slots,
            xi_writes: slots,
            forwards: slots,
            stream_reads: slots,
            b_reads: slots,
            dm_writes: slots,
            dm_reads: 0,
            psum_reads: 0,
            psum_writes: 0,
            ..RunStats::default()
        };
        let rep = m.estimate(&stats, &arch);
        assert!(
            (rep.avg_power_w - m.peak_power_w()).abs() < 1e-9,
            "{} vs {}",
            rep.avg_power_w,
            m.peak_power_w()
        );
    }

    #[test]
    fn energy_scales_with_time() {
        let m = EnergyModel::paper_28nm();
        let arch = ArchConfig::default();
        let s1 = RunStats {
            cycles: 1000,
            ..RunStats::default()
        };
        let s2 = RunStats {
            cycles: 2000,
            ..RunStats::default()
        };
        let r1 = m.estimate(&s1, &arch);
        let r2 = m.estimate(&s2, &arch);
        assert!((r2.energy_j / r1.energy_j - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gops_per_watt_sane() {
        let rep = EnergyReport {
            avg_power_w: 0.156,
            energy_j: 1e-6,
            per_component: vec![],
            area_mm2: 2.11,
        };
        let e = rep.gops_per_watt(6.5);
        assert!((e - 41.7).abs() < 0.2, "{e}"); // Table IV: 41.4 GOPS/W
    }
}
