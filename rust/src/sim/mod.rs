//! Cycle-accurate simulator of the accelerator (paper §III.B, Fig. 4(b)).
//!
//! The simulator is the stand-in for the paper's SystemVerilog/VCS model
//! (see DESIGN.md "Substitutions"). It executes a compiled [`Program`]'s
//! instruction streams against real register files, crossbars and
//! memories — it never sees the matrix or the DAG. Correctness is
//! established by two independent checks:
//!
//! 1. **numerics**: the scattered data-memory contents must equal the
//!    serial reference solve, and
//! 2. **double-entry cycles**: executed-op/nop counts must equal the
//!    compiler's prediction exactly.
//!
//! [`Program`]: crate::compiler::Program

pub mod accel;
pub mod cu;
pub mod energy;
pub mod interconnect;

pub use accel::{Accelerator, RunResult, RunStats};
pub use energy::{EnergyModel, EnergyReport};
