//! Architecture parameters of the accelerator (paper §III.B, Fig. 4/5 and
//! the synthesis configuration of §V.A).
//!
//! The hyperparameters mirror Table I's `N/M/K/T`:
//! `2^N` compute units, `2^M`-word `x_i` register files and `2^K`-word `psum`
//! register files per CU, and a data memory addressed with `T` bits.

/// Static configuration of one accelerator instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArchConfig {
    /// `N`: number of CUs is `2^N` (paper default: 6 → 64 CUs).
    pub log2_cus: u32,
    /// `M`: `x_i` register file words per CU is `2^M` (default: 6 → 64).
    pub log2_xi_words: u32,
    /// `psum` register file words per CU (paper default 8). Kept as a plain
    /// count (not forced to a power of two) because Fig. 9(b)/(c) sweeps
    /// capacities including 0 = caching disabled.
    pub psum_words: u32,
    /// Data memory words (paper default 8192). Logical solves larger than
    /// this spill to host DRAM in a real system; the simulator treats the
    /// data memory as an append log per CU and reports occupancy.
    pub dm_words: u32,
    /// Instruction memory words (paper default 65536). Reported, not
    /// enforced.
    pub imem_words: u32,
    /// Stream memory words (paper default 65536). Reported, not enforced.
    pub smem_words: u32,
    /// Accelerator clock in Hz (paper: 150 MHz).
    pub clock_hz: f64,
}

impl Default for ArchConfig {
    fn default() -> Self {
        Self {
            log2_cus: 6,
            log2_xi_words: 6,
            psum_words: 8,
            dm_words: 8192,
            imem_words: 65536,
            smem_words: 65536,
            clock_hz: 150e6,
        }
    }
}

impl ArchConfig {
    /// Number of compute units (`2^N`).
    pub fn num_cus(&self) -> usize {
        1usize << self.log2_cus
    }

    /// `x_i` register-file words per CU (`2^M`).
    pub fn xi_words(&self) -> usize {
        1usize << self.log2_xi_words
    }

    /// Architecture peak throughput in GOPS: each CU retires one
    /// multiply+add per cycle (the PE is a serial fp-mul → fp-add pair).
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.num_cus() as f64 * self.clock_hz / 1e9
    }

    /// Clock period in seconds.
    pub fn clock_period(&self) -> f64 {
        1.0 / self.clock_hz
    }

    /// CDU threshold used for Table III statistics: 20% of the maximum
    /// parallelism (paper §V.B).
    pub fn cdu_threshold(&self) -> usize {
        ((self.num_cus() as f64) * crate::graph::CDU_THRESHOLD_FRACTION).ceil() as usize
    }

    /// The paper-faithful VLIW word length in bits (Fig. 5(a)):
    /// psum(1+K) + xi(1+M+1) + dm(1+T) + I/O_en(2N) + S34(2) + PE_en(2) +
    /// S12(2) + ct(1) + block(1).
    pub fn paper_word_bits(&self) -> u32 {
        let k = (self.psum_words.max(2) as f64).log2().ceil() as u32;
        let t = (self.dm_words as f64).log2().ceil() as u32;
        (1 + k) + (1 + self.log2_xi_words + 1) + (1 + t) + 2 * self.log2_cus + 2 + 2 + 2 + 1 + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_synthesis() {
        let a = ArchConfig::default();
        assert_eq!(a.num_cus(), 64);
        assert_eq!(a.xi_words(), 64);
        assert_eq!(a.psum_words, 8);
        // 64 CU × 2 flop × 150 MHz = 19.2 GOPS (Table IV "Peak throughput").
        assert!((a.peak_gops() - 19.2).abs() < 1e-9);
        assert_eq!(a.cdu_threshold(), 13);
    }

    #[test]
    fn word_bits_reasonable() {
        let a = ArchConfig::default();
        // K=3, M=6, T=13, N=6 → 4 + 8 + 14 + 12 + 8 = 46 bits.
        assert_eq!(a.paper_word_bits(), 46);
    }

    #[test]
    fn small_config() {
        let a = ArchConfig {
            log2_cus: 2,
            ..ArchConfig::default()
        };
        assert_eq!(a.num_cus(), 4);
        assert_eq!(a.cdu_threshold(), 1);
    }
}
