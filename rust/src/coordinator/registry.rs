//! Multi-matrix registry of the sharded serving runtime.
//!
//! The paper's accelerator amortizes all per-matrix preprocessing —
//! clustering, ICR reordering, scheduling — across a stream of solves of
//! the same structure. [`MatrixRegistry`] is that amortization boundary
//! for the serving runtime: registering a matrix under a key compiles the
//! accelerator program, runs the cycle-accurate simulation once (the
//! shared cost model and double-entry check), builds the [`LevelSolver`]
//! plan, and assigns the matrix to a shard. Every later request for that
//! key only routes, gathers and executes — no per-request setup of any
//! kind.
//!
//! Shard assignment is round-robin in registration order, which spreads
//! matrices evenly across the service's shards without any knowledge of
//! the request mix; the entry records its shard so routing is a single
//! map lookup.

use super::metrics::SolveMetrics;
use crate::compiler::{compile, CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::LevelSolver;
use crate::sim::Accelerator;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// One registered matrix: everything the serve path needs, prepared once.
pub struct RegisteredMatrix {
    key: String,
    shard: usize,
    solver: Arc<LevelSolver>,
    program: Arc<Program>,
    metrics: SolveMetrics,
    served: AtomicU64,
}

impl RegisteredMatrix {
    /// The registration key requests route by.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Index of the shard that owns this matrix's requests.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The shared solve plan (level sets + cached medium-granularity
    /// plan), built once at registration.
    pub fn solver(&self) -> &Arc<LevelSolver> {
        &self.solver
    }

    /// The compiled accelerator program (inspection, benches).
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Per-solve accelerator metrics from the one-time simulation,
    /// attached to every response for this matrix.
    pub fn metrics(&self) -> &SolveMetrics {
        &self.metrics
    }

    /// Requests served against this matrix so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Count `n` served requests (called by shard workers).
    pub(crate) fn note_served(&self, n: u64) {
        self.served.fetch_add(n, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for RegisteredMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegisteredMatrix")
            .field("key", &self.key)
            .field("shard", &self.shard)
            .field("n", &self.solver.n())
            .field("served", &self.served())
            .finish_non_exhaustive()
    }
}

/// Key → prepared-matrix map with round-robin shard assignment.
///
/// Lookups are lock-cheap (`RwLock` read); registration takes the write
/// lock only to insert — the compile/simulate work happens outside it.
pub struct MatrixRegistry {
    shards: usize,
    compiler: CompilerConfig,
    inner: RwLock<HashMap<String, Arc<RegisteredMatrix>>>,
}

impl MatrixRegistry {
    /// An empty registry assigning matrices across `shards` shards
    /// (clamped to ≥ 1) and compiling with `compiler`.
    pub fn new(shards: usize, compiler: CompilerConfig) -> Self {
        Self {
            shards: shards.max(1),
            compiler,
            inner: RwLock::new(HashMap::new()),
        }
    }

    /// Shards this registry assigns across.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// Register `m` under `key`: compile, simulate once (double-entry
    /// verification + shared cost model), build the solve plan, and
    /// assign a shard. Errors if the key is already registered — a key is
    /// an identity, not a slot to overwrite.
    pub fn register(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        if self.inner.read().unwrap().contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        let program = Arc::new(
            compile(m, &self.compiler).with_context(|| format!("compile matrix {key:?}"))?,
        );
        let mut acc = Accelerator::new(self.compiler.arch);
        let probe_b = vec![1.0f32; m.n];
        let run = acc
            .run(&program, &probe_b)
            .with_context(|| format!("simulate matrix {key:?}"))?;
        run.stats
            .verify_against(&program.predicted)
            .with_context(|| format!("double-entry check for matrix {key:?}"))?;
        let metrics = SolveMetrics::from_run(&run.stats, &self.compiler.arch, program.flops());
        let solver = Arc::new(LevelSolver::new(m));
        let mut map = self.inner.write().unwrap();
        // Re-check under the write lock: a concurrent register of the
        // same key must not be silently clobbered.
        if map.contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        let entry = Arc::new(RegisteredMatrix {
            key: key.to_string(),
            shard: map.len() % self.shards,
            solver,
            program,
            metrics,
            served: AtomicU64::new(0),
        });
        map.insert(key.to_string(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Look up a registered matrix by key.
    pub fn get(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        self.inner.read().unwrap().get(key).cloned()
    }

    /// Remove a registered matrix, returning its entry (registration
    /// rollback, eviction). Requests already routed hold their own `Arc`
    /// and complete normally; later submits for the key get the
    /// unknown-key error reply, and the key may be registered again.
    /// Future shard assignment derives from the current map size, so
    /// removal can skew balance slightly — acceptable for these cases.
    pub fn remove(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        self.inner.write().unwrap().remove(key)
    }

    /// Registered matrix count.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    /// True when nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered keys, sorted (stable output for tables and logs).
    pub fn keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.inner.read().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    fn registry(shards: usize) -> MatrixRegistry {
        MatrixRegistry::new(shards, CompilerConfig::default())
    }

    #[test]
    fn registers_and_looks_up() {
        let reg = registry(2);
        assert!(reg.is_empty());
        let m = gen::banded(150, 4, 0.6, GenSeed(61));
        let entry = reg.register("band", &m).unwrap();
        assert_eq!(entry.key(), "band");
        assert_eq!(entry.metrics().cycles, entry.program().predicted.cycles);
        assert_eq!(entry.solver().n(), m.n);
        assert_eq!(reg.len(), 1);
        let again = reg.get("band").unwrap();
        assert!(Arc::ptr_eq(&entry, &again));
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn shard_assignment_is_round_robin() {
        let reg = registry(3);
        let mut shards = Vec::new();
        for k in 0..5 {
            let m = gen::chain(40 + k, GenSeed(62 + k as u64));
            shards.push(reg.register(&format!("m{k}"), &m).unwrap().shard());
        }
        assert_eq!(shards, vec![0, 1, 2, 0, 1]);
        assert_eq!(reg.keys(), vec!["m0", "m1", "m2", "m3", "m4"]);
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let reg = registry(2);
        let m = gen::chain(60, GenSeed(63));
        reg.register("dup", &m).unwrap();
        let err = reg.register("dup", &m).unwrap_err();
        assert!(format!("{err:#}").contains("already registered"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn remove_frees_the_key_for_reregistration() {
        let reg = registry(2);
        let m = gen::chain(70, GenSeed(65));
        let entry = reg.register("evict", &m).unwrap();
        let removed = reg.remove("evict").unwrap();
        assert!(Arc::ptr_eq(&entry, &removed));
        assert!(reg.get("evict").is_none());
        assert!(reg.is_empty());
        assert!(reg.remove("evict").is_none());
        // The key is free again.
        reg.register("evict", &m).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let reg = registry(0);
        assert_eq!(reg.num_shards(), 1);
        let m = gen::chain(30, GenSeed(64));
        assert_eq!(reg.register("only", &m).unwrap().shard(), 0);
    }
}
