//! Multi-matrix registry of the sharded serving runtime, with live
//! eviction and hot swap.
//!
//! The paper's accelerator amortizes all per-matrix preprocessing —
//! clustering, ICR reordering, scheduling — across a stream of solves of
//! the same structure. [`MatrixRegistry`] is that amortization boundary
//! for the serving runtime: registering a matrix under a key compiles the
//! accelerator program, runs the cycle-accurate simulation once (the
//! shared cost model and double-entry check), builds the [`LevelSolver`]
//! plan, and assigns the matrix to a shard. Every later request for that
//! key only routes, gathers and executes — no per-request setup of any
//! kind.
//!
//! The registry is also the matrix **lifecycle** boundary:
//!
//! - [`MatrixRegistry::evict`] retires a key: the key becomes unknown
//!   immediately (new submits get the error reply), the call drains the
//!   requests already routed against the entry, and the plan drops with
//!   the last reference. The key is then free for re-registration.
//! - [`MatrixRegistry::swap`] replaces a key's matrix **live**: the new
//!   entry is compiled/simulated/planned entirely off the hot path, then
//!   published under the write lock in one pointer move — a concurrent
//!   request observes either the old fully-formed entry or the new one,
//!   never a torn mix. In-flight requests against the old entry finish
//!   on the plan they resolved (their `Arc` keeps it alive).
//!
//! Shard assignment is round-robin in registration order, which spreads
//! matrices evenly across the service's shards without any knowledge of
//! the request mix; the entry records its shard so routing is a single
//! map lookup. A swap keeps the old entry's shard, so a key never
//! migrates between request queues mid-stream.

use super::metrics::SolveMetrics;
use crate::compiler::{compile, CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::sync::{Arc, Condvar, Mutex, RwLock};
use crate::runtime::{LevelSolver, RequestClass};
use crate::sim::Accelerator;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parking spot for [`MatrixRegistry::evict`]: the evictor waits here for
/// the lineage's in-flight count to drain instead of burning a core in a
/// poll loop. Lineage-shared like the counters, so a drain covers
/// requests routed against any entry the key ever resolved to.
#[derive(Default)]
struct DrainGate {
    lock: Mutex<()>,
    drained: Condvar,
}

/// One registered matrix: everything the serve path needs, prepared once.
///
/// The `served` and `inflight` counters are **lineage-shared**: a
/// [`MatrixRegistry::swap`] clones them into the replacement entry, so a
/// key's counters stay exact across swaps — a reply delivered against a
/// pre-swap entry still counts, and an evict drains requests in flight
/// against *any* entry the key ever resolved to. Evict + re-register
/// starts a fresh lineage (counters reset).
pub struct RegisteredMatrix {
    key: String,
    shard: usize,
    solver: Arc<LevelSolver>,
    program: Arc<Program>,
    metrics: SolveMetrics,
    served: Arc<AtomicU64>,
    /// Requests routed against this key whose replies have not been
    /// delivered yet — what [`MatrixRegistry::evict`] drains.
    inflight: Arc<AtomicU64>,
    /// Where the evictor parks while the drain completes (lineage-shared
    /// with `inflight`).
    drain: Arc<DrainGate>,
    /// The class a request for this key runs under when it carries no
    /// class of its own.
    default_class: RequestClass,
}

impl RegisteredMatrix {
    /// The registration key requests route by.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Index of the shard that owns this matrix's requests.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The shared solve plan (level sets + cached medium-granularity
    /// plan), built once at registration.
    pub fn solver(&self) -> &Arc<LevelSolver> {
        &self.solver
    }

    /// The compiled accelerator program (inspection, benches).
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Per-solve accelerator metrics from the one-time simulation,
    /// attached to every response for this matrix.
    pub fn metrics(&self) -> &SolveMetrics {
        &self.metrics
    }

    /// Requests served against this key so far — a per-key lifetime
    /// counter, exact across [`MatrixRegistry::swap`] (the counter is
    /// shared with the replaced entry, so late replies against it still
    /// land here); reset by evict + re-register.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Requests currently routed against this key (any entry in its swap
    /// lineage) and not yet replied.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    /// The scheduling class a request for this key runs under when it
    /// carries no class of its own — set at
    /// [`MatrixRegistry::register_with_class`] /
    /// [`MatrixRegistry::swap_with_class`], `Bulk` otherwise.
    pub fn default_class(&self) -> RequestClass {
        self.default_class
    }

    /// Count `n` served requests (called by shard workers).
    pub(crate) fn note_served(&self, n: u64) {
        self.served.fetch_add(n, Ordering::Relaxed);
    }

    /// One request finished (replied or dropped); pairs with the
    /// increment `MatrixRegistry::checkout` performed at route time.
    /// The request that drains the lineage to zero wakes any evictor
    /// parked on the drain gate; the empty critical section orders the
    /// notification after the evictor's check-then-wait, so the wakeup
    /// cannot be lost.
    pub(crate) fn note_done(&self) {
        if self.inflight.fetch_sub(1, Ordering::Release) == 1 {
            let _guard = self.drain.lock.lock().unwrap();
            self.drain.drained.notify_all();
        }
    }
}

impl std::fmt::Debug for RegisteredMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegisteredMatrix")
            .field("key", &self.key)
            .field("shard", &self.shard)
            .field("n", &self.solver.n())
            .field("served", &self.served())
            .field("inflight", &self.inflight())
            .finish_non_exhaustive()
    }
}

/// Key → prepared-matrix map with round-robin shard assignment, live
/// eviction and atomic hot swap.
///
/// Lookups are lock-cheap (`RwLock` read); registration and swap take the
/// write lock only to publish — the compile/simulate work happens outside
/// it.
pub struct MatrixRegistry {
    shards: usize,
    compiler: CompilerConfig,
    inner: RwLock<HashMap<String, Arc<RegisteredMatrix>>>,
}

impl MatrixRegistry {
    /// An empty registry assigning matrices across `shards` shards
    /// (clamped to ≥ 1) and compiling with `compiler`.
    pub fn new(shards: usize, compiler: CompilerConfig) -> Self {
        Self {
            shards: shards.max(1),
            compiler,
            inner: RwLock::new(HashMap::new()),
        }
    }

    /// Shards this registry assigns across.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// Compile, simulate (double-entry verification + shared cost model)
    /// and plan one matrix — the expensive part of registration and swap,
    /// always run with **no registry lock held**. The cheap
    /// [`RegisteredMatrix`] wrapper is assembled by the caller once the
    /// shard and lineage counters are known (at publish time).
    fn prepare_parts(
        &self,
        key: &str,
        m: &CsrMatrix,
    ) -> Result<(Arc<Program>, SolveMetrics, Arc<LevelSolver>)> {
        let program = Arc::new(
            compile(m, &self.compiler).with_context(|| format!("compile matrix {key:?}"))?,
        );
        let mut acc = Accelerator::new(self.compiler.arch);
        let probe_b = vec![1.0f32; m.n];
        let run = acc
            .run(&program, &probe_b)
            .with_context(|| format!("simulate matrix {key:?}"))?;
        run.stats
            .verify_against(&program.predicted)
            .with_context(|| format!("double-entry check for matrix {key:?}"))?;
        let metrics = SolveMetrics::from_run(&run.stats, &self.compiler.arch, program.flops());
        let solver = Arc::new(LevelSolver::new(m));
        // Debug builds statically audit a freshly built medium-granularity
        // plan at every registration and swap — the static tier of the
        // verification ladder (`MgdPlan::verify`, also exposed as `mgd
        // check`). Built standalone on purpose: `LevelSolver::mgd_plan`
        // caches its first config, and the backend — not the registry —
        // owns the thread-count choice that picks the served plan's shape.
        #[cfg(debug_assertions)]
        crate::runtime::MgdPlan::build(m, crate::runtime::MgdPlanConfig::default())
            .verify()
            .with_context(|| format!("static plan audit for matrix {key:?}"))?;
        Ok((program, metrics, solver))
    }

    /// Register `m` under `key`: compile, simulate once, build the solve
    /// plan, and assign a shard. Errors if the key is already registered
    /// — a key is an identity, not a slot to overwrite (use
    /// [`MatrixRegistry::swap`] to replace a live key). Requests for the
    /// key default to the `Bulk` class; use
    /// [`MatrixRegistry::register_with_class`] for latency-critical keys.
    pub fn register(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        self.register_with_class(key, m, RequestClass::Bulk)
    }

    /// [`MatrixRegistry::register`] with an explicit per-key default
    /// [`RequestClass`]: requests that carry no class of their own are
    /// admitted, queued and executed under `class`.
    pub fn register_with_class(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: RequestClass,
    ) -> Result<Arc<RegisteredMatrix>> {
        if self.inner.read().unwrap().contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        let (program, metrics, solver) = self.prepare_parts(key, m)?;
        let mut map = self.inner.write().unwrap();
        // Re-check under the write lock: a concurrent register of the
        // same key must not be silently clobbered.
        if map.contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        // Shard assignment and the fresh lineage counters are decided
        // here, under the write lock — the single derivation point.
        let entry = Arc::new(RegisteredMatrix {
            key: key.to_string(),
            shard: map.len() % self.shards,
            solver,
            program,
            metrics,
            served: Arc::new(AtomicU64::new(0)),
            inflight: Arc::new(AtomicU64::new(0)),
            drain: Arc::new(DrainGate::default()),
            default_class: class,
        });
        map.insert(key.to_string(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Replace the matrix registered under `key` **live**. The new entry
    /// is built (compile + simulate + plan) with no lock held, `warm` is
    /// invoked on it (the service points this at
    /// [`SolverBackend::prepare`](crate::runtime::SolverBackend::prepare)
    /// so the owning shard's backend caches the new plan before any
    /// request can reach it), and only then is the map entry swapped in
    /// one atomic pointer move — no request ever observes a torn entry.
    ///
    /// The new entry keeps the old entry's shard (routing stays stable)
    /// and **shares** its lineage counters: `served` keeps counting
    /// exactly (late replies against the old entry still land on the
    /// key), and a later evict drains requests in flight against either
    /// entry. Requests in flight against the old entry complete on the
    /// plan they resolved. Errors if `key` is not registered, or if it
    /// was evicted — or evicted and re-registered as a fresh lineage —
    /// while the replacement was being built; if two swaps of the same
    /// key (and so the same lineage) race, the later publish wins.
    pub fn swap<F>(&self, key: &str, m: &CsrMatrix, warm: F) -> Result<Arc<RegisteredMatrix>>
    where
        F: FnOnce(&Arc<RegisteredMatrix>) -> Result<()>,
    {
        self.swap_with_class(key, m, None, warm)
    }

    /// [`MatrixRegistry::swap`] that also sets the key's default
    /// [`RequestClass`]: `Some(class)` re-classes the key, `None` keeps
    /// the class of the entry being replaced.
    pub fn swap_with_class<F>(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: Option<RequestClass>,
        warm: F,
    ) -> Result<Arc<RegisteredMatrix>>
    where
        F: FnOnce(&Arc<RegisteredMatrix>) -> Result<()>,
    {
        let Some(old) = self.get(key) else {
            bail!("swap: matrix key {key:?} is not registered");
        };
        let (program, metrics, solver) = self.prepare_parts(key, m)?;
        let entry = Arc::new(RegisteredMatrix {
            key: key.to_string(),
            shard: old.shard(),
            solver,
            program,
            metrics,
            served: Arc::clone(&old.served),
            inflight: Arc::clone(&old.inflight),
            drain: Arc::clone(&old.drain),
            default_class: class.unwrap_or(old.default_class),
        });
        warm(&entry)?;
        let mut map = self.inner.write().unwrap();
        // Publish only into the lineage the replacement was built from
        // (same shared counters). `contains_key` would be an ABA hole: an
        // evict + re-register racing with the off-lock build would let
        // this swap clobber the fresh registration with an entry wired to
        // the retired lineage's counters — miscounting served requests
        // and letting a later evict return before draining. A racing swap
        // of the same lineage still wins normally.
        match map.get(key) {
            Some(current) if Arc::ptr_eq(&current.inflight, &entry.inflight) => {}
            _ => bail!(
                "swap: matrix key {key:?} was evicted (or evicted and re-registered) \
                 while the replacement was being built"
            ),
        }
        map.insert(key.to_string(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Look up a registered matrix by key (inspection only — does **not**
    /// mark a request in flight; the serve path uses the crate-internal
    /// `checkout`, which does).
    pub fn get(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        self.inner.read().unwrap().get(key).cloned()
    }

    /// Resolve `key` for one request and mark it in flight — the
    /// increment happens under the read lock, so an
    /// [`evict`](MatrixRegistry::evict) (which holds the write lock to
    /// unmap the key) either sees the request in its drain or the request
    /// sees the key already gone; there is no window where both miss each
    /// other. Callers must pair this with
    /// `RegisteredMatrix::note_done` once the reply is delivered (the
    /// service does so via a drop guard, so even dropped jobs check in).
    pub(crate) fn checkout(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        let map = self.inner.read().unwrap();
        let entry = map.get(key).cloned()?;
        entry.inflight.fetch_add(1, Ordering::AcqRel);
        Some(entry)
    }

    /// Remove a registered matrix immediately, returning its entry
    /// (registration rollback; [`MatrixRegistry::evict`] is the draining
    /// form). Requests already routed hold their own `Arc` and complete
    /// normally; later submits for the key get the unknown-key error
    /// reply, and the key may be registered again. Future shard
    /// assignment derives from the current map size, so removal can skew
    /// balance slightly — acceptable for these cases.
    pub fn remove(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        self.inner.write().unwrap().remove(key)
    }

    /// Evict `key`: unmap it (new submits immediately get the
    /// unknown-key error reply), then **block until every request already
    /// routed against the entry has been replied to**, and return the
    /// drained entry — dropping it releases the plan. `None` if the key
    /// was not registered.
    ///
    /// The wait parks on the lineage's drain gate (a `Condvar` signaled
    /// by the request that drains `inflight` to zero) instead of
    /// polling: an evictor blocked behind a slow solve costs nothing
    /// until the wakeup. Because the key is unmapped first, `inflight`
    /// is monotonically non-increasing here — once zero, it stays zero.
    pub fn evict(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        let entry = self.remove(key)?;
        let mut guard = entry.drain.lock.lock().unwrap();
        // The check runs under the gate's lock and `note_done` notifies
        // under the same lock, so the last decrement either happens
        // before this check (we never wait) or its notification happens
        // after we wait — a lost-wakeup window does not exist.
        while entry.inflight() > 0 {
            guard = entry.drain.drained.wait(guard).unwrap();
        }
        drop(guard);
        Some(entry)
    }

    /// Registered matrix count.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    /// True when nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered keys, sorted (stable output for tables and logs).
    pub fn keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.inner.read().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    fn registry(shards: usize) -> MatrixRegistry {
        MatrixRegistry::new(shards, CompilerConfig::default())
    }

    #[test]
    fn registers_and_looks_up() {
        let reg = registry(2);
        assert!(reg.is_empty());
        let m = gen::banded(150, 4, 0.6, GenSeed(61));
        let entry = reg.register("band", &m).unwrap();
        assert_eq!(entry.key(), "band");
        assert_eq!(entry.metrics().cycles, entry.program().predicted.cycles);
        assert_eq!(entry.solver().n(), m.n);
        assert_eq!(entry.inflight(), 0);
        assert_eq!(reg.len(), 1);
        let again = reg.get("band").unwrap();
        assert!(Arc::ptr_eq(&entry, &again));
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn shard_assignment_is_round_robin() {
        let reg = registry(3);
        let mut shards = Vec::new();
        for k in 0..5 {
            let m = gen::chain(40 + k, GenSeed(62 + k as u64));
            shards.push(reg.register(&format!("m{k}"), &m).unwrap().shard());
        }
        assert_eq!(shards, vec![0, 1, 2, 0, 1]);
        assert_eq!(reg.keys(), vec!["m0", "m1", "m2", "m3", "m4"]);
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let reg = registry(2);
        let m = gen::chain(60, GenSeed(63));
        reg.register("dup", &m).unwrap();
        let err = reg.register("dup", &m).unwrap_err();
        assert!(format!("{err:#}").contains("already registered"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn remove_frees_the_key_for_reregistration() {
        let reg = registry(2);
        let m = gen::chain(70, GenSeed(65));
        let entry = reg.register("evict", &m).unwrap();
        let removed = reg.remove("evict").unwrap();
        assert!(Arc::ptr_eq(&entry, &removed));
        assert!(reg.get("evict").is_none());
        assert!(reg.is_empty());
        assert!(reg.remove("evict").is_none());
        // The key is free again.
        reg.register("evict", &m).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn evict_returns_the_entry_and_frees_the_key() {
        let reg = registry(2);
        let m = gen::chain(80, GenSeed(66));
        let entry = reg.register("cold", &m).unwrap();
        // No traffic in flight: evict drains instantly.
        let evicted = reg.evict("cold").expect("key was registered");
        assert!(Arc::ptr_eq(&entry, &evicted));
        assert_eq!(evicted.inflight(), 0);
        assert!(reg.get("cold").is_none());
        assert!(reg.evict("cold").is_none(), "second evict finds nothing");
        reg.register("cold", &m).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn checkout_tracks_inflight_and_evict_waits_for_it() {
        let reg = Arc::new(registry(1));
        let m = gen::chain(60, GenSeed(67));
        reg.register("busy", &m).unwrap();
        let entry = reg.checkout("busy").expect("known key");
        assert_eq!(entry.inflight(), 1);
        // Evict on another thread: it must not return while the request
        // is outstanding.
        let reg2 = Arc::clone(&reg);
        let evictor = std::thread::spawn(move || reg2.evict("busy").unwrap());
        // The key is unmapped promptly even while the drain waits.
        let mut spins = 0u64;
        while reg.get("busy").is_some() {
            std::thread::yield_now();
            spins += 1;
            assert!(spins < 50_000_000, "evict never unmapped the key");
        }
        assert!(!evictor.is_finished(), "evict returned with a request in flight");
        entry.note_done();
        let drained = evictor.join().unwrap();
        assert_eq!(drained.inflight(), 0);
        assert!(Arc::ptr_eq(&entry, &drained));
    }

    #[test]
    fn swap_replaces_the_entry_atomically_and_keeps_shard_and_served() {
        let reg = registry(3);
        let m0 = gen::chain(40, GenSeed(68));
        reg.register("pad", &m0).unwrap(); // shifts round-robin off 0
        let ma = gen::banded(120, 4, 0.6, GenSeed(69));
        let old = reg.register("hot", &ma).unwrap();
        old.note_served(7);
        assert_eq!(old.shard(), 1);
        let mb = gen::banded(160, 5, 0.7, GenSeed(70));
        let mut warmed = false;
        let new = reg
            .swap("hot", &mb, |e| {
                assert_eq!(e.solver().n(), mb.n, "warm sees the new plan");
                warmed = true;
                Ok(())
            })
            .unwrap();
        assert!(warmed, "warm hook must run before publish");
        assert!(!Arc::ptr_eq(&old, &new));
        assert_eq!(new.shard(), old.shard(), "swap must not migrate shards");
        assert_eq!(new.served(), 7, "served carries across the swap");
        assert_eq!(new.solver().n(), mb.n);
        // Lookups now resolve the new entry; the old Arc is still usable
        // by whoever holds it (in-flight requests).
        assert!(Arc::ptr_eq(&reg.get("hot").unwrap(), &new));
        assert_eq!(old.solver().n(), ma.n);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn swap_unknown_or_evicted_key_errors() {
        let reg = registry(2);
        let m = gen::chain(50, GenSeed(71));
        let err = reg.swap("ghost", &m, |_| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("not registered"), "{err:#}");
        // A warm failure aborts the swap and leaves the old entry live.
        let old = reg.register("hot", &m).unwrap();
        let err = reg
            .swap("hot", &m, |_| anyhow::bail!("backend prepare failed"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("prepare failed"), "{err:#}");
        assert!(Arc::ptr_eq(&reg.get("hot").unwrap(), &old));
    }

    #[test]
    fn swap_detects_evict_and_reregister_racing_the_build() {
        // The ABA case: while a swap's replacement is being built off the
        // lock, the key is evicted AND re-registered as a fresh lineage.
        // Publishing anyway would wire the key to the retired lineage's
        // counters; the swap must error and leave the fresh registration
        // untouched. The warm hook runs exactly in that window, so the
        // interleaving is deterministic.
        let reg = registry(2);
        let ma = gen::chain(50, GenSeed(72));
        let mb = gen::chain(90, GenSeed(73));
        reg.register("k", &ma).unwrap();
        let err = reg
            .swap("k", &mb, |_| {
                reg.evict("k").expect("evict the old lineage");
                reg.register("k", &ma).expect("fresh re-registration");
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("re-registered"), "{err:#}");
        // The fresh registration survived un-clobbered.
        assert_eq!(reg.get("k").unwrap().solver().n(), ma.n);
    }

    #[test]
    fn default_class_is_bulk_and_survives_plain_swaps() {
        let reg = registry(1);
        let m = gen::chain(40, GenSeed(80));
        let bulk = reg.register("bg", &m).unwrap();
        assert_eq!(bulk.default_class(), RequestClass::Bulk);
        let lat = reg
            .register_with_class("fg", &m, RequestClass::Latency)
            .unwrap();
        assert_eq!(lat.default_class(), RequestClass::Latency);
        // A plain swap keeps the key's class; an explicit one re-classes.
        let m2 = gen::chain(60, GenSeed(81));
        let swapped = reg.swap("fg", &m2, |_| Ok(())).unwrap();
        assert_eq!(swapped.default_class(), RequestClass::Latency);
        let reclassed = reg
            .swap_with_class("fg", &m, Some(RequestClass::Bulk), |_| Ok(()))
            .unwrap();
        assert_eq!(reclassed.default_class(), RequestClass::Bulk);
    }

    #[test]
    fn evict_with_no_straggler_parks_and_wakes_across_threads() {
        // Several requests in flight, finished from another thread one by
        // one: the evictor must park (not spin) and wake exactly when the
        // last reply lands. Timing-independent: the finisher sleeps
        // between note_done calls, so a broken wakeup hangs loudly.
        let reg = Arc::new(registry(1));
        let m = gen::chain(50, GenSeed(82));
        reg.register("drainme", &m).unwrap();
        let e1 = reg.checkout("drainme").unwrap();
        let e2 = reg.checkout("drainme").unwrap();
        assert_eq!(e1.inflight(), 2);
        let finisher = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            e1.note_done();
            std::thread::sleep(std::time::Duration::from_millis(30));
            e2.note_done();
        });
        let drained = reg.evict("drainme").expect("key was registered");
        assert_eq!(drained.inflight(), 0);
        finisher.join().unwrap();
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let reg = registry(0);
        assert_eq!(reg.num_shards(), 1);
        let m = gen::chain(30, GenSeed(64));
        assert_eq!(reg.register("only", &m).unwrap().shard(), 0);
    }

    use crate::runtime::sync::{model, thread};

    /// Model-checked: the real [`DrainGate`] protocol never loses a
    /// wakeup. Across every explored interleaving of two finishing
    /// requests and a concurrent evict, the evictor terminates (a lost
    /// wakeup would park it forever — the explorer's stall detector is
    /// the oracle) and returns only after the drain.
    #[test]
    fn model_drain_gate_has_no_lost_wakeup() {
        let reg = Arc::new(registry(1));
        let m = gen::chain(30, GenSeed(90));
        let entry = reg.register("gate", &m).unwrap();
        reg.evict("gate").unwrap();
        let out = model::explore(model::ModelConfig::fast(), move || {
            let stale = reg
                .inner
                .write()
                .unwrap()
                .insert("gate".to_string(), Arc::clone(&entry));
            if stale.is_some() {
                model::flag("previous schedule left the key mapped");
            }
            let a = reg.checkout("gate").expect("known key");
            let b = reg.checkout("gate").expect("known key");
            let finishers: Vec<_> = [a, b]
                .into_iter()
                .map(|e| thread::spawn(move || e.note_done()))
                .collect();
            let evicted = reg.evict("gate").expect("key was registered");
            if evicted.inflight() != 0 {
                model::flag("evict returned before the drain");
            }
            for h in finishers {
                h.join().unwrap();
            }
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// The seeded protocol mutation the acceptance gate demands: a
    /// replica of [`DrainGate`] whose last decrement notifies *without*
    /// taking the gate lock — reverting the notify-under-lock fix that
    /// [`RegisteredMatrix::note_done`] carries. The checker must find the
    /// schedule where the notify fires inside the evictor's
    /// checked-but-not-yet-waiting window and report the lost wakeup.
    #[test]
    fn model_catches_unlocked_drain_notify_mutation() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let gate = Arc::new((AtomicU64::new(1), Mutex::new(()), Condvar::new()));
            let finisher = {
                let gate = Arc::clone(&gate);
                thread::spawn(move || {
                    // The mutant: decrement, then notify with the gate
                    // lock NOT held.
                    if gate.0.fetch_sub(1, Ordering::Release) == 1 {
                        gate.2.notify_all();
                    }
                })
            };
            let mut guard = gate.1.lock().unwrap();
            while gate.0.load(Ordering::Acquire) > 0 {
                guard = gate.2.wait(guard).unwrap();
            }
            drop(guard);
            finisher.join().unwrap();
        });
        out.assert_fails_with("lost wakeup");
    }
}
