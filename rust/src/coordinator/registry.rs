//! Multi-matrix registry of the sharded serving runtime, with live
//! eviction and hot swap.
//!
//! The paper's accelerator amortizes all per-matrix preprocessing —
//! clustering, ICR reordering, scheduling — across a stream of solves of
//! the same structure. [`MatrixRegistry`] is that amortization boundary
//! for the serving runtime: registering a matrix under a key compiles the
//! accelerator program, runs the cycle-accurate simulation once (the
//! shared cost model and double-entry check), builds the [`LevelSolver`]
//! plan, and assigns the matrix to a shard. Every later request for that
//! key only routes, gathers and executes — no per-request setup of any
//! kind.
//!
//! The registry is also the matrix **lifecycle** boundary:
//!
//! - [`MatrixRegistry::evict`] retires a key: the key becomes unknown
//!   immediately (new submits get the error reply), the call drains the
//!   requests already routed against the entry, and the plan drops with
//!   the last reference. The key is then free for re-registration.
//! - [`MatrixRegistry::swap`] replaces a key's matrix **live**: the new
//!   entry is compiled/simulated/planned entirely off the hot path, then
//!   published under the write lock in one pointer move — a concurrent
//!   request observes either the old fully-formed entry or the new one,
//!   never a torn mix. In-flight requests against the old entry finish
//!   on the plan they resolved (their `Arc` keeps it alive).
//!
//! Shard assignment is **cost-model-driven** by default
//! ([`PlacementPolicy::Cost`]): registration derives a [`MatrixCost`]
//! from the plan and the simulator run, and the key lands on the shard
//! with the least accumulated weight (ties go to the lowest index, so an
//! empty registry fills shards in order). The entry records its shard,
//! so routing stays a single map lookup; a swap keeps the old entry's
//! shard, so a key never migrates between request queues mid-swap.
//! Removal and eviction give the weight back to the shard, and
//! [`MatrixRegistry::rebalance_plan`] / [`MatrixRegistry::migrate`]
//! live-migrate keys off overloaded shards after evict churn — a
//! migration clones the entry with a new shard index but **shares** the
//! lineage counters exactly like a swap, so served/in-flight accounting
//! stays exact across the move. [`PlacementPolicy::RoundRobin`] keeps
//! the old registration-order behavior as an opt-out.

use super::cost::{MatrixCost, PlacementPolicy};
use super::metrics::SolveMetrics;
use crate::compiler::{compile, CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::sync::{Arc, Condvar, Mutex, RwLock};
use crate::runtime::{LevelSolver, RequestClass, SchedulerKind};
use crate::sim::Accelerator;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::sync::OnceLock;

/// Parking spot for [`MatrixRegistry::evict`]: the evictor waits here for
/// the lineage's in-flight count to drain instead of burning a core in a
/// poll loop. Lineage-shared like the counters, so a drain covers
/// requests routed against any entry the key ever resolved to.
#[derive(Default)]
struct DrainGate {
    lock: Mutex<()>,
    drained: Condvar,
}

/// One registered matrix: everything the serve path needs, prepared once.
///
/// The `served` and `inflight` counters are **lineage-shared**: a
/// [`MatrixRegistry::swap`] clones them into the replacement entry, so a
/// key's counters stay exact across swaps — a reply delivered against a
/// pre-swap entry still counts, and an evict drains requests in flight
/// against *any* entry the key ever resolved to. Evict + re-register
/// starts a fresh lineage (counters reset).
pub struct RegisteredMatrix {
    key: String,
    shard: usize,
    solver: Arc<LevelSolver>,
    program: Arc<Program>,
    metrics: SolveMetrics,
    served: Arc<AtomicU64>,
    /// Requests routed against this key whose replies have not been
    /// delivered yet — what [`MatrixRegistry::evict`] drains.
    inflight: Arc<AtomicU64>,
    /// Where the evictor parks while the drain completes (lineage-shared
    /// with `inflight`).
    drain: Arc<DrainGate>,
    /// The class a request for this key runs under when it carries no
    /// class of its own.
    default_class: RequestClass,
    /// Cost profile derived at registration (plan stats + the measured
    /// simulator cycles); drives placement weight and the per-matrix
    /// scheduler recommendation.
    cost: MatrixCost,
    /// The scheduler the serving backend actually resolved for this
    /// matrix, recorded once after the registration-time
    /// [`prepare`](crate::runtime::SolverBackend::prepare) warmup so
    /// `mgd serve` can report the choice.
    scheduler_choice: OnceLock<SchedulerKind>,
}

impl RegisteredMatrix {
    /// The registration key requests route by.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Index of the shard that owns this matrix's requests.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The shared solve plan (level sets + cached medium-granularity
    /// plan), built once at registration.
    pub fn solver(&self) -> &Arc<LevelSolver> {
        &self.solver
    }

    /// The compiled accelerator program (inspection, benches).
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Per-solve accelerator metrics from the one-time simulation,
    /// attached to every response for this matrix.
    pub fn metrics(&self) -> &SolveMetrics {
        &self.metrics
    }

    /// Requests served against this key so far — a per-key lifetime
    /// counter, exact across [`MatrixRegistry::swap`] (the counter is
    /// shared with the replaced entry, so late replies against it still
    /// land here); reset by evict + re-register.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Requests currently routed against this key (any entry in its swap
    /// lineage) and not yet replied.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    /// The cost profile derived at registration: placement weight,
    /// parallelism profile, memory estimate (see [`MatrixCost`]).
    pub fn cost(&self) -> &MatrixCost {
        &self.cost
    }

    /// Debug-build static audit of the plan this entry **actually
    /// serves**: re-runs
    /// [`MgdPlan::verify`](crate::runtime::MgdPlan::verify) *and* the
    /// kernel-IR round trip ([`kir::lower`](crate::runtime::kir::lower)
    /// + [`kir::verify`](crate::runtime::kir::verify())) against the
    /// medium-granularity plan the backend cached during its
    /// registration-time
    /// [`prepare`](crate::runtime::SolverBackend::prepare) warmup. Reads
    /// the cache only ([`LevelSolver::cached_mgd_plan`]) — it never
    /// builds a plan, so it cannot poison the backend-owned
    /// first-config-wins cache — and is a no-op when no plan was cached
    /// (level-only or pool-less backends).
    pub fn audit_served_plan(&self) -> Result<()> {
        let Some(plan) = self.solver.cached_mgd_plan() else {
            return Ok(());
        };
        let key = &self.key;
        plan.verify()
            .with_context(|| format!("static plan audit for matrix {key:?}"))?;
        crate::runtime::kir::verify(&crate::runtime::kir::lower(&plan), &plan)
            .with_context(|| format!("kernel-IR audit for matrix {key:?}"))
    }

    /// The scheduler the serving backend resolved for this matrix, if
    /// the backend reported one (the native backend always does; PJRT
    /// has no scheduler seam). Recorded by the service after the
    /// registration/swap warmup.
    pub fn scheduler_choice(&self) -> Option<SchedulerKind> {
        self.scheduler_choice.get().copied()
    }

    /// Record the backend's resolved scheduler (first write wins — the
    /// choice is a per-entry constant).
    pub(crate) fn note_scheduler(&self, kind: SchedulerKind) {
        let _ = self.scheduler_choice.set(kind);
    }

    /// The scheduling class a request for this key runs under when it
    /// carries no class of its own — set at
    /// [`MatrixRegistry::register_with_class`] /
    /// [`MatrixRegistry::swap_with_class`], `Bulk` otherwise.
    pub fn default_class(&self) -> RequestClass {
        self.default_class
    }

    /// Count `n` served requests (called by shard workers).
    pub(crate) fn note_served(&self, n: u64) {
        self.served.fetch_add(n, Ordering::Relaxed);
    }

    /// One request finished (replied or dropped); pairs with the
    /// increment `MatrixRegistry::checkout` performed at route time.
    /// The request that drains the lineage to zero wakes any evictor
    /// parked on the drain gate; the empty critical section orders the
    /// notification after the evictor's check-then-wait, so the wakeup
    /// cannot be lost.
    pub(crate) fn note_done(&self) {
        if self.inflight.fetch_sub(1, Ordering::Release) == 1 {
            let _guard = self.drain.lock.lock().unwrap();
            self.drain.drained.notify_all();
        }
    }
}

impl std::fmt::Debug for RegisteredMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RegisteredMatrix")
            .field("key", &self.key)
            .field("shard", &self.shard)
            .field("n", &self.solver.n())
            .field("served", &self.served())
            .field("inflight", &self.inflight())
            .finish_non_exhaustive()
    }
}

/// One planned key move from an overloaded shard to an underloaded one,
/// produced by [`MatrixRegistry::rebalance_plan`] and applied by
/// [`MatrixRegistry::migrate`]. Holds the entry observed at plan time so
/// the apply step can detect (and refuse) a stale plan, and so the
/// service can warm the destination backend before publishing.
#[derive(Debug)]
pub struct Migration {
    /// The key being moved.
    pub key: String,
    /// Source shard (the most loaded at plan time).
    pub from: usize,
    /// Destination shard (the least loaded at plan time).
    pub to: usize,
    entry: Arc<RegisteredMatrix>,
}

impl Migration {
    /// The entry as observed at plan time — what the destination
    /// backend should warm ([`SolverBackend::prepare`](crate::runtime::SolverBackend::prepare))
    /// before the move is applied.
    pub fn entry(&self) -> &Arc<RegisteredMatrix> {
        &self.entry
    }
}

/// Key → prepared-matrix map with cost-model shard placement, live
/// eviction, atomic hot swap and load-rebalancing migration.
///
/// Lookups are lock-cheap (`RwLock` read); registration and swap take the
/// write lock only to publish — the compile/simulate work happens outside
/// it. The per-shard load accounting (`loads`) is mutated only under the
/// write lock; the relaxed atomics exist so [`MatrixRegistry::shard_loads`]
/// can read it under the read lock.
pub struct MatrixRegistry {
    shards: usize,
    compiler: CompilerConfig,
    placement: PlacementPolicy,
    /// Accumulated [`MatrixCost::weight`] per shard — the least-loaded
    /// placement input. Incremented at register, adjusted at swap, and
    /// decremented at remove/evict and on a migration's source shard, so
    /// post-churn placement never skews toward shards that only *look*
    /// loaded.
    loads: Vec<AtomicU64>,
    inner: RwLock<HashMap<String, Arc<RegisteredMatrix>>>,
}

impl MatrixRegistry {
    /// An empty registry assigning matrices across `shards` shards
    /// (clamped to ≥ 1) and compiling with `compiler`, placing by cost
    /// ([`PlacementPolicy::Cost`]).
    pub fn new(shards: usize, compiler: CompilerConfig) -> Self {
        Self::with_placement(shards, compiler, PlacementPolicy::Cost)
    }

    /// [`MatrixRegistry::new`] with an explicit [`PlacementPolicy`].
    pub fn with_placement(
        shards: usize,
        compiler: CompilerConfig,
        placement: PlacementPolicy,
    ) -> Self {
        let shards = shards.max(1);
        Self {
            shards,
            compiler,
            placement,
            loads: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            inner: RwLock::new(HashMap::new()),
        }
    }

    /// Shards this registry assigns across.
    pub fn num_shards(&self) -> usize {
        self.shards
    }

    /// The active placement policy.
    pub fn placement(&self) -> PlacementPolicy {
        self.placement
    }

    /// Accumulated placement weight per shard (placement units — the
    /// registered keys' estimated solve cycles).
    pub fn shard_loads(&self) -> Vec<u64> {
        // relaxed: monotonic-per-publish accounting, only mutated under
        // the write lock; this is an observational read.
        self.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    /// Add to a shard's accumulated load (caller holds the write lock —
    /// the load/store pair cannot interleave with another mutation;
    /// saturation guards the accounting against drift).
    fn add_load(&self, shard: usize, weight: u64) {
        let cur = self.loads[shard].load(Ordering::Relaxed);
        self.loads[shard].store(cur.saturating_add(weight), Ordering::Relaxed);
    }

    /// Give a departing key's weight back to its shard (write lock held,
    /// like [`MatrixRegistry::add_load`]).
    fn sub_load(&self, shard: usize, weight: u64) {
        let cur = self.loads[shard].load(Ordering::Relaxed);
        self.loads[shard].store(cur.saturating_sub(weight), Ordering::Relaxed);
    }

    /// Pick the shard for a fresh key, given the map size at publish
    /// time: least-loaded under [`PlacementPolicy::Cost`] (ties to the
    /// lowest index), registration-order round-robin under
    /// [`PlacementPolicy::RoundRobin`].
    fn place(&self, registered: usize) -> usize {
        match self.placement {
            PlacementPolicy::RoundRobin => registered % self.shards,
            PlacementPolicy::Cost => self
                .shard_loads()
                .iter()
                .enumerate()
                .min_by_key(|&(_, &l)| l)
                .map(|(s, _)| s)
                .unwrap_or(0),
        }
    }

    /// Compile, simulate (double-entry verification + shared cost model)
    /// and plan one matrix — the expensive part of registration and swap,
    /// always run with **no registry lock held**. The cheap
    /// [`RegisteredMatrix`] wrapper is assembled by the caller once the
    /// shard and lineage counters are known (at publish time).
    fn prepare_parts(
        &self,
        key: &str,
        m: &CsrMatrix,
    ) -> Result<(Arc<Program>, SolveMetrics, Arc<LevelSolver>, MatrixCost)> {
        let program = Arc::new(
            compile(m, &self.compiler).with_context(|| format!("compile matrix {key:?}"))?,
        );
        let mut acc = Accelerator::new(self.compiler.arch);
        let probe_b = vec![1.0f32; m.n];
        let run = acc
            .run(&program, &probe_b)
            .with_context(|| format!("simulate matrix {key:?}"))?;
        run.stats
            .verify_against(&program.predicted)
            .with_context(|| format!("double-entry check for matrix {key:?}"))?;
        let metrics = SolveMetrics::from_run(&run.stats, &self.compiler.arch, program.flops());
        let solver = Arc::new(LevelSolver::new(m));
        let cost = MatrixCost::from_plan(&solver).with_measured_cycles(metrics.cycles);
        Ok((program, metrics, solver, cost))
    }

    /// Register `m` under `key`: compile, simulate once, build the solve
    /// plan, and assign a shard. Errors if the key is already registered
    /// — a key is an identity, not a slot to overwrite (use
    /// [`MatrixRegistry::swap`] to replace a live key). Requests for the
    /// key default to the `Bulk` class; use
    /// [`MatrixRegistry::register_with_class`] for latency-critical keys.
    pub fn register(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        self.register_with_class(key, m, RequestClass::Bulk)
    }

    /// [`MatrixRegistry::register`] with an explicit per-key default
    /// [`RequestClass`]: requests that carry no class of their own are
    /// admitted, queued and executed under `class`.
    pub fn register_with_class(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: RequestClass,
    ) -> Result<Arc<RegisteredMatrix>> {
        if self.inner.read().unwrap().contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        let (program, metrics, solver, cost) = self.prepare_parts(key, m)?;
        let mut map = self.inner.write().unwrap();
        // Re-check under the write lock: a concurrent register of the
        // same key must not be silently clobbered.
        if map.contains_key(key) {
            bail!("matrix key {key:?} is already registered");
        }
        // Shard assignment (least-loaded by cost weight, or round-robin)
        // and the fresh lineage counters are decided here, under the
        // write lock — the single derivation point.
        let shard = self.place(map.len());
        let weight = cost.weight();
        let entry = Arc::new(RegisteredMatrix {
            key: key.to_string(),
            shard,
            solver,
            program,
            metrics,
            served: Arc::new(AtomicU64::new(0)),
            inflight: Arc::new(AtomicU64::new(0)),
            drain: Arc::new(DrainGate::default()),
            default_class: class,
            cost,
            scheduler_choice: OnceLock::new(),
        });
        map.insert(key.to_string(), Arc::clone(&entry));
        self.add_load(shard, weight);
        Ok(entry)
    }

    /// Replace the matrix registered under `key` **live**. The new entry
    /// is built (compile + simulate + plan) with no lock held, `warm` is
    /// invoked on it (the service points this at
    /// [`SolverBackend::prepare`](crate::runtime::SolverBackend::prepare)
    /// so the owning shard's backend caches the new plan before any
    /// request can reach it), and only then is the map entry swapped in
    /// one atomic pointer move — no request ever observes a torn entry.
    ///
    /// The new entry keeps the old entry's shard (routing stays stable)
    /// and **shares** its lineage counters: `served` keeps counting
    /// exactly (late replies against the old entry still land on the
    /// key), and a later evict drains requests in flight against either
    /// entry. Requests in flight against the old entry complete on the
    /// plan they resolved. Errors if `key` is not registered, or if it
    /// was evicted — or evicted and re-registered as a fresh lineage —
    /// while the replacement was being built; if two swaps of the same
    /// key (and so the same lineage) race, the later publish wins.
    pub fn swap<F>(&self, key: &str, m: &CsrMatrix, warm: F) -> Result<Arc<RegisteredMatrix>>
    where
        F: FnOnce(&Arc<RegisteredMatrix>) -> Result<()>,
    {
        self.swap_with_class(key, m, None, warm)
    }

    /// [`MatrixRegistry::swap`] that also sets the key's default
    /// [`RequestClass`]: `Some(class)` re-classes the key, `None` keeps
    /// the class of the entry being replaced.
    pub fn swap_with_class<F>(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: Option<RequestClass>,
        warm: F,
    ) -> Result<Arc<RegisteredMatrix>>
    where
        F: FnOnce(&Arc<RegisteredMatrix>) -> Result<()>,
    {
        let Some(old) = self.get(key) else {
            bail!("swap: matrix key {key:?} is not registered");
        };
        let (program, metrics, solver, cost) = self.prepare_parts(key, m)?;
        let entry = Arc::new(RegisteredMatrix {
            key: key.to_string(),
            shard: old.shard(),
            solver,
            program,
            metrics,
            served: Arc::clone(&old.served),
            inflight: Arc::clone(&old.inflight),
            drain: Arc::clone(&old.drain),
            default_class: class.unwrap_or(old.default_class),
            cost,
            scheduler_choice: OnceLock::new(),
        });
        warm(&entry)?;
        // Debug builds re-audit the plan the replacement will actually
        // serve — the medium-granularity invariants plus the kernel-IR
        // lowering round trip — against whatever the warm step cached. A
        // failed audit aborts before publish; the old entry keeps serving.
        #[cfg(debug_assertions)]
        entry.audit_served_plan()?;
        let mut map = self.inner.write().unwrap();
        // Publish only into the lineage the replacement was built from
        // (same shared counters). `contains_key` would be an ABA hole: an
        // evict + re-register racing with the off-lock build would let
        // this swap clobber the fresh registration with an entry wired to
        // the retired lineage's counters — miscounting served requests
        // and letting a later evict return before draining. A racing swap
        // of the same lineage still wins normally.
        let replaced = match map.get(key) {
            Some(current) if Arc::ptr_eq(&current.inflight, &entry.inflight) => Arc::clone(current),
            _ => bail!(
                "swap: matrix key {key:?} was evicted (or evicted and re-registered) \
                 while the replacement was being built"
            ),
        };
        map.insert(key.to_string(), Arc::clone(&entry));
        // The new matrix may weigh differently: re-base the shard's load
        // on the replacement's cost (same shard, so one adjustment).
        self.sub_load(replaced.shard, replaced.cost.weight());
        self.add_load(entry.shard, entry.cost.weight());
        Ok(entry)
    }

    /// Look up a registered matrix by key (inspection only — does **not**
    /// mark a request in flight; the serve path uses the crate-internal
    /// `checkout`, which does).
    pub fn get(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        self.inner.read().unwrap().get(key).cloned()
    }

    /// Resolve `key` for one request and mark it in flight — the
    /// increment happens under the read lock, so an
    /// [`evict`](MatrixRegistry::evict) (which holds the write lock to
    /// unmap the key) either sees the request in its drain or the request
    /// sees the key already gone; there is no window where both miss each
    /// other. Callers must pair this with
    /// `RegisteredMatrix::note_done` once the reply is delivered (the
    /// service does so via a drop guard, so even dropped jobs check in).
    pub(crate) fn checkout(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        let map = self.inner.read().unwrap();
        let entry = map.get(key).cloned()?;
        entry.inflight.fetch_add(1, Ordering::AcqRel);
        Some(entry)
    }

    /// Remove a registered matrix immediately, returning its entry
    /// (registration rollback; [`MatrixRegistry::evict`] is the draining
    /// form). Requests already routed hold their own `Arc` and complete
    /// normally; later submits for the key get the unknown-key error
    /// reply, and the key may be registered again. The departing key's
    /// weight is given back to its shard, so post-churn placement keeps
    /// seeing the real load — not a ghost of evicted keys.
    pub fn remove(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        let mut map = self.inner.write().unwrap();
        let entry = map.remove(key)?;
        self.sub_load(entry.shard, entry.cost.weight());
        Some(entry)
    }

    /// Evict `key`: unmap it (new submits immediately get the
    /// unknown-key error reply), then **block until every request already
    /// routed against the entry has been replied to**, and return the
    /// drained entry — dropping it releases the plan. `None` if the key
    /// was not registered.
    ///
    /// The wait parks on the lineage's drain gate (a `Condvar` signaled
    /// by the request that drains `inflight` to zero) instead of
    /// polling: an evictor blocked behind a slow solve costs nothing
    /// until the wakeup. Because the key is unmapped first, `inflight`
    /// is monotonically non-increasing here — once zero, it stays zero.
    pub fn evict(&self, key: &str) -> Option<Arc<RegisteredMatrix>> {
        let entry = self.remove(key)?;
        let mut guard = entry.drain.lock.lock().unwrap();
        // The check runs under the gate's lock and `note_done` notifies
        // under the same lock, so the last decrement either happens
        // before this check (we never wait) or its notification happens
        // after we wait — a lost-wakeup window does not exist.
        while entry.inflight() > 0 {
            guard = entry.drain.drained.wait(guard).unwrap();
        }
        drop(guard);
        Some(entry)
    }

    /// Plan a set of key migrations that evens out the per-shard load —
    /// the repair step after evict churn concentrates weight. Greedy:
    /// repeatedly move, from the most-loaded to the least-loaded shard,
    /// the key whose weight lands closest to half the gap, until no move
    /// shrinks the spread. Read-only — nothing migrates until each
    /// [`Migration`] is applied with [`MatrixRegistry::migrate`] (the
    /// two-phase split lets the service warm the destination backend
    /// between planning and publishing).
    pub fn rebalance_plan(&self) -> Vec<Migration> {
        let map = self.inner.read().unwrap();
        let mut loads = self.shard_loads();
        let mut weights: Vec<(String, usize, u64, Arc<RegisteredMatrix>)> = map
            .iter()
            .map(|(k, e)| (k.clone(), e.shard, e.cost.weight(), Arc::clone(e)))
            .collect();
        weights.sort_by(|a, b| a.0.cmp(&b.0)); // deterministic plan order
        let mut moves = Vec::new();
        for _ in 0..map.len() {
            let (max_s, &max_l) = match loads.iter().enumerate().max_by_key(|&(_, &l)| l) {
                Some(m) => m,
                None => break,
            };
            let (min_s, &min_l) = match loads.iter().enumerate().min_by_key(|&(_, &l)| l) {
                Some(m) => m,
                None => break,
            };
            let gap = max_l - min_l;
            if gap == 0 {
                break;
            }
            // A move only helps when the key's whole weight fits inside
            // the gap; the best candidate halves it.
            let candidate = weights
                .iter()
                .filter(|(_, shard, w, _)| *shard == max_s && *w > 0 && *w < gap)
                .min_by_key(|(_, _, w, _)| (gap / 2).abs_diff(*w));
            let Some((key, _, w, entry)) = candidate else {
                break;
            };
            loads[max_s] -= *w;
            loads[min_s] += *w;
            moves.push(Migration {
                key: key.clone(),
                from: max_s,
                to: min_s,
                entry: Arc::clone(entry),
            });
            let moved_key = key.clone();
            if let Some(slot) = weights.iter_mut().find(|(k, ..)| *k == moved_key) {
                slot.1 = min_s;
            }
        }
        moves
    }

    /// Apply one planned [`Migration`]: republish the key under its new
    /// shard. The republished entry **shares** the lineage counters
    /// (served / in-flight / drain gate) with the entry it replaces —
    /// exactly like [`MatrixRegistry::swap`] — so per-key accounting
    /// stays exact across the move; requests already queued on the old
    /// shard finish there on the `Arc` they hold, while new submits route
    /// to the new shard. Errors if the key was evicted or re-registered
    /// (a fresh lineage) since the plan was made — a stale plan must not
    /// clobber live state.
    pub fn migrate(&self, mv: &Migration) -> Result<Arc<RegisteredMatrix>> {
        ensure!(
            mv.to < self.shards,
            "migrate: destination shard {} out of range ({} shards)",
            mv.to,
            self.shards
        );
        let mut map = self.inner.write().unwrap();
        let current = match map.get(&mv.key) {
            Some(cur) if Arc::ptr_eq(&cur.inflight, &mv.entry.inflight) => Arc::clone(cur),
            _ => bail!(
                "migrate: matrix key {:?} was evicted or re-registered since the rebalance plan",
                mv.key
            ),
        };
        let moved = Arc::new(RegisteredMatrix {
            key: current.key.clone(),
            shard: mv.to,
            solver: Arc::clone(&current.solver),
            program: Arc::clone(&current.program),
            metrics: current.metrics.clone(),
            served: Arc::clone(&current.served),
            inflight: Arc::clone(&current.inflight),
            drain: Arc::clone(&current.drain),
            default_class: current.default_class,
            cost: current.cost.clone(),
            scheduler_choice: OnceLock::new(),
        });
        if let Some(k) = current.scheduler_choice.get() {
            let _ = moved.scheduler_choice.set(*k);
        }
        map.insert(mv.key.clone(), Arc::clone(&moved));
        self.sub_load(current.shard, current.cost.weight());
        self.add_load(mv.to, moved.cost.weight());
        Ok(moved)
    }

    /// Plan and apply a rebalance in one call (no destination warmup
    /// between the phases — the sharded service's `rebalance` wraps the
    /// two-phase form to warm backends first). Keys that were evicted or
    /// re-registered between plan and apply are skipped, not errors.
    pub fn rebalance(&self) -> Result<Vec<Migration>> {
        let moves = self.rebalance_plan();
        let mut applied = Vec::new();
        for mv in moves {
            if self.migrate(&mv).is_ok() {
                applied.push(mv);
            }
        }
        Ok(applied)
    }

    /// Registered matrix count.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    /// True when nothing is registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered keys, sorted (stable output for tables and logs).
    pub fn keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.inner.read().unwrap().keys().cloned().collect();
        keys.sort();
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    fn registry(shards: usize) -> MatrixRegistry {
        MatrixRegistry::new(shards, CompilerConfig::default())
    }

    #[test]
    fn registers_and_looks_up() {
        let reg = registry(2);
        assert!(reg.is_empty());
        let m = gen::banded(150, 4, 0.6, GenSeed(61));
        let entry = reg.register("band", &m).unwrap();
        assert_eq!(entry.key(), "band");
        assert_eq!(entry.metrics().cycles, entry.program().predicted.cycles);
        assert_eq!(entry.solver().n(), m.n);
        assert_eq!(entry.inflight(), 0);
        assert_eq!(reg.len(), 1);
        let again = reg.get("band").unwrap();
        assert!(Arc::ptr_eq(&entry, &again));
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn shard_assignment_rotates_for_growing_keys() {
        // Monotonically growing matrices: least-loaded placement fills
        // shards in rotation (each new key lands where the least weight
        // has accumulated) — the same footprint round-robin used to give.
        let reg = registry(3);
        let mut shards = Vec::new();
        for k in 0..5 {
            let m = gen::chain(40 + k, GenSeed(62 + k as u64));
            shards.push(reg.register(&format!("m{k}"), &m).unwrap().shard());
        }
        assert_eq!(shards, vec![0, 1, 2, 0, 1]);
        assert_eq!(reg.keys(), vec!["m0", "m1", "m2", "m3", "m4"]);
    }

    #[test]
    fn cost_placement_is_least_loaded() {
        // One heavy key then several light ones: round-robin would bounce
        // the light keys across both shards; least-loaded parks them all
        // opposite the heavy key until the loads cross.
        let reg = registry(2);
        let heavy = gen::banded(400, 8, 0.8, GenSeed(91));
        let light = gen::chain(40, GenSeed(92));
        assert_eq!(reg.register("heavy", &heavy).unwrap().shard(), 0);
        let heavy_w = reg.get("heavy").unwrap().cost().weight();
        let light_w = reg.register("l0", &light).unwrap().cost().weight();
        assert!(
            heavy_w > 3 * light_w,
            "premise: heavy must outweigh light ({heavy_w} vs {light_w})"
        );
        for k in 1..3 {
            let e = reg.register(&format!("l{k}"), &light).unwrap();
            assert_eq!(e.shard(), 1, "light keys stack on the light shard");
        }
        let loads = reg.shard_loads();
        assert_eq!(loads[0], heavy_w);
        assert_eq!(loads[1], 3 * light_w);
        assert!(loads[0] > loads[1]);
    }

    #[test]
    fn round_robin_placement_opt_out() {
        let reg = MatrixRegistry::with_placement(
            2,
            CompilerConfig::default(),
            PlacementPolicy::RoundRobin,
        );
        assert_eq!(reg.placement(), PlacementPolicy::RoundRobin);
        let heavy = gen::banded(300, 6, 0.8, GenSeed(93));
        let light = gen::chain(40, GenSeed(94));
        // Registration order alone decides — the heavy key's weight is
        // ignored and the third key returns to the heavy shard.
        assert_eq!(reg.register("heavy", &heavy).unwrap().shard(), 0);
        assert_eq!(reg.register("l0", &light).unwrap().shard(), 1);
        assert_eq!(reg.register("l1", &light).unwrap().shard(), 0);
    }

    #[test]
    fn evict_decrements_the_shards_load() {
        // The post-churn skew bug: without the decrement, an evicted
        // key's weight would haunt its shard and push every later
        // registration onto the other one.
        let reg = registry(2);
        let heavy = gen::banded(400, 8, 0.8, GenSeed(95));
        let light = gen::chain(40, GenSeed(96));
        reg.register("heavy", &heavy).unwrap();
        reg.register("l0", &light).unwrap();
        assert!(reg.shard_loads()[0] > 0);
        reg.evict("heavy").unwrap();
        assert_eq!(reg.shard_loads()[0], 0, "evict must give the weight back");
        // Shard 0 is now the least loaded again.
        assert_eq!(reg.register("l1", &light).unwrap().shard(), 0);
    }

    #[test]
    fn rebalance_migrates_and_keeps_lineage_exact() {
        let reg = registry(2);
        for (k, n) in [40usize, 41, 42, 43].iter().enumerate() {
            let m = gen::chain(*n, GenSeed(100 + k as u64));
            reg.register(&format!("c{k}"), &m).unwrap();
        }
        // Rotation placed [0, 1, 0, 1]; evicting shard 0's keys leaves it
        // empty while shard 1 still carries two.
        reg.evict("c0").unwrap();
        reg.evict("c2").unwrap();
        assert_eq!(reg.shard_loads()[0], 0);
        // Live traffic state that must survive the move exactly.
        reg.get("c1").unwrap().note_served(5);
        let checked_out = reg.checkout("c1").unwrap();
        assert_eq!(checked_out.inflight(), 1);

        let moved = reg.rebalance().unwrap();
        assert_eq!(moved.len(), 1, "one move evens two keys across two shards");
        assert_eq!(moved[0].from, 1);
        assert_eq!(moved[0].to, 0);
        let migrated = reg.get(&moved[0].key).unwrap();
        assert_eq!(migrated.shard(), 0);
        if moved[0].key == "c1" {
            assert_eq!(migrated.served(), 5, "served is lineage-shared across the move");
            assert_eq!(migrated.inflight(), 1, "in-flight is lineage-shared too");
        }
        let loads = reg.shard_loads();
        assert!(loads[0] > 0 && loads[1] > 0, "both shards carry load: {loads:?}");
        // The pre-move Arc still settles the shared lineage counters.
        checked_out.note_done();
        assert_eq!(reg.get("c1").unwrap().inflight(), 0);
        // Balanced now: another plan finds nothing to move.
        assert!(reg.rebalance_plan().is_empty());
    }

    #[test]
    fn migrate_refuses_a_stale_plan() {
        // Stack three light keys opposite one heavy key, then evict the
        // heavy one: the plan moves a light key into the emptied shard.
        let reg = registry(2);
        let heavy = gen::banded(400, 8, 0.8, GenSeed(110));
        let light = gen::chain(40, GenSeed(111));
        reg.register("heavy", &heavy).unwrap();
        for k in 0..3 {
            reg.register(&format!("l{k}"), &light).unwrap();
        }
        reg.evict("heavy").unwrap();
        let plan = reg.rebalance_plan();
        assert_eq!(plan.len(), 1, "one light key evens 3-vs-0");
        assert_eq!((plan[0].from, plan[0].to), (1, 0));
        // The key leaves (or is re-registered) between plan and apply:
        // the stale move must refuse to publish.
        reg.evict(&plan[0].key).unwrap();
        let err = reg.migrate(&plan[0]).unwrap_err();
        assert!(
            format!("{err:#}").contains("since the rebalance plan"),
            "{err:#}"
        );
        // And the skipping convenience wrapper tolerates it.
        assert!(reg.rebalance().is_ok());
    }

    #[test]
    fn duplicate_keys_are_rejected() {
        let reg = registry(2);
        let m = gen::chain(60, GenSeed(63));
        reg.register("dup", &m).unwrap();
        let err = reg.register("dup", &m).unwrap_err();
        assert!(format!("{err:#}").contains("already registered"));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn remove_frees_the_key_for_reregistration() {
        let reg = registry(2);
        let m = gen::chain(70, GenSeed(65));
        let entry = reg.register("evict", &m).unwrap();
        let removed = reg.remove("evict").unwrap();
        assert!(Arc::ptr_eq(&entry, &removed));
        assert!(reg.get("evict").is_none());
        assert!(reg.is_empty());
        assert!(reg.remove("evict").is_none());
        // The key is free again.
        reg.register("evict", &m).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn evict_returns_the_entry_and_frees_the_key() {
        let reg = registry(2);
        let m = gen::chain(80, GenSeed(66));
        let entry = reg.register("cold", &m).unwrap();
        // No traffic in flight: evict drains instantly.
        let evicted = reg.evict("cold").expect("key was registered");
        assert!(Arc::ptr_eq(&entry, &evicted));
        assert_eq!(evicted.inflight(), 0);
        assert!(reg.get("cold").is_none());
        assert!(reg.evict("cold").is_none(), "second evict finds nothing");
        reg.register("cold", &m).unwrap();
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn checkout_tracks_inflight_and_evict_waits_for_it() {
        let reg = Arc::new(registry(1));
        let m = gen::chain(60, GenSeed(67));
        reg.register("busy", &m).unwrap();
        let entry = reg.checkout("busy").expect("known key");
        assert_eq!(entry.inflight(), 1);
        // Evict on another thread: it must not return while the request
        // is outstanding.
        let reg2 = Arc::clone(&reg);
        let evictor = std::thread::spawn(move || reg2.evict("busy").unwrap());
        // The key is unmapped promptly even while the drain waits.
        let mut spins = 0u64;
        while reg.get("busy").is_some() {
            std::thread::yield_now();
            spins += 1;
            assert!(spins < 50_000_000, "evict never unmapped the key");
        }
        assert!(!evictor.is_finished(), "evict returned with a request in flight");
        entry.note_done();
        let drained = evictor.join().unwrap();
        assert_eq!(drained.inflight(), 0);
        assert!(Arc::ptr_eq(&entry, &drained));
    }

    #[test]
    fn swap_replaces_the_entry_atomically_and_keeps_shard_and_served() {
        let reg = registry(3);
        let m0 = gen::chain(40, GenSeed(68));
        reg.register("pad", &m0).unwrap(); // shifts round-robin off 0
        let ma = gen::banded(120, 4, 0.6, GenSeed(69));
        let old = reg.register("hot", &ma).unwrap();
        old.note_served(7);
        assert_eq!(old.shard(), 1);
        let mb = gen::banded(160, 5, 0.7, GenSeed(70));
        let mut warmed = false;
        let new = reg
            .swap("hot", &mb, |e| {
                assert_eq!(e.solver().n(), mb.n, "warm sees the new plan");
                warmed = true;
                Ok(())
            })
            .unwrap();
        assert!(warmed, "warm hook must run before publish");
        assert!(!Arc::ptr_eq(&old, &new));
        assert_eq!(new.shard(), old.shard(), "swap must not migrate shards");
        assert_eq!(new.served(), 7, "served carries across the swap");
        assert_eq!(new.solver().n(), mb.n);
        // Lookups now resolve the new entry; the old Arc is still usable
        // by whoever holds it (in-flight requests).
        assert!(Arc::ptr_eq(&reg.get("hot").unwrap(), &new));
        assert_eq!(old.solver().n(), ma.n);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn swap_unknown_or_evicted_key_errors() {
        let reg = registry(2);
        let m = gen::chain(50, GenSeed(71));
        let err = reg.swap("ghost", &m, |_| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("not registered"), "{err:#}");
        // A warm failure aborts the swap and leaves the old entry live.
        let old = reg.register("hot", &m).unwrap();
        let err = reg
            .swap("hot", &m, |_| anyhow::bail!("backend prepare failed"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("prepare failed"), "{err:#}");
        assert!(Arc::ptr_eq(&reg.get("hot").unwrap(), &old));
    }

    #[test]
    fn swap_detects_evict_and_reregister_racing_the_build() {
        // The ABA case: while a swap's replacement is being built off the
        // lock, the key is evicted AND re-registered as a fresh lineage.
        // Publishing anyway would wire the key to the retired lineage's
        // counters; the swap must error and leave the fresh registration
        // untouched. The warm hook runs exactly in that window, so the
        // interleaving is deterministic.
        let reg = registry(2);
        let ma = gen::chain(50, GenSeed(72));
        let mb = gen::chain(90, GenSeed(73));
        reg.register("k", &ma).unwrap();
        let err = reg
            .swap("k", &mb, |_| {
                reg.evict("k").expect("evict the old lineage");
                reg.register("k", &ma).expect("fresh re-registration");
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("re-registered"), "{err:#}");
        // The fresh registration survived un-clobbered.
        assert_eq!(reg.get("k").unwrap().solver().n(), ma.n);
    }

    #[test]
    fn default_class_is_bulk_and_survives_plain_swaps() {
        let reg = registry(1);
        let m = gen::chain(40, GenSeed(80));
        let bulk = reg.register("bg", &m).unwrap();
        assert_eq!(bulk.default_class(), RequestClass::Bulk);
        let lat = reg
            .register_with_class("fg", &m, RequestClass::Latency)
            .unwrap();
        assert_eq!(lat.default_class(), RequestClass::Latency);
        // A plain swap keeps the key's class; an explicit one re-classes.
        let m2 = gen::chain(60, GenSeed(81));
        let swapped = reg.swap("fg", &m2, |_| Ok(())).unwrap();
        assert_eq!(swapped.default_class(), RequestClass::Latency);
        let reclassed = reg
            .swap_with_class("fg", &m, Some(RequestClass::Bulk), |_| Ok(()))
            .unwrap();
        assert_eq!(reclassed.default_class(), RequestClass::Bulk);
    }

    #[test]
    fn evict_with_no_straggler_parks_and_wakes_across_threads() {
        // Several requests in flight, finished from another thread one by
        // one: the evictor must park (not spin) and wake exactly when the
        // last reply lands. Timing-independent: the finisher sleeps
        // between note_done calls, so a broken wakeup hangs loudly.
        let reg = Arc::new(registry(1));
        let m = gen::chain(50, GenSeed(82));
        reg.register("drainme", &m).unwrap();
        let e1 = reg.checkout("drainme").unwrap();
        let e2 = reg.checkout("drainme").unwrap();
        assert_eq!(e1.inflight(), 2);
        let finisher = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            e1.note_done();
            std::thread::sleep(std::time::Duration::from_millis(30));
            e2.note_done();
        });
        let drained = reg.evict("drainme").expect("key was registered");
        assert_eq!(drained.inflight(), 0);
        finisher.join().unwrap();
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let reg = registry(0);
        assert_eq!(reg.num_shards(), 1);
        let m = gen::chain(30, GenSeed(64));
        assert_eq!(reg.register("only", &m).unwrap().shard(), 0);
    }

    use crate::runtime::sync::{model, thread};

    /// Model-checked: the real [`DrainGate`] protocol never loses a
    /// wakeup. Across every explored interleaving of two finishing
    /// requests and a concurrent evict, the evictor terminates (a lost
    /// wakeup would park it forever — the explorer's stall detector is
    /// the oracle) and returns only after the drain.
    #[test]
    fn model_drain_gate_has_no_lost_wakeup() {
        let reg = Arc::new(registry(1));
        let m = gen::chain(30, GenSeed(90));
        let entry = reg.register("gate", &m).unwrap();
        reg.evict("gate").unwrap();
        let out = model::explore(model::ModelConfig::fast(), move || {
            let stale = reg
                .inner
                .write()
                .unwrap()
                .insert("gate".to_string(), Arc::clone(&entry));
            if stale.is_some() {
                model::flag("previous schedule left the key mapped");
            }
            let a = reg.checkout("gate").expect("known key");
            let b = reg.checkout("gate").expect("known key");
            let finishers: Vec<_> = [a, b]
                .into_iter()
                .map(|e| thread::spawn(move || e.note_done()))
                .collect();
            let evicted = reg.evict("gate").expect("key was registered");
            if evicted.inflight() != 0 {
                model::flag("evict returned before the drain");
            }
            for h in finishers {
                h.join().unwrap();
            }
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// The seeded protocol mutation the acceptance gate demands: a
    /// replica of [`DrainGate`] whose last decrement notifies *without*
    /// taking the gate lock — reverting the notify-under-lock fix that
    /// [`RegisteredMatrix::note_done`] carries. The checker must find the
    /// schedule where the notify fires inside the evictor's
    /// checked-but-not-yet-waiting window and report the lost wakeup.
    #[test]
    fn model_catches_unlocked_drain_notify_mutation() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let gate = Arc::new((AtomicU64::new(1), Mutex::new(()), Condvar::new()));
            let finisher = {
                let gate = Arc::clone(&gate);
                thread::spawn(move || {
                    // The mutant: decrement, then notify with the gate
                    // lock NOT held.
                    if gate.0.fetch_sub(1, Ordering::Release) == 1 {
                        gate.2.notify_all();
                    }
                })
            };
            let mut guard = gate.1.lock().unwrap();
            while gate.0.load(Ordering::Acquire) > 0 {
                guard = gate.2.wait(guard).unwrap();
            }
            drop(guard);
            finisher.join().unwrap();
        });
        out.assert_fails_with("lost wakeup");
    }
}
