//! Streaming solve sessions: admission paid once, RHS pipelined.
//!
//! A [`SolveSession`] is the serving stack's answer to the
//! transient-simulation pattern (`examples/circuit_transient.rs`): one
//! factor, thousands of time-step solves. Instead of paying a full
//! submit/wait round trip per RHS, a client opens a session against a
//! registered key — resolving the key and pinning the scheduling class
//! once — and then pipelines RHS after RHS with a bounded number of
//! solves in flight ([`SolveSession::depth`]). Keeping the next requests
//! queued while the current one solves lets the shard worker batch
//! same-matrix neighbors through the backend's multi-RHS path and
//! overlap solve N's reply/epilogue with N+1's gather, while the bound
//! keeps a runaway producer from turning the session into an unbounded
//! queue (the Xie et al. failure mode, PAPERS.md).
//!
//! Replies stream back through the waker-based completion layer
//! ([`super::completion`]) in strict submission order.
//!
//! # Epochs: sessions compose with `swap`/`evict`
//!
//! Sessions hold no lock on the registry — a key can be hot-swapped or
//! evicted mid-stream. The session observes a swap as an **epoch
//! boundary**: before each submit it compares the key's current registry
//! entry against the lineage it opened on ([`Arc::ptr_eq`] — `swap`
//! always publishes a fresh entry), and on a mismatch it drains every
//! in-flight reply (all solved against the old lineage), bumps
//! [`SolveSession::epoch`], and resumes on the new lineage. Replies
//! therefore never mix lineages inside one pipeline window: each one is
//! bitwise-reproducible against `solve_serial` on whichever matrix its
//! epoch pinned. An *evicted* key ends the stream instead: the next
//! submit errors, but already-earned replies stay collectable.

use super::registry::RegisteredMatrix;
use super::service::{
    Admission, ShardedSolveService, SolveHandle, SolveResponse, SolveService, SINGLE_KEY,
};
use crate::runtime::sync::Arc;
use crate::runtime::RequestClass;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;

/// A streaming solve session against one registered matrix key; see the
/// [module docs](self) for the pipelining and epoch model. Created by
/// [`ShardedSolveService::open_session`]; borrows the service, so drop
/// the session before shutting the service down.
pub struct SolveSession<'svc> {
    svc: &'svc ShardedSolveService,
    key: String,
    /// Effective class, pinned at open (explicit or the key's default).
    class: RequestClass,
    depth: usize,
    epoch: u64,
    /// The registry entry the current epoch solves against.
    lineage: Arc<RegisteredMatrix>,
    /// In-flight handles, oldest first (replies harvest in this order).
    inflight: VecDeque<SolveHandle>,
    /// Harvested replies not yet handed to the caller, oldest first.
    ready: VecDeque<Result<SolveResponse>>,
    submitted: u64,
}

impl ShardedSolveService {
    /// Opens a streaming session against `key` under the key's default
    /// scheduling class, with at most `depth` solves in flight (clamped
    /// to ≥ 1). Admission state — key resolution, class, lease affinity
    /// — is pinned here, once, instead of per request.
    pub fn open_session(&self, key: &str, depth: usize) -> Result<SolveSession<'_>> {
        self.open_session_class(key, None, depth)
    }

    /// [`ShardedSolveService::open_session`] with an explicit class
    /// override (`None` = the key's default).
    pub fn open_session_class(
        &self,
        key: &str,
        class: Option<RequestClass>,
        depth: usize,
    ) -> Result<SolveSession<'_>> {
        let Some(lineage) = self.registry().get(key) else {
            bail!(
                "cannot open session: unknown matrix key {key:?} (registered: [{}])",
                self.registry().keys().join(", ")
            );
        };
        let class = class.unwrap_or_else(|| lineage.default_class());
        Ok(SolveSession {
            svc: self,
            key: key.to_string(),
            class,
            depth: depth.max(1),
            epoch: 0,
            lineage,
            inflight: VecDeque::new(),
            ready: VecDeque::new(),
            submitted: 0,
        })
    }
}

impl SolveService {
    /// Opens a streaming session against the facade's single matrix;
    /// see [`ShardedSolveService::open_session`].
    pub fn open_session(&self, depth: usize) -> Result<SolveSession<'_>> {
        self.inner.open_session(SINGLE_KEY, depth)
    }
}

impl SolveSession<'_> {
    /// Pipelines one more RHS into the session. Blocks only when the
    /// in-session depth bound is reached (harvesting the oldest reply
    /// first) or when the shard's admission policy parks the submitter;
    /// a shed and an evicted key are errors. Replies come back through
    /// [`SolveSession::next_reply`]/[`SolveSession::try_next`] in
    /// submission order.
    pub fn submit(&mut self, b: Vec<f32>) -> Result<()> {
        self.observe_epoch()?;
        while self.inflight.len() >= self.depth {
            self.harvest_oldest();
        }
        match self.svc.try_route(&self.key, b, Some(self.class))? {
            Admission::Admitted(handle) => {
                self.inflight.push_back(handle);
                self.submitted += 1;
                Ok(())
            }
            Admission::Shed(reason) => Err(anyhow!(
                "session submit for {:?} shed: {reason}",
                self.key
            )),
        }
    }

    /// Epoch maintenance at the submit boundary: a swapped key drains
    /// the pipeline (old-lineage replies stay collectable, in order)
    /// and re-pins; an evicted key is an error.
    fn observe_epoch(&mut self) -> Result<()> {
        let Some(current) = self.svc.registry().get(&self.key) else {
            bail!(
                "session key {:?} was evicted while streaming \
                 (epoch {}, {} replies still collectable)",
                self.key,
                self.epoch,
                self.inflight.len() + self.ready.len()
            );
        };
        if !Arc::ptr_eq(&current, &self.lineage) {
            // Epoch boundary: everything in flight was solved against
            // the old lineage — drain it before the first new-lineage
            // submit so no pipeline window mixes matrices.
            while !self.inflight.is_empty() {
                self.harvest_oldest();
            }
            self.lineage = current;
            self.epoch += 1;
        }
        Ok(())
    }

    /// Blocks on the oldest in-flight handle and buffers its reply.
    fn harvest_oldest(&mut self) {
        if let Some(handle) = self.inflight.pop_front() {
            self.ready.push_back(handle.wait());
        }
    }

    /// Next reply in submission order: buffered if available, otherwise
    /// blocks on the oldest in-flight solve. `None` means the session
    /// has nothing outstanding (every submit was answered and
    /// collected).
    pub fn next_reply(&mut self) -> Option<Result<SolveResponse>> {
        if self.ready.is_empty() {
            self.harvest_oldest();
        }
        self.ready.pop_front()
    }

    /// Non-blocking [`SolveSession::next_reply`]: also returns `None`
    /// when the oldest in-flight solve has not finished yet.
    pub fn try_next(&mut self) -> Option<Result<SolveResponse>> {
        if self.ready.is_empty() {
            if let Some(front) = self.inflight.front() {
                let reply = front.try_wait()?;
                self.inflight.pop_front();
                self.ready.push_back(reply);
            }
        }
        self.ready.pop_front()
    }

    /// Drains the session: blocks for every outstanding reply and
    /// returns them (buffered first, then in-flight), in submission
    /// order.
    pub fn drain(&mut self) -> Vec<Result<SolveResponse>> {
        while !self.inflight.is_empty() {
            self.harvest_oldest();
        }
        self.ready.drain(..).collect()
    }

    /// The registered key this session streams against.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// The in-session pipeline depth bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Epoch counter: 0 at open, +1 per observed swap of the key.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Solves currently in flight plus harvested replies not yet
    /// collected.
    pub fn outstanding(&self) -> usize {
        self.inflight.len() + self.ready.len()
    }

    /// Total RHS submitted over the session's lifetime.
    pub fn submitted(&self) -> u64 {
        self.submitted
    }
}

impl std::fmt::Debug for SolveSession<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolveSession")
            .field("key", &self.key)
            .field("depth", &self.depth)
            .field("epoch", &self.epoch)
            .field("inflight", &self.inflight.len())
            .field("ready", &self.ready.len())
            .field("submitted", &self.submitted)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ServiceConfig;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::solve_serial;

    #[test]
    fn session_streams_in_order_and_matches_serial_bitwise() {
        let m = gen::circuit(300, 4, 0.8, GenSeed(9));
        let svc = SolveService::start(&m, ServiceConfig::default()).unwrap();
        let mut session = svc.open_session(3).unwrap();
        let bs: Vec<Vec<f32>> = (0..10)
            .map(|t| (0..m.n).map(|i| ((i + 3 * t) % 5) as f32 - 2.0).collect())
            .collect();
        for b in &bs {
            session.submit(b.clone()).unwrap();
        }
        assert_eq!(session.outstanding(), bs.len(), "nothing collected yet");
        let replies = session.drain();
        assert_eq!(replies.len() as u64, session.submitted());
        assert_eq!(replies.len(), bs.len());
        for (reply, b) in replies.into_iter().zip(&bs) {
            let x = reply.unwrap().x;
            let want = solve_serial(&m, b);
            for i in 0..m.n {
                assert_eq!(x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        assert_eq!(session.epoch(), 0);
        drop(session);
        svc.shutdown();
    }

    #[test]
    fn open_session_unknown_key_errors() {
        let m = gen::chain(20, GenSeed(3));
        let svc = SolveService::start(&m, ServiceConfig::default()).unwrap();
        let err = svc.inner.open_session("nope", 2).unwrap_err();
        assert!(format!("{err:#}").contains("unknown matrix key"), "{err:#}");
        svc.shutdown();
    }
}
