//! L3 coordinator: the multi-RHS solve service.
//!
//! In the paper's motivating applications (transient circuit simulation,
//! preconditioned iterative solvers) the same triangular factor is solved
//! against a *stream* of right-hand sides. The service compiles the matrix
//! once (accelerator program + PJRT level plan), then serves RHS requests
//! from worker threads with batched dispatch:
//!
//! - numerics run on the PJRT executables ([`crate::runtime`]),
//! - per-request accelerator metrics (cycles, energy) come from the
//!   cycle-accurate simulator, run once per matrix — the schedule is
//!   RHS-independent, so the cost model is shared across requests.

pub mod metrics;
pub mod service;

pub use metrics::SolveMetrics;
pub use service::{ServiceConfig, SolveRequest, SolveResponse, SolveService};
