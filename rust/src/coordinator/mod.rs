//! L3 coordinator: the multi-RHS solve service.
//!
//! In the paper's motivating applications (transient circuit simulation,
//! preconditioned iterative solvers) the same triangular factor is solved
//! against a *stream* of right-hand sides. The service compiles the matrix
//! once (accelerator program + shared level plan), then serves RHS requests
//! from worker threads with batched dispatch:
//!
//! - numerics run on the configured [`crate::runtime::SolverBackend`] —
//!   the native parallel level executor by default, the PJRT kernels when
//!   the `pjrt` feature is enabled and its artifacts load;
//! - per-request accelerator metrics (cycles, energy) come from the
//!   cycle-accurate simulator, run once per matrix — the schedule is
//!   RHS-independent, so the cost model is shared across requests.
//!
//! Failures are loud: backend construction errors fail
//! [`SolveService::start`], and per-request solver errors are replied to
//! the requester instead of being dropped.

pub mod metrics;
pub mod service;

pub use metrics::SolveMetrics;
pub use service::{ServiceConfig, SolveRequest, SolveResponse, SolveService};
