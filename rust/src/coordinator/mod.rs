//! L3 coordinator: the sharded multi-matrix solve service.
//!
//! In the paper's motivating applications (transient circuit simulation,
//! preconditioned iterative solvers) the same triangular factor is solved
//! against a *stream* of right-hand sides — and a serving deployment
//! hosts many such factors at once. The coordinator amortizes all
//! per-matrix work at **registration** and keeps the request path
//! setup-free:
//!
//! - [`MatrixRegistry`] compiles each registered matrix once (accelerator
//!   program + cycle-accurate simulation for the shared cost model +
//!   [`crate::runtime::LevelSolver`] plan with its cached MGD plan),
//!   condenses the results into a [`MatrixCost`] profile, and places the
//!   key on the **least-loaded shard** by accumulated cost weight
//!   ([`PlacementPolicy`]; `rebalance` live-migrates keys after evict
//!   churn with lineage-exact counters);
//! - [`ShardedSolveService`] routes each [`SolveRequest`] by `matrix_key`
//!   to the owning shard, whose workers batch same-matrix requests
//!   through the configured [`crate::runtime::SolverBackend`] — shared
//!   across shards by default, so the native backend's **persistent MGD
//!   worker pool** is spawned once and reused across every solve and
//!   matrix, with independent solves overlapping as concurrent pool
//!   sessions;
//! - admission is **bounded, class-aware and aging-fair**: each shard
//!   holds two queue lanes ([`crate::runtime::RequestClass::Latency`]
//!   drained before `Bulk`, except that a bulk job older than the
//!   configured aging bound is promoted — a latency flood cannot starve
//!   bulk indefinitely) capped by `queue_cap`, an [`AdmissionPolicy`]
//!   decides whether a full lane blocks or sheds
//!   ([`ShardedSolveService::try_route`] → [`Admission`]), and
//!   [`SolveHandle::wait_timeout`] gives callers deadlines; the class
//!   rides down to the pool's reserved latency-lane workers, so bulk
//!   floods neither wedge the queues nor lease the pool dry;
//! - matrices are **dynamic**: [`ShardedSolveService::evict`] retires a
//!   key after draining its in-flight requests, and
//!   [`ShardedSolveService::swap`] replaces a key's matrix live with an
//!   atomically published, pre-warmed entry;
//! - completion is **waker-based**, not thread-per-waiter: replies land
//!   in one-shot completion cells ([`completion`]) that fire whatever
//!   readiness the caller registered — blocking waits, `poll`/`on_ready`
//!   callbacks, or a zero-dependency `Future` adapter — so a parked OS
//!   thread per in-flight request is no longer the price of waiting;
//! - streaming clients open a [`SolveSession`]
//!   ([`ShardedSolveService::open_session`]): key resolution and request
//!   class pinned once at open, RHS pipelined with bounded in-session
//!   depth, and a live `swap` observed as a documented epoch boundary;
//! - per-shard [`ShardCounters`] roll up into service-wide
//!   [`ServingStats`] (which also surfaces pool-session concurrency);
//!   per-request accelerator metrics ([`SolveMetrics`]) come from the
//!   one-time simulation.
//!
//! [`SolveService`] is the single-matrix facade over the same machinery
//! (one shard, one registered matrix) used by `mgd solve` and the
//! benches.
//!
//! Failures are loud: backend construction errors fail
//! [`ShardedSolveService::start`], registration errors fail
//! [`ShardedSolveService::register`], unknown keys get an immediate error
//! reply, and per-request solver errors are replied to the requester
//! instead of being dropped.

pub mod completion;
pub mod cost;
pub mod metrics;
pub mod registry;
pub mod service;
pub mod session;

pub use cost::{MatrixCost, PlacementPolicy};
pub use metrics::{ServingStats, ShardCounters, ShardStats, SolveMetrics};
pub use registry::{MatrixRegistry, Migration, RegisteredMatrix};
pub use service::{
    Admission, AdmissionPolicy, ServiceConfig, ShardedServiceConfig, ShardedSolveService,
    SolveFuture, SolveHandle, SolveRequest, SolveResponse, SolveService,
};
pub use session::SolveSession;
