//! One-shot completion cells: the waker/poll layer under [`SolveHandle`].
//!
//! [`channel`] returns a ([`Completer`], [`Completion`]) pair around a
//! single-value cell built on the [`crate::runtime::sync`] facade — a
//! `Notify`-style primitive, so every protocol here is model-checkable
//! by `sync::model` under plain `cargo test`. The consumer side offers
//! the full ladder of completion styles without an async-runtime
//! dependency:
//!
//! - [`Completion::wait`] / [`Completion::wait_timeout`] — blocking,
//!   the PR 5 handle contract;
//! - [`Completion::poll`] / [`Completion::try_take`] — readiness
//!   polling with a registered [`Waker`] callback;
//! - [`Completion::on_ready`] — fire-and-forget `FnOnce` registration;
//! - [`Completion::into_future`] — a zero-dep [`std::future::Future`]
//!   adapter ([`CompletionFuture`]) for callers that do own a runtime.
//!
//! The no-lost-wakeup discipline is the same one the registry's drain
//! gate uses: the value is published and the condvar notified *while
//! holding the cell lock*, and a registered waker callback is taken out
//! under the lock but invoked only after it is released (the callback
//! may re-enter handle APIs). Double-completion is idempotent — the
//! first [`Completer::send`] wins, later sends report `false` — and
//! dropping every completer without sending wakes waiters with
//! [`PollState::Gone`] so nobody parks forever on an abandoned cell.
//!
//! [`SolveHandle`]: super::service::SolveHandle

use crate::runtime::sync::{Arc, Condvar, Mutex};
use std::fmt;
use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll};
use std::time::{Duration, Instant};

/// A cheap, cloneable wake callback registered via [`Completion::poll`].
///
/// Deliberately minimal (a shared `Fn() + Send + Sync`): it is the
/// crate's runtime-free stand-in for `std::task::Waker`, and the
/// [`CompletionFuture`] adapter bridges one to the real thing.
pub struct Waker(Arc<dyn Fn() + Send + Sync>);

impl Waker {
    /// Wraps a callback. The callback must be safe to invoke from the
    /// completing thread, with no cell lock held.
    pub fn new<F: Fn() + Send + Sync + 'static>(f: F) -> Waker {
        Waker(Arc::new(f))
    }

    /// Invokes the callback.
    pub fn wake(&self) {
        (self.0)()
    }
}

impl Clone for Waker {
    fn clone(&self) -> Waker {
        Waker(Arc::clone(&self.0))
    }
}

impl fmt::Debug for Waker {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Waker").finish_non_exhaustive()
    }
}

/// Result of a non-blocking look at a completion cell.
#[derive(Debug, PartialEq, Eq)]
pub enum PollState<T> {
    /// No value yet — the solve (or other producer) is still in flight.
    Pending,
    /// The value, moved out of the cell. A cell completes exactly once,
    /// so every later look reports [`PollState::Gone`].
    Ready(T),
    /// No value will ever arrive: either every [`Completer`] was dropped
    /// without sending, or the value was already taken.
    Gone,
}

struct State<T> {
    value: Option<T>,
    taken: bool,
    senders: usize,
    waker: Option<Box<dyn FnOnce() + Send>>,
}

struct Cell<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
}

/// Decide what a consumer sees, moving the value out on first contact.
fn take_locked<T>(st: &mut State<T>) -> PollState<T> {
    if let Some(v) = st.value.take() {
        st.taken = true;
        PollState::Ready(v)
    } else if st.taken || st.senders == 0 {
        PollState::Gone
    } else {
        PollState::Pending
    }
}

/// Producer side of a completion cell; clone freely. The first
/// [`Completer::send`] across all clones wins.
pub struct Completer<T> {
    cell: Arc<Cell<T>>,
}

impl<T> Completer<T> {
    /// Publishes the value and fires readiness: condvar waiters are
    /// notified under the cell lock (no lost wakeup), a registered
    /// waker/`on_ready` callback runs after the lock is released.
    ///
    /// Returns `false` (and drops `value`) if the cell already
    /// completed — double-fire is idempotent by construction.
    pub fn send(&self, value: T) -> bool {
        let waker = {
            let mut st = self.cell.state.lock().expect("completion cell poisoned");
            if st.value.is_some() || st.taken {
                return false;
            }
            st.value = Some(value);
            self.cell.ready.notify_all();
            st.waker.take()
        };
        if let Some(w) = waker {
            w();
        }
        true
    }
}

impl<T> Clone for Completer<T> {
    fn clone(&self) -> Completer<T> {
        {
            let mut st = self.cell.state.lock().expect("completion cell poisoned");
            st.senders += 1;
        }
        Completer {
            cell: Arc::clone(&self.cell),
        }
    }
}

impl<T> Drop for Completer<T> {
    fn drop(&mut self) {
        let waker = {
            let Ok(mut st) = self.cell.state.lock() else {
                return;
            };
            st.senders -= 1;
            if st.senders > 0 || st.value.is_some() || st.taken {
                None
            } else {
                // Last producer gone with nothing sent: wake everyone so
                // they observe `Gone` instead of parking forever.
                self.cell.ready.notify_all();
                st.waker.take()
            }
        };
        if let Some(w) = waker {
            w();
        }
    }
}

impl<T> fmt::Debug for Completer<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Completer").finish_non_exhaustive()
    }
}

/// Consumer side of a completion cell (single consumer, not `Clone`).
pub struct Completion<T> {
    cell: Arc<Cell<T>>,
}

impl<T> Completion<T> {
    /// Non-blocking: takes the value if it is there.
    pub fn try_take(&self) -> PollState<T> {
        let mut st = self.cell.state.lock().expect("completion cell poisoned");
        take_locked(&mut st)
    }

    /// Non-blocking look that arms `waker` on [`PollState::Pending`]:
    /// the waker fires exactly once, when the cell completes (or when
    /// the last producer is dropped). Re-polling replaces any earlier
    /// registration — only the most recent waker fires.
    pub fn poll(&self, waker: &Waker) -> PollState<T> {
        let mut st = self.cell.state.lock().expect("completion cell poisoned");
        match take_locked(&mut st) {
            PollState::Pending => {
                let w = waker.clone();
                st.waker = Some(Box::new(move || w.wake()));
                PollState::Pending
            }
            out => out,
        }
    }

    /// Registers a one-shot readiness callback. If the cell already
    /// completed (or is abandoned), `f` runs immediately on this thread;
    /// otherwise it runs on the completing thread, after the cell lock
    /// is released. Replaces any waker armed by an earlier
    /// [`Completion::poll`] or `on_ready` call.
    pub fn on_ready<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut f = Some(f);
        {
            let mut st = self.cell.state.lock().expect("completion cell poisoned");
            if st.value.is_none() && !st.taken && st.senders > 0 {
                let g = f.take().expect("callback registered twice");
                st.waker = Some(Box::new(g));
            }
        }
        if let Some(g) = f {
            g();
        }
    }

    /// Blocks until the cell completes. `None` means no value will ever
    /// arrive (every producer dropped, or the value was already taken).
    pub fn wait(self) -> Option<T> {
        let mut st = self.cell.state.lock().expect("completion cell poisoned");
        loop {
            match take_locked(&mut st) {
                PollState::Ready(v) => return Some(v),
                PollState::Gone => return None,
                PollState::Pending => {
                    st = self.cell.ready.wait(st).expect("completion cell poisoned");
                }
            }
        }
    }

    /// Blocks up to `timeout`. [`PollState::Pending`] means the deadline
    /// elapsed with the producer still in flight — the cell is untouched
    /// and the call can be re-issued (the PR 5 re-wait contract).
    pub fn wait_timeout(&self, timeout: Duration) -> PollState<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.cell.state.lock().expect("completion cell poisoned");
        loop {
            match take_locked(&mut st) {
                PollState::Pending => {}
                out => return out,
            }
            let now = Instant::now();
            if now >= deadline {
                return PollState::Pending;
            }
            let (g, _timed) = self
                .cell
                .ready
                .wait_timeout(st, deadline - now)
                .expect("completion cell poisoned");
            st = g;
        }
    }

    /// Adapts the cell to a [`std::future::Future`] resolving to
    /// `Option<T>` (`None` = abandoned), for callers that bring their
    /// own executor. No runtime dependency: the adapter just bridges
    /// `std::task::Waker` to the cell's own [`Waker`].
    pub fn into_future(self) -> CompletionFuture<T> {
        CompletionFuture { inner: self }
    }
}

impl<T> fmt::Debug for Completion<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Completion").finish_non_exhaustive()
    }
}

/// Creates a completion cell, returning the producer and consumer ends.
pub fn channel<T>() -> (Completer<T>, Completion<T>) {
    let cell = Arc::new(Cell {
        state: Mutex::new(State {
            value: None,
            taken: false,
            senders: 1,
            waker: None,
        }),
        ready: Condvar::new(),
    });
    (
        Completer {
            cell: Arc::clone(&cell),
        },
        Completion { cell },
    )
}

/// [`Future`] adapter over a [`Completion`] (see
/// [`Completion::into_future`]). Resolves to `Some(value)` on
/// completion, `None` if every producer dropped without sending.
pub struct CompletionFuture<T> {
    inner: Completion<T>,
}

impl<T> Future for CompletionFuture<T> {
    type Output = Option<T>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        let w = cx.waker().clone();
        match self.inner.poll(&Waker::new(move || w.wake_by_ref())) {
            PollState::Ready(v) => Poll::Ready(Some(v)),
            PollState::Gone => Poll::Ready(None),
            PollState::Pending => Poll::Pending,
        }
    }
}

impl<T> fmt::Debug for CompletionFuture<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("CompletionFuture").finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use crate::runtime::sync::{model, thread};

    #[test]
    fn send_then_wait_returns_value() {
        let (tx, rx) = channel();
        assert!(tx.send(41));
        assert_eq!(rx.wait(), Some(41));
    }

    #[test]
    fn double_send_is_idempotent_and_first_wins() {
        let (tx, rx) = channel();
        let tx2 = tx.clone();
        assert!(tx.send(1));
        assert!(!tx2.send(2), "second completion must report false");
        assert_eq!(rx.wait(), Some(1));
    }

    #[test]
    fn drop_without_send_is_gone() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert_eq!(rx.try_take(), PollState::Gone);
        assert_eq!(rx.wait(), None);
    }

    #[test]
    fn value_taken_once_then_gone() {
        let (tx, rx) = channel();
        assert!(tx.send(9));
        assert_eq!(rx.try_take(), PollState::Ready(9));
        assert_eq!(rx.try_take(), PollState::Gone);
        assert_eq!(rx.wait_timeout(Duration::from_millis(1)), PollState::Gone);
    }

    #[test]
    fn on_ready_after_completion_fires_immediately() {
        let (tx, rx) = channel();
        assert!(tx.send(3));
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&fired);
        rx.on_ready(move || f2.store(true, Ordering::SeqCst));
        assert!(fired.load(Ordering::SeqCst), "callback must run inline");
        assert_eq!(rx.try_take(), PollState::Ready(3));
    }

    #[test]
    fn on_ready_fires_when_last_completer_drops() {
        let (tx, rx) = channel::<u32>();
        let fired = Arc::new(AtomicBool::new(false));
        let f2 = Arc::clone(&fired);
        rx.on_ready(move || f2.store(true, Ordering::SeqCst));
        assert!(!fired.load(Ordering::SeqCst));
        drop(tx);
        assert!(fired.load(Ordering::SeqCst), "abandonment must wake");
        assert_eq!(rx.try_take(), PollState::Gone);
    }

    #[test]
    fn wait_timeout_pending_then_ready_rearms() {
        let (tx, rx) = channel();
        // Deadline elapses with the producer still live: Pending, and
        // the cell stays waitable.
        assert_eq!(rx.wait_timeout(Duration::from_millis(5)), PollState::Pending);
        assert!(tx.send(12));
        assert_eq!(rx.wait_timeout(Duration::from_secs(30)), PollState::Ready(12));
        assert_eq!(rx.wait_timeout(Duration::from_millis(1)), PollState::Gone);
    }

    #[test]
    fn poll_registers_waker_and_send_fires_it() {
        let (tx, rx) = channel();
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&fired);
        let w = Waker::new(move || {
            f2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(rx.poll(&w), PollState::Pending);
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        assert!(tx.send(5));
        assert_eq!(fired.load(Ordering::SeqCst), 1, "send fires the waker once");
        assert_eq!(rx.poll(&w), PollState::Ready(5));
        assert_eq!(fired.load(Ordering::SeqCst), 1, "take does not re-fire");
    }

    /// Hand-rolled executor: park the test thread until the future's
    /// `std::task::Waker` unparks it. Proves the adapter needs no
    /// runtime crate.
    fn block_on<F: Future + Unpin>(mut fut: F) -> F::Output {
        struct Unpark(std::thread::Thread);
        impl std::task::Wake for Unpark {
            fn wake(self: Arc<Self>) {
                self.0.unpark();
            }
        }
        let waker = std::task::Waker::from(Arc::new(Unpark(std::thread::current())));
        let mut cx = Context::from_waker(&waker);
        loop {
            match Pin::new(&mut fut).poll(&mut cx) {
                Poll::Ready(v) => return v,
                Poll::Pending => std::thread::park(),
            }
        }
    }

    #[test]
    fn future_adapter_wakes_and_resolves() {
        let (tx, rx) = channel();
        let sender = thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            assert!(tx.send(77));
        });
        assert_eq!(block_on(rx.into_future()), Some(77));
        sender.join().unwrap();
    }

    #[test]
    fn future_adapter_resolves_none_on_abandonment() {
        let (tx, rx) = channel::<u32>();
        let dropper = thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            drop(tx);
        });
        assert_eq!(block_on(rx.into_future()), None);
        dropper.join().unwrap();
    }

    /// Model-checked: the register-vs-fire race loses no wakeup. A
    /// consumer that registers `on_ready` concurrently with the
    /// producer's `send` always gets its callback, and by the time the
    /// callback runs the value is observable via `try_take` — in every
    /// explored interleaving.
    #[test]
    fn model_register_vs_fire_race_loses_no_wakeup() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let (tx, rx) = channel::<u32>();
            let producer = thread::spawn(move || {
                assert!(tx.send(7));
            });
            // The callback records readiness under its own (mutex,
            // condvar) pair; the root thread parks on that pair, so a
            // lost callback is a stall the explorer flags.
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = Arc::clone(&pair);
            rx.on_ready(move || {
                let (m, c) = &*p2;
                let mut fired = m.lock().expect("pair poisoned");
                *fired = true;
                c.notify_all();
            });
            {
                let (m, c) = &*pair;
                let mut fired = m.lock().expect("pair poisoned");
                while !*fired {
                    fired = c.wait(fired).expect("pair poisoned");
                }
            }
            match rx.try_take() {
                PollState::Ready(7) => {}
                _ => model::flag("waker fired before the value was observable"),
            }
            producer.join().expect("producer panicked");
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// Model-checked: double-fire is idempotent. Two racing completer
    /// clones — exactly one `send` wins in every interleaving, and the
    /// consumer always observes the winner's value.
    #[test]
    fn model_double_fire_is_idempotent() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let (tx, rx) = channel::<u32>();
            let tx2 = tx.clone();
            let wins = Arc::new(AtomicUsize::new(0));
            let (w1, w2) = (Arc::clone(&wins), Arc::clone(&wins));
            let t1 = thread::spawn(move || {
                if tx.send(1) {
                    w1.fetch_add(1, Ordering::SeqCst);
                }
            });
            let t2 = thread::spawn(move || {
                if tx2.send(2) {
                    w2.fetch_add(1, Ordering::SeqCst);
                }
            });
            match rx.wait() {
                Some(1) | Some(2) => {}
                _ => model::flag("consumer saw neither racer's value"),
            }
            t1.join().expect("racer 1 panicked");
            t2.join().expect("racer 2 panicked");
            if wins.load(Ordering::SeqCst) != 1 {
                model::flag("exactly one send must claim the cell");
            }
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// Mutation test: replay the naive waker protocol the cell exists to
    /// rule out — value published to an atomic, readiness notified
    /// *outside* the mutex — and prove the explorer still discriminates
    /// by catching the lost wakeup. Guards the checker itself (the PR 6
    /// pattern): if this mutation ever passes, the model tests above
    /// prove nothing.
    #[test]
    fn model_unlocked_notify_waker_mutation_is_caught() {
        let out = model::explore(model::ModelConfig::fast(), || {
            let value = Arc::new(AtomicUsize::new(0));
            let pair = Arc::new((Mutex::new(()), Condvar::new()));
            let (v2, p2) = (Arc::clone(&value), Arc::clone(&pair));
            let producer = thread::spawn(move || {
                v2.store(7, Ordering::SeqCst);
                // BUG under test: notify with the pair mutex NOT held —
                // it can slip into the waiter's check-then-register
                // window and be lost.
                p2.1.notify_all();
            });
            {
                let (m, c) = &*pair;
                let mut g = m.lock().expect("pair poisoned");
                while value.load(Ordering::SeqCst) == 0 {
                    g = c.wait(g).expect("pair poisoned");
                }
            }
            producer.join().expect("producer panicked");
        });
        out.assert_fails_with("lost wakeup");
    }
}
