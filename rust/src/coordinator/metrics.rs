//! Per-matrix accelerator metrics attached to every solve response.

use crate::arch::ArchConfig;
use crate::sim::{EnergyModel, RunStats};

/// Metrics derived from one cycle-accurate simulation of the compiled
/// program (shared across all RHS requests for the same matrix).
#[derive(Debug, Clone)]
pub struct SolveMetrics {
    /// Accelerator cycles per solve.
    pub cycles: u64,
    /// Modeled accelerator latency per solve (seconds, at 150 MHz).
    pub accel_seconds: f64,
    /// Throughput in GOPS (binary ops / accel time).
    pub gops: f64,
    /// PE utilization.
    pub utilization: f64,
    /// Modeled average power (W).
    pub power_w: f64,
    /// Energy per solve (J).
    pub energy_j: f64,
    /// Energy efficiency (GOPS/W).
    pub gops_per_w: f64,
}

impl SolveMetrics {
    /// Derive the shared metrics from a simulated run.
    pub fn from_run(stats: &RunStats, arch: &ArchConfig, flops: u64) -> Self {
        let seconds = stats.cycles as f64 * arch.clock_period();
        let gops = flops as f64 / seconds / 1e9;
        let energy = EnergyModel::paper_28nm().estimate(stats, arch);
        Self {
            cycles: stats.cycles,
            accel_seconds: seconds,
            gops,
            utilization: stats.utilization(arch.num_cus()),
            power_w: energy.avg_power_w,
            energy_j: energy.energy_j,
            gops_per_w: energy.gops_per_watt(gops),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derives_consistent_metrics() {
        let stats = RunStats {
            cycles: 1500,
            exec: 64_000,
            macs: 60_000,
            finals: 4_000,
            ..RunStats::default()
        };
        let arch = ArchConfig::default();
        let m = SolveMetrics::from_run(&stats, &arch, 100_000);
        assert_eq!(m.cycles, 1500);
        assert!((m.accel_seconds - 1500.0 / 150e6).abs() < 1e-15);
        assert!(m.gops > 0.0 && m.power_w > 0.0);
        assert!((m.gops_per_w - m.gops / m.power_w).abs() < 1e-9);
    }
}
