//! Metrics of the coordinator layer: per-matrix accelerator metrics
//! attached to every solve response, plus the live per-shard serving
//! counters of the sharded service and their aggregate view.

use crate::arch::ArchConfig;
use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::RequestClass;
use crate::sim::{EnergyModel, RunStats};
use std::time::Duration;

/// Metrics derived from one cycle-accurate simulation of the compiled
/// program (shared across all RHS requests for the same matrix).
#[derive(Debug, Clone)]
pub struct SolveMetrics {
    /// Accelerator cycles per solve.
    pub cycles: u64,
    /// Modeled accelerator latency per solve (seconds, at 150 MHz).
    pub accel_seconds: f64,
    /// Throughput in GOPS (binary ops / accel time).
    pub gops: f64,
    /// PE utilization.
    pub utilization: f64,
    /// Modeled average power (W).
    pub power_w: f64,
    /// Energy per solve (J).
    pub energy_j: f64,
    /// Energy efficiency (GOPS/W).
    pub gops_per_w: f64,
}

impl SolveMetrics {
    /// Derive the shared metrics from a simulated run.
    pub fn from_run(stats: &RunStats, arch: &ArchConfig, flops: u64) -> Self {
        let seconds = stats.cycles as f64 * arch.clock_period();
        let gops = flops as f64 / seconds / 1e9;
        let energy = EnergyModel::paper_28nm().estimate(stats, arch);
        Self {
            cycles: stats.cycles,
            accel_seconds: seconds,
            gops,
            utilization: stats.utilization(arch.num_cus()),
            power_w: energy.avg_power_w,
            energy_j: energy.energy_j,
            gops_per_w: energy.gops_per_watt(gops),
        }
    }
}

/// Live counters of one shard, shared (behind an `Arc`) between the
/// shard's worker threads and the service handle. All fields are atomics
/// updated with `Relaxed` ordering: they are monotonic telemetry, never a
/// synchronization edge.
#[derive(Debug, Default)]
pub struct ShardCounters {
    served: AtomicU64,
    errors: AtomicU64,
    batched_rounds: AtomicU64,
    solve_nanos: AtomicU64,
    admitted_latency: AtomicU64,
    admitted_bulk: AtomicU64,
    shed_latency: AtomicU64,
    shed_bulk: AtomicU64,
    peak_queue_depth: AtomicU64,
}

impl ShardCounters {
    /// Record one dispatch round: `served` successful replies, `errors`
    /// error replies, and the wall-clock time the round spent in the
    /// numeric backend.
    pub fn record_round(&self, served: u64, errors: u64, solve_time: Duration) {
        self.served.fetch_add(served, Ordering::Relaxed);
        self.errors.fetch_add(errors, Ordering::Relaxed);
        self.batched_rounds.fetch_add(1, Ordering::Relaxed);
        self.solve_nanos
            .fetch_add(solve_time.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record one admitted request of `class`, with the depth its lane
    /// reached after the enqueue (feeds the queue-depth high-water mark,
    /// which the admission cap bounds by construction).
    pub fn note_admitted(&self, class: RequestClass, depth: u64) {
        match class {
            RequestClass::Latency => self.admitted_latency.fetch_add(1, Ordering::Relaxed),
            RequestClass::Bulk => self.admitted_bulk.fetch_add(1, Ordering::Relaxed),
        };
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// Record one request of `class` shed at admission (the caller got
    /// the queue-cap error reply instead of a queue slot).
    pub fn note_shed(&self, class: RequestClass) {
        match class {
            RequestClass::Latency => self.shed_latency.fetch_add(1, Ordering::Relaxed),
            RequestClass::Bulk => self.shed_bulk.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Successful replies so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Point-in-time snapshot, tagged with the shard's index.
    pub fn snapshot(&self, shard: usize) -> ShardStats {
        ShardStats {
            shard,
            served: self.served.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            batched_rounds: self.batched_rounds.load(Ordering::Relaxed),
            solve_seconds: self.solve_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            admitted_latency: self.admitted_latency.load(Ordering::Relaxed),
            admitted_bulk: self.admitted_bulk.load(Ordering::Relaxed),
            shed_latency: self.shed_latency.load(Ordering::Relaxed),
            shed_bulk: self.shed_bulk.load(Ordering::Relaxed),
            peak_queue_depth: self.peak_queue_depth.load(Ordering::Relaxed),
            aged_bulk: 0,
        }
    }
}

/// Point-in-time serving statistics of one shard.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardStats {
    /// Shard index within the service.
    pub shard: usize,
    /// Requests answered successfully.
    pub served: u64,
    /// Requests answered with an error reply.
    pub errors: u64,
    /// Backend dispatches executed: a multi-request same-matrix group
    /// riding the backend's multi-RHS path counts once; scalar solves
    /// count one each.
    pub batched_rounds: u64,
    /// Cumulative wall-clock seconds the shard spent in the numeric
    /// backend.
    pub solve_seconds: f64,
    /// Latency-class requests admitted to this shard's queue.
    pub admitted_latency: u64,
    /// Bulk-class requests admitted to this shard's queue.
    pub admitted_bulk: u64,
    /// Latency-class requests shed at admission (queue-cap error reply).
    pub shed_latency: u64,
    /// Bulk-class requests shed at admission (queue-cap error reply).
    pub shed_bulk: u64,
    /// High-water mark of this shard's queue-lane depth; bounded by the
    /// service's `queue_cap` whenever one is set.
    pub peak_queue_depth: u64,
    /// Bulk jobs promoted ahead of waiting latency work by the bulk
    /// lane's aging bound (`bulk_aging_ms`). Filled in by the service
    /// from its shard queues; [`ShardCounters::snapshot`] reports zero.
    pub aged_bulk: u64,
}

/// Aggregate serving statistics across every shard of a service.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingStats {
    /// Number of shards aggregated.
    pub shards: usize,
    /// Total successful replies.
    pub served: u64,
    /// Total error replies.
    pub errors: u64,
    /// Total dispatch rounds.
    pub batched_rounds: u64,
    /// Total backend wall-clock seconds, summed over shards (shards solve
    /// concurrently, so this can exceed elapsed wall time).
    pub solve_seconds: f64,
    /// Worker-pool sessions in flight right now, summed over the
    /// service's distinct backends (zero for pool-less backends). Filled
    /// in by `ShardedSolveService::stats`; [`ServingStats::aggregate`]
    /// initializes it to zero.
    pub concurrent_sessions: u64,
    /// High-water mark of simultaneously in-flight pool sessions (max
    /// over the service's distinct backends) — `>= 2` proves two solves
    /// overlapped in one pool instead of queueing. Filled in by
    /// `ShardedSolveService::stats`.
    pub peak_concurrency: u64,
    /// Total latency-class requests admitted across shards.
    pub admitted_latency: u64,
    /// Total bulk-class requests admitted across shards.
    pub admitted_bulk: u64,
    /// Total latency-class requests shed at admission.
    pub shed_latency: u64,
    /// Total bulk-class requests shed at admission.
    pub shed_bulk: u64,
    /// Deepest queue lane observed on any shard (≤ the configured
    /// `queue_cap` whenever one is set).
    pub peak_queue_depth: u64,
    /// Total bulk jobs promoted past waiting latency work by the aging
    /// bound, summed over shards.
    pub aged_bulk: u64,
}

impl ServingStats {
    /// Sum per-shard snapshots into the service-wide view. Pool
    /// concurrency is a backend-level (not shard-level) quantity, so the
    /// concurrency fields start at zero here; the service fills them in
    /// from its backends' pool stats.
    pub fn aggregate(per_shard: &[ShardStats]) -> Self {
        Self {
            shards: per_shard.len(),
            served: per_shard.iter().map(|s| s.served).sum(),
            errors: per_shard.iter().map(|s| s.errors).sum(),
            batched_rounds: per_shard.iter().map(|s| s.batched_rounds).sum(),
            solve_seconds: per_shard.iter().map(|s| s.solve_seconds).sum(),
            concurrent_sessions: 0,
            peak_concurrency: 0,
            admitted_latency: per_shard.iter().map(|s| s.admitted_latency).sum(),
            admitted_bulk: per_shard.iter().map(|s| s.admitted_bulk).sum(),
            shed_latency: per_shard.iter().map(|s| s.shed_latency).sum(),
            shed_bulk: per_shard.iter().map(|s| s.shed_bulk).sum(),
            peak_queue_depth: per_shard.iter().map(|s| s.peak_queue_depth).max().unwrap_or(0),
            aged_bulk: per_shard.iter().map(|s| s.aged_bulk).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_counters_accumulate_and_aggregate() {
        let a = ShardCounters::default();
        a.record_round(3, 0, Duration::from_millis(2));
        a.record_round(1, 1, Duration::from_millis(1));
        a.note_admitted(RequestClass::Bulk, 3);
        a.note_admitted(RequestClass::Latency, 1);
        a.note_shed(RequestClass::Bulk);
        let b = ShardCounters::default();
        b.record_round(5, 0, Duration::from_millis(4));
        b.note_admitted(RequestClass::Bulk, 5);
        let mut snaps = [a.snapshot(0), b.snapshot(1)];
        assert_eq!(snaps[0].aged_bulk, 0, "snapshot leaves aged_bulk to the service");
        // The service fills aged_bulk from its shard queues; the
        // aggregate must sum it like the other totals.
        snaps[0].aged_bulk = 2;
        snaps[1].aged_bulk = 1;
        assert_eq!(snaps[0].served, 4);
        assert_eq!(snaps[0].errors, 1);
        assert_eq!(snaps[0].batched_rounds, 2);
        assert_eq!(snaps[0].admitted_latency, 1);
        assert_eq!(snaps[0].admitted_bulk, 1);
        assert_eq!(snaps[0].shed_bulk, 1);
        assert_eq!(snaps[0].shed_latency, 0);
        assert_eq!(snaps[0].peak_queue_depth, 3);
        assert_eq!(snaps[1].shard, 1);
        let agg = ServingStats::aggregate(&snaps);
        assert_eq!(agg.shards, 2);
        assert_eq!(agg.served, 9);
        assert_eq!(agg.errors, 1);
        assert_eq!(agg.batched_rounds, 3);
        assert_eq!(agg.admitted_latency, 1);
        assert_eq!(agg.admitted_bulk, 2);
        assert_eq!(agg.shed_bulk, 1);
        assert_eq!(agg.peak_queue_depth, 5, "aggregate takes the max depth");
        assert_eq!(agg.aged_bulk, 3);
        assert!((agg.solve_seconds - 0.007).abs() < 1e-6);
    }

    #[test]
    fn derives_consistent_metrics() {
        let stats = RunStats {
            cycles: 1500,
            exec: 64_000,
            macs: 60_000,
            finals: 4_000,
            ..RunStats::default()
        };
        let arch = ArchConfig::default();
        let m = SolveMetrics::from_run(&stats, &arch, 100_000);
        assert_eq!(m.cycles, 1500);
        assert!((m.accel_seconds - 1500.0 / 150e6).abs() < 1e-15);
        assert!(m.gops > 0.0 && m.power_w > 0.0);
        assert!((m.gops_per_w - m.gops / m.power_w).abs() < 1e-9);
    }
}
