//! Per-matrix cost model of the serving runtime.
//!
//! Registration already produces everything a placement or scheduling
//! decision could want — the level plan (depth, per-level widths), the
//! matrix shape (order, nonzeros) and a cycle-accurate simulator run —
//! and until now all of it sat unused in the registry entry while shard
//! assignment stayed round-robin and the `auto` scheduler used one
//! global width heuristic. [`MatrixCost`] condenses those inputs into a
//! small, cheaply clonable profile that drives three decisions:
//!
//! - **Placement** — [`MatrixCost::weight`] is the expected per-solve
//!   cost a key adds to its shard; the registry's least-loaded placement
//!   ([`PlacementPolicy::Cost`]) and its `rebalance()` migrations
//!   accumulate these weights per shard.
//! - **Scheduling** — [`MatrixCost::scheduler_for`] applies the same
//!   barriered-vs-barrier-free cost comparison the native backend's
//!   `auto` resolution uses ([`recommend_scheduler`]), from the stored
//!   parallelism profile.
//! - **Capacity** — [`MatrixCost::memory_bytes`] estimates the resident
//!   footprint of serving the key (matrix + plan + solve slabs).
//!
//! # Example
//!
//! A deep, narrow band is barrier-dominated and cheap; a wide, shallow
//! DAG amortizes its few barriers and carries more work per solve. The
//! cost model separates them on both axes — and a least-loaded placement
//! loop over the weights spreads them across shards:
//!
//! ```
//! use mgd_sptrsv::coordinator::MatrixCost;
//! use mgd_sptrsv::matrix::gen::{self, GenSeed};
//! use mgd_sptrsv::runtime::{LevelSolver, SchedulerKind};
//!
//! // A pure chain: one row per level — deep and narrow.
//! let narrow = MatrixCost::from_plan(&LevelSolver::new(&gen::chain(400, GenSeed(1))));
//! // A shallow DAG: a handful of very wide levels.
//! let wide = MatrixCost::from_plan(&LevelSolver::new(&gen::shallow(2000, 0.4, GenSeed(2))));
//!
//! // The parallelism profile drives the per-matrix scheduler choice:
//! assert_eq!(narrow.scheduler_for(4), SchedulerKind::Mgd);
//! assert_eq!(wide.scheduler_for(4), SchedulerKind::Level);
//! assert!(narrow.critical_path() > wide.critical_path());
//!
//! // ...and the weight drives placement. Least-loaded: each key lands
//! // on the shard with the smallest accumulated cost, so the two keys
//! // end up on different shards instead of wherever round-robin points.
//! let mut loads = [0u64; 2];
//! for cost in [&wide, &narrow] {
//!     let shard = if loads[0] <= loads[1] { 0 } else { 1 };
//!     loads[shard] += cost.weight();
//! }
//! assert!(loads[0] > 0 && loads[1] > 0);
//! assert!(wide.weight() > narrow.weight());
//! ```

use crate::runtime::{recommend_scheduler, LevelSolver, SchedulerKind};
use anyhow::{bail, Result};
use std::str::FromStr;

/// How the registry assigns a freshly registered key to a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Least-loaded by accumulated [`MatrixCost::weight`] (ties go to the
    /// lowest shard index). The default.
    #[default]
    Cost,
    /// Registration-order round-robin, blind to the request mix — the
    /// pre-cost-model behavior, kept as an opt-out and as the bench
    /// baseline (`mgd bench skew` measures the difference).
    RoundRobin,
}

impl FromStr for PlacementPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "cost" => Ok(Self::Cost),
            "round-robin" | "rr" => Ok(Self::RoundRobin),
            other => bail!("unknown placement {other:?} (expected cost|round-robin)"),
        }
    }
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Cost => "cost",
            Self::RoundRobin => "round-robin",
        })
    }
}

/// Cost profile of one registered matrix, derived at registration time
/// from the level plan and (when available) the registration-time
/// simulator run. Cheap to clone; a swap or migration carries it along.
#[derive(Debug, Clone)]
pub struct MatrixCost {
    n: usize,
    nnz: usize,
    /// Per-level row counts of the level decomposition, in dependency
    /// order — the parallelism profile everything else derives from.
    level_widths: Vec<u32>,
    /// Estimated cycles per solve: the cycle-accurate simulator's count
    /// when the matrix went through registration
    /// ([`MatrixCost::with_measured_cycles`]), an analytic work estimate
    /// otherwise. Never zero.
    est_cycles: u64,
}

impl MatrixCost {
    /// Build the profile from a prepared plan alone, with an analytic
    /// cycle estimate (each row costs its solve, each off-diagonal edge
    /// a multiply-accumulate). Registration refines the estimate with
    /// the measured simulator run via
    /// [`MatrixCost::with_measured_cycles`].
    pub fn from_plan(solver: &LevelSolver) -> Self {
        let m = solver.matrix();
        let est_cycles = (m.n as u64 + 2 * m.off_diag_nnz() as u64).max(1);
        Self {
            n: m.n,
            nnz: m.nnz(),
            level_widths: solver.plans().iter().map(|p| p.rows.len() as u32).collect(),
            est_cycles,
        }
    }

    /// Replace the analytic cycle estimate with a measured count (the
    /// registration-time cycle-accurate simulation). Zero is clamped to
    /// one so a weight can never vanish from the placement accounting.
    pub fn with_measured_cycles(mut self, cycles: u64) -> Self {
        self.est_cycles = cycles.max(1);
        self
    }

    /// Matrix order.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored nonzeros (diagonal included).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Length of the critical path: the level count — no schedule on any
    /// number of workers can finish in fewer dependent steps.
    pub fn critical_path(&self) -> usize {
        self.level_widths.len()
    }

    /// Widest level of the decomposition — the peak useful parallelism.
    pub fn max_width(&self) -> usize {
        self.level_widths.iter().map(|&w| w as usize).max().unwrap_or(0)
    }

    /// Average level width (rows per dependent step), rounded down.
    pub fn avg_width(&self) -> usize {
        self.n / self.level_widths.len().max(1)
    }

    /// Estimated cycles per solve (measured by the registration-time
    /// simulation when available). Always ≥ 1.
    pub fn cycles(&self) -> u64 {
        self.est_cycles
    }

    /// The load this key adds to its shard, in placement units: the
    /// per-solve cycle estimate. Always ≥ 1, so even a trivial key
    /// occupies its shard in the least-loaded accounting.
    pub fn weight(&self) -> u64 {
        self.est_cycles
    }

    /// Estimated resident footprint of serving this key: CSR storage
    /// (values + column ids + row pointers) plus the per-solve x/b slabs.
    pub fn memory_bytes(&self) -> u64 {
        let nnz = self.nnz as u64;
        let n = self.n as u64;
        nnz * 8 + (n + 1) * 8 + 2 * n * 4
    }

    /// The scheduler the cost model picks for this matrix on `threads`
    /// workers — the same barriered-vs-barrier-free comparison the
    /// native backend's `auto` resolution runs
    /// ([`recommend_scheduler`]): deep/narrow profiles go barrier-free
    /// (`Mgd`), wide/shallow ones take the `Level` path.
    pub fn scheduler_for(&self, threads: usize) -> SchedulerKind {
        recommend_scheduler(self.level_widths.iter().map(|&w| w as usize), threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::{self, GenSeed};

    #[test]
    fn placement_policy_parses_and_displays() {
        assert_eq!("cost".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::Cost);
        assert_eq!(
            "round-robin".parse::<PlacementPolicy>().unwrap(),
            PlacementPolicy::RoundRobin
        );
        assert_eq!("rr".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::RoundRobin);
        assert!("hash".parse::<PlacementPolicy>().is_err());
        for p in [PlacementPolicy::Cost, PlacementPolicy::RoundRobin] {
            assert_eq!(p.to_string().parse::<PlacementPolicy>().unwrap(), p);
        }
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::Cost);
    }

    #[test]
    fn profile_reflects_the_dag_shape() {
        let chain = MatrixCost::from_plan(&LevelSolver::new(&gen::chain(300, GenSeed(5))));
        assert_eq!(chain.n(), 300);
        assert_eq!(chain.critical_path(), 300);
        assert_eq!(chain.max_width(), 1);
        assert_eq!(chain.avg_width(), 1);
        let wide = MatrixCost::from_plan(&LevelSolver::new(&gen::shallow(2000, 0.4, GenSeed(6))));
        assert!(wide.critical_path() < 30, "{}", wide.critical_path());
        assert!(wide.max_width() > 100);
        assert!(wide.memory_bytes() > chain.memory_bytes());
    }

    #[test]
    fn weight_prefers_measured_cycles_and_never_vanishes() {
        let cost = MatrixCost::from_plan(&LevelSolver::new(&gen::chain(100, GenSeed(7))));
        let analytic = cost.weight();
        assert!(analytic >= 100);
        let measured = cost.clone().with_measured_cycles(12_345);
        assert_eq!(measured.weight(), 12_345);
        let clamped = cost.with_measured_cycles(0);
        assert_eq!(clamped.weight(), 1, "zero cycles must clamp, not vanish");
    }

    #[test]
    fn scheduler_choice_matches_the_backend_rule() {
        use crate::runtime::{NativeBackend, NativeConfig};
        let nb = NativeBackend::new(NativeConfig {
            threads: 4,
            ..NativeConfig::default()
        });
        for m in [
            gen::chain(200, GenSeed(31)),
            gen::shallow(2000, 0.4, GenSeed(32)),
            gen::banded(400, 5, 0.6, GenSeed(33)),
            gen::circuit(600, 5, 0.8, GenSeed(34)),
        ] {
            let solver = LevelSolver::new(&m);
            let cost = MatrixCost::from_plan(&solver);
            assert_eq!(
                cost.scheduler_for(4),
                nb.resolve_scheduler(&solver),
                "cost model and backend must agree on the auto pick"
            );
        }
    }
}
