//! The solve service: compile once, serve a stream of RHS requests.
//!
//! Requests flow through an mpsc queue into worker threads; each worker
//! batches up to `batch_size` requests per dequeue round to amortize
//! dispatch overhead (the PJRT executables and level plans are shared,
//! read-only). Responses return through per-request channels.

use super::metrics::SolveMetrics;
use crate::compiler::{compile, CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::{LevelSolver, PjrtRuntime};
use crate::sim::Accelerator;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Compiler/architecture options.
    pub compiler: CompilerConfig,
    /// Worker threads serving the numeric path.
    pub workers: usize,
    /// Max requests drained per batch round.
    pub batch_size: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            workers: 2,
            batch_size: 8,
        }
    }
}

/// One solve request.
pub struct SolveRequest {
    /// Right-hand side (length n).
    pub b: Vec<f32>,
    /// Response channel.
    pub reply: mpsc::Sender<Result<SolveResponse>>,
}

/// One solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Solution vector.
    pub x: Vec<f32>,
    /// Host wall-clock latency of the numeric path (seconds).
    pub host_seconds: f64,
    /// Shared accelerator metrics for this matrix.
    pub metrics: SolveMetrics,
}

/// The running service.
pub struct SolveService {
    tx: Option<mpsc::Sender<SolveRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The compiled accelerator program (public for inspection/benches).
    pub program: Arc<Program>,
    /// Shared per-matrix metrics.
    pub metrics: SolveMetrics,
    served: Arc<AtomicU64>,
}

impl SolveService {
    /// Compile `m`, simulate once for metrics, load the PJRT runtime, and
    /// spawn the worker pool.
    pub fn start(m: &CsrMatrix, artifacts: &Path, cfg: ServiceConfig) -> Result<Self> {
        let program = Arc::new(compile(m, &cfg.compiler).context("compile")?);
        // One cycle-accurate run (RHS-independent schedule): double-entry
        // verification + the cost model shared by all requests.
        let mut acc = Accelerator::new(cfg.compiler.arch);
        let probe_b = vec![1.0f32; m.n];
        let run = acc.run(&program, &probe_b).context("simulate")?;
        run.stats
            .verify_against(&program.predicted)
            .context("double-entry check")?;
        let metrics = SolveMetrics::from_run(&run.stats, &cfg.compiler.arch, program.flops());
        let solver = Arc::new(LevelSolver::new(m));
        // Validate the artifacts once on the calling thread (fail fast).
        PjrtRuntime::load(artifacts).context("load artifacts")?;
        let (tx, rx) = mpsc::channel::<SolveRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let served = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let solver = Arc::clone(&solver);
            // PJRT clients are not Send/Sync (Rc-backed FFI handles), so
            // each worker owns a private runtime with its own compiled
            // executables.
            let artifacts = artifacts.to_path_buf();
            let metrics = metrics.clone();
            let served = Arc::clone(&served);
            let batch = cfg.batch_size.max(1);
            workers.push(std::thread::spawn(move || {
                let runtime = match PjrtRuntime::load(&artifacts) {
                    Ok(rt) => rt,
                    Err(_) => return, // validated above; only races can fail
                };
                loop {
                // Drain up to `batch` requests in one round.
                let mut reqs = Vec::with_capacity(batch);
                {
                    let guard = rx.lock().unwrap();
                    match guard.recv() {
                        Ok(r) => reqs.push(r),
                        Err(_) => return, // channel closed
                    }
                    while reqs.len() < batch {
                        match guard.try_recv() {
                            Ok(r) => reqs.push(r),
                            Err(_) => break,
                        }
                    }
                }
                    // Batched rounds go through the multi-RHS kernels,
                    // amortizing PJRT dispatch (EXPERIMENTS.md §Perf).
                    let t0 = Instant::now();
                    if reqs.len() > 1 {
                        let bs: Vec<Vec<f32>> =
                            reqs.iter().map(|r| r.b.clone()).collect();
                        match solver.solve_multi(&runtime, &bs) {
                            Ok(xs) => {
                                let per = t0.elapsed().as_secs_f64() / reqs.len() as f64;
                                for (req, x) in reqs.into_iter().zip(xs) {
                                    served.fetch_add(1, Ordering::Relaxed);
                                    let _ = req.reply.send(Ok(SolveResponse {
                                        x,
                                        host_seconds: per,
                                        metrics: metrics.clone(),
                                    }));
                                }
                            }
                            Err(e) => {
                                let msg = format!("{e:#}");
                                for req in reqs {
                                    served.fetch_add(1, Ordering::Relaxed);
                                    let _ =
                                        req.reply.send(Err(anyhow::anyhow!(msg.clone())));
                                }
                            }
                        }
                    } else {
                        for req in reqs {
                            let t0 = Instant::now();
                            let out =
                                solver.solve(&runtime, &req.b).map(|x| SolveResponse {
                                    x,
                                    host_seconds: t0.elapsed().as_secs_f64(),
                                    metrics: metrics.clone(),
                                });
                            served.fetch_add(1, Ordering::Relaxed);
                            let _ = req.reply.send(out);
                        }
                    }
                }
            }));
        }
        Ok(Self {
            tx: Some(tx),
            workers,
            program,
            metrics,
            served,
        })
    }

    /// Submit a request; returns the receiver for the response.
    pub fn submit(&self, b: Vec<f32>) -> Result<mpsc::Receiver<Result<SolveResponse>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("service stopped")?
            .send(SolveRequest { b, reply })
            .ok()
            .context("service queue closed")?;
        Ok(rx)
    }

    /// Solve synchronously (submit + wait).
    pub fn solve(&self, b: Vec<f32>) -> Result<SolveResponse> {
        self.submit(b)?.recv().context("worker dropped")?
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop the workers (drains the queue first).
    pub fn shutdown(mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for SolveService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use std::path::PathBuf;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            workers: 2,
            batch_size: 4,
        }
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        if !artifacts().join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = gen::circuit(400, 5, 0.8, GenSeed(1));
        let svc = SolveService::start(&m, &artifacts(), small_cfg()).unwrap();
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..12 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.recv().unwrap().unwrap();
            assert_close_to_reference(&m, &b, &resp.x, 1e-3);
            assert!(resp.metrics.gops > 0.0);
            assert!(resp.host_seconds > 0.0);
        }
        assert_eq!(svc.served(), 12);
        svc.shutdown();
    }

    #[test]
    fn metrics_match_program_prediction() {
        if !artifacts().join("manifest.txt").exists() {
            return;
        }
        let m = gen::banded(300, 5, 0.6, GenSeed(2));
        let svc = SolveService::start(&m, &artifacts(), small_cfg()).unwrap();
        assert_eq!(svc.metrics.cycles, svc.program.predicted.cycles);
        svc.shutdown();
    }
}
