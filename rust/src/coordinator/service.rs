//! The solve service: compile once, serve a stream of RHS requests.
//!
//! Requests flow through an mpsc queue into worker threads; each worker
//! batches up to `batch_size` requests per dequeue round to amortize
//! dispatch overhead (the solver backend and level plans are shared,
//! read-only). Responses return through per-request channels.
//!
//! The numeric path is a pluggable [`SolverBackend`] chosen at startup by
//! [`create_backend`]: native by default, PJRT when the `pjrt` feature is
//! enabled and its artifacts load. A backend that cannot initialize fails
//! [`SolveService::start`] immediately, and per-request solver errors are
//! replied to the requester — workers never exit silently with requests
//! pending.

use super::metrics::SolveMetrics;
use crate::compiler::{compile, CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::{create_backend, BackendConfig, LevelSolver, SolverBackend};
use crate::sim::Accelerator;
use anyhow::{Context, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Compiler/architecture options.
    pub compiler: CompilerConfig,
    /// Worker threads serving the numeric path.
    pub workers: usize,
    /// Max requests drained per batch round.
    pub batch_size: usize,
    /// Numeric backend selection (native by default).
    pub backend: BackendConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            workers: 2,
            batch_size: 8,
            backend: BackendConfig::default(),
        }
    }
}

/// One solve request.
pub struct SolveRequest {
    /// Right-hand side (length n).
    pub b: Vec<f32>,
    /// Response channel.
    pub reply: mpsc::Sender<Result<SolveResponse>>,
}

/// One solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Solution vector.
    pub x: Vec<f32>,
    /// Host wall-clock latency of the numeric path (seconds). May be 0.0
    /// for tiny solves at coarse timer resolution.
    pub host_seconds: f64,
    /// Shared accelerator metrics for this matrix.
    pub metrics: SolveMetrics,
}

/// The running service.
pub struct SolveService {
    tx: Option<mpsc::Sender<SolveRequest>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// The compiled accelerator program (public for inspection/benches).
    pub program: Arc<Program>,
    /// Shared per-matrix metrics.
    pub metrics: SolveMetrics,
    served: Arc<AtomicU64>,
    backend_name: &'static str,
}

impl SolveService {
    /// Compile `m`, simulate once for metrics, construct the configured
    /// backend ([`create_backend`]), and spawn the worker pool. Backend
    /// construction failures — e.g. an explicit `pjrt` request without the
    /// toolchain — are startup errors, not hung requests.
    pub fn start(m: &CsrMatrix, cfg: ServiceConfig) -> Result<Self> {
        let backend = create_backend(&cfg.backend).context("construct solver backend")?;
        Self::start_with_backend(m, backend, cfg)
    }

    /// Like [`SolveService::start`] but with a caller-provided backend
    /// (dependency injection for tests, benches and embedders).
    pub fn start_with_backend(
        m: &CsrMatrix,
        backend: Arc<dyn SolverBackend>,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let program = Arc::new(compile(m, &cfg.compiler).context("compile")?);
        // One cycle-accurate run (RHS-independent schedule): double-entry
        // verification + the cost model shared by all requests.
        let mut acc = Accelerator::new(cfg.compiler.arch);
        let probe_b = vec![1.0f32; m.n];
        let run = acc.run(&program, &probe_b).context("simulate")?;
        run.stats
            .verify_against(&program.predicted)
            .context("double-entry check")?;
        let metrics = SolveMetrics::from_run(&run.stats, &cfg.compiler.arch, program.flops());
        let solver = Arc::new(LevelSolver::new(m));
        let backend_name = backend.name();
        let (tx, rx) = mpsc::channel::<SolveRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let served = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let solver = Arc::clone(&solver);
            let backend = Arc::clone(&backend);
            let metrics = metrics.clone();
            let served = Arc::clone(&served);
            let batch = cfg.batch_size.max(1);
            workers.push(std::thread::spawn(move || {
                loop {
                    // Drain up to `batch` requests in one round.
                    let mut reqs = Vec::with_capacity(batch);
                    {
                        let guard = rx.lock().unwrap();
                        match guard.recv() {
                            Ok(r) => reqs.push(r),
                            Err(_) => return, // channel closed
                        }
                        while reqs.len() < batch {
                            match guard.try_recv() {
                                Ok(r) => reqs.push(r),
                                Err(_) => break,
                            }
                        }
                    }
                    // Batched rounds go through the backend's multi-RHS
                    // path, amortizing dispatch and gather staging.
                    let t0 = Instant::now();
                    if reqs.len() > 1 && backend.supports_multi_rhs() {
                        let count = reqs.len();
                        // Move the RHS vectors out of the requests instead
                        // of cloning them; replies only need the channels.
                        let (bs, replies): (Vec<Vec<f32>>, Vec<_>) =
                            reqs.into_iter().map(|r| (r.b, r.reply)).unzip();
                        match backend.solve_multi(&solver, &bs) {
                            Ok(xs) => {
                                let per = t0.elapsed().as_secs_f64() / count as f64;
                                for (reply, x) in replies.into_iter().zip(xs) {
                                    served.fetch_add(1, Ordering::Relaxed);
                                    let _ = reply.send(Ok(SolveResponse {
                                        x,
                                        host_seconds: per,
                                        metrics: metrics.clone(),
                                    }));
                                }
                            }
                            Err(e) => {
                                // Propagate the failure to every caller in
                                // the round; a worker must never drop
                                // requests on the floor.
                                let msg = format!("{e:#}");
                                for reply in replies {
                                    served.fetch_add(1, Ordering::Relaxed);
                                    let _ = reply.send(Err(anyhow::anyhow!(msg.clone())));
                                }
                            }
                        }
                    } else {
                        for req in reqs {
                            let t0 = Instant::now();
                            let out = backend.solve(&solver, &req.b).map(|x| SolveResponse {
                                x,
                                host_seconds: t0.elapsed().as_secs_f64(),
                                metrics: metrics.clone(),
                            });
                            served.fetch_add(1, Ordering::Relaxed);
                            let _ = req.reply.send(out);
                        }
                    }
                }
            }));
        }
        Ok(Self {
            tx: Some(tx),
            workers,
            program,
            metrics,
            served,
            backend_name,
        })
    }

    /// Submit a request; returns the receiver for the response.
    pub fn submit(&self, b: Vec<f32>) -> Result<mpsc::Receiver<Result<SolveResponse>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("service stopped")?
            .send(SolveRequest { b, reply })
            .ok()
            .context("service queue closed")?;
        Ok(rx)
    }

    /// Solve synchronously (submit + wait).
    pub fn solve(&self, b: Vec<f32>) -> Result<SolveResponse> {
        self.submit(b)?.recv().context("worker dropped")?
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Name of the numeric backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Stop the workers (drains the queue first).
    pub fn shutdown(mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for SolveService {
    fn drop(&mut self) {
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use crate::runtime::BackendKind;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            workers: 2,
            batch_size: 4,
            backend: BackendConfig::default(),
        }
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(1));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..12 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.recv().unwrap().unwrap();
            assert_close_to_reference(&m, &b, &resp.x, 1e-3);
            assert!(resp.metrics.gops > 0.0);
            // >= 0.0, not > 0.0: tiny solves can land under the host
            // timer's resolution.
            assert!(resp.host_seconds >= 0.0);
        }
        assert_eq!(svc.served(), 12);
        svc.shutdown();
    }

    #[test]
    fn serves_through_the_mgd_scheduler() {
        use crate::runtime::{NativeConfig, SchedulerKind};
        // A deep matrix served with the barrier-free scheduler pinned:
        // requests flow through `MgdPlan`/`mgd_exec` end to end.
        let m = gen::banded(600, 3, 0.9, GenSeed(6));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Native,
                native: NativeConfig {
                    threads: 4,
                    scheduler: SchedulerKind::Mgd,
                    ..NativeConfig::default()
                },
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let svc = SolveService::start(&m, cfg).unwrap();
        assert_eq!(svc.backend_name(), "native");
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..6 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + 2 * k) % 5) as f32 - 2.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.recv().unwrap().unwrap();
            // The MGD scheduler's contract is bitwise-serial numerics.
            let want = crate::matrix::triangular::solve_serial(&m, &b);
            for i in 0..m.n {
                assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        assert_eq!(svc.served(), 6);
        svc.shutdown();
    }

    #[test]
    fn default_backend_is_native_without_pjrt_artifacts() {
        let m = gen::banded(200, 4, 0.6, GenSeed(3));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        // Auto selection: PJRT artifacts are absent in a clean checkout,
        // so the service must come up on the native executor.
        assert_eq!(svc.backend_name(), "native");
        let resp = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &resp.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn explicit_pjrt_without_toolchain_fails_at_start_not_at_solve() {
        // The seed bug: a worker whose runtime failed to load returned
        // silently, so submitted requests hung forever. Backend
        // construction now happens before any worker spawns.
        let m = gen::banded(150, 4, 0.6, GenSeed(4));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Pjrt,
                artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let err = SolveService::start(&m, cfg).err().expect("must not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt") || msg.contains("PJRT"), "{msg}");
    }

    #[test]
    fn worker_replies_with_error_on_bad_request() {
        // A malformed RHS must produce an error reply, not a hang or a
        // worker exit.
        let m = gen::banded(100, 4, 0.6, GenSeed(5));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let err = svc.solve(vec![1.0f32; m.n + 7]).unwrap_err();
        assert!(format!("{err:#}").contains("rhs length"));
        // The service keeps serving after an error round.
        let ok = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &ok.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn metrics_match_program_prediction() {
        let m = gen::banded(300, 5, 0.6, GenSeed(2));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        assert_eq!(svc.metrics.cycles, svc.program.predicted.cycles);
        svc.shutdown();
    }
}
