//! The solve services: register matrices once, serve streams of RHS
//! requests.
//!
//! The serving runtime is **sharded and multi-matrix**
//! ([`ShardedSolveService`]): N matrices are registered by key into a
//! [`MatrixRegistry`] (each compiled, simulated and planned exactly once,
//! then placed on the least-loaded shard by its cost-model weight — see
//! [`super::cost::MatrixCost`] and
//! [`ShardedServiceConfig::placement`]), and every
//! [`SolveRequest`]` { matrix_key, b, reply }` is routed to the shard
//! that owns its matrix. Each shard drains its own queue with a
//! small worker pool, batching same-matrix requests through the
//! backend's multi-RHS path; responses return through per-request
//! completion cells. Per-shard [`ShardCounters`] aggregate into
//! service-wide [`ServingStats`].
//!
//! The numeric path is a pluggable [`SolverBackend`] chosen at startup by
//! [`create_backend`] and — by default — **shared across every shard and
//! matrix**, so the native backend's persistent MGD worker pool is
//! spawned once per service (or once per backend lifetime, when an
//! embedder reuses a backend across service restarts) rather than per
//! solve or per matrix. Registration calls
//! [`SolverBackend::prepare`], so plan construction and pool spawn happen
//! at register time, not on the first request.
//!
//! Matrices are **dynamic**, not pinned forever:
//! [`ShardedSolveService::evict`] retires a key after draining its
//! in-flight requests (every routed request carries a drop-guarded
//! in-flight mark, so the drain cannot be wedged or racily skipped), and
//! [`ShardedSolveService::swap`] replaces a key's matrix live — the new
//! entry is compiled/planned/warmed off the hot path and published in one
//! atomic pointer move while requests keep flowing.
//!
//! # Admission control and priority lanes
//!
//! The front end is **admission-controlled**: each shard holds two
//! bounded queue lanes — [`RequestClass::Latency`] drained before
//! [`RequestClass::Bulk`], except that a bulk job older than
//! [`ShardedServiceConfig::bulk_aging_ms`] is promoted ahead of the
//! latency lane, so a sustained latency flood cannot starve bulk
//! indefinitely — and
//! [`ShardedServiceConfig::queue_cap`] bounds each lane's depth. What
//! happens at a full lane is the [`AdmissionPolicy`]: `Block` parks the
//! submitter until space frees (bounded first-come), `Shed` rejects with
//! an immediate queue-cap error reply, and `ByClass` sheds bulk while
//! blocking (never dropping) latency traffic.
//! [`ShardedSolveService::try_route`] is the non-blocking submit: it
//! returns [`Admission::Shed`] with the reason instead of ever parking a
//! `Shed`/`ByClass`-bulk caller, and [`Admission::Admitted`] carries a
//! [`SolveHandle`] whose [`SolveHandle::wait_timeout`] finally gives
//! callers a deadline. The class rides the request (or the key's default,
//! set at `register`/`swap`) through queue ordering and down into the
//! native backend's pool lease, where reserved latency-lane workers stop
//! a bulk flood from leasing the pool dry.
//!
//! Failure story: failures are loud, and every *admitted* request is
//! answered. Backend construction errors fail `start`, registration
//! (compile/verify) errors fail `register`, an unknown `matrix_key` or a
//! shed request gets an immediate error *reply*, per-request solver
//! errors are replied to the requester, and the shutdown race replies
//! with a "service stopped" error instead of dropping the channel —
//! workers never exit silently with requests pending. The one *wait* a
//! caller can still experience — its own solve taking long — is what
//! [`SolveHandle::wait_timeout`] bounds: the request stays in flight
//! (and its in-flight accounting intact) after a timeout, and the reply
//! can still be awaited later.
//!
//! # Completion without parked threads
//!
//! Replies travel through one-shot completion cells
//! ([`super::completion`]), not a parked mpsc receiver: the shard worker
//! fires whatever readiness the caller registered. A [`SolveHandle`] can
//! therefore be consumed four ways — blocking
//! ([`SolveHandle::wait`]/[`SolveHandle::wait_timeout`], the historical
//! contract), polled ([`SolveHandle::poll`]/[`SolveHandle::try_wait`]
//! with a [`completion::Waker`] callback), callback-registered
//! ([`SolveHandle::on_ready`]), or as a zero-dependency
//! [`std::future::Future`] ([`SolveHandle::into_future`]). Streaming
//! clients build on this via [`super::session::SolveSession`]
//! ([`ShardedSolveService::open_session`]): admission paid once per
//! session, RHS pipelined with bounded in-session depth.
//!
//! [`SolveService`] remains as the single-matrix facade (CLI `mgd solve`,
//! benches): a 1-shard service with one matrix registered under an
//! internal key.

use super::completion::{self, Completion, PollState};
use super::cost::PlacementPolicy;
use super::metrics::{ServingStats, ShardCounters, ShardStats, SolveMetrics};
use super::registry::{MatrixRegistry, Migration, RegisteredMatrix};
use crate::compiler::{CompilerConfig, Program};
use crate::matrix::CsrMatrix;
use crate::runtime::sync::atomic::{AtomicU64, Ordering};
use crate::runtime::sync::{Arc, Condvar, Mutex};
use crate::runtime::{create_backend, BackendConfig, RequestClass, SolverBackend};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::str::FromStr;
use std::time::{Duration, Instant};

/// What a shard does when a request arrives at a full queue lane (each
/// lane is bounded by [`ShardedServiceConfig::queue_cap`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Park the submitting thread until the lane has room — bounded
    /// first-come (the compatibility default; with `queue_cap == 0`
    /// nothing ever blocks and admission behaves exactly like the
    /// unbounded front end this replaces).
    #[default]
    Block,
    /// Reject with an immediate error reply naming the cap — the
    /// submitter never parks; [`ShardedSolveService::try_route`] reports
    /// it as [`Admission::Shed`].
    Shed,
    /// Per-class: [`RequestClass::Bulk`] is shed at the cap,
    /// [`RequestClass::Latency`] blocks (latency-critical traffic is
    /// never dropped; its lane only fills under genuine latency
    /// overload, which back-pressures instead of losing requests).
    ByClass,
}

impl FromStr for AdmissionPolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "block" => Ok(Self::Block),
            "shed" => Ok(Self::Shed),
            "by-class" => Ok(Self::ByClass),
            other => bail!("unknown admission policy {other:?} (expected block|shed|by-class)"),
        }
    }
}

impl std::fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Block => "block",
            Self::Shed => "shed",
            Self::ByClass => "by-class",
        })
    }
}

/// Configuration of the sharded multi-matrix service.
#[derive(Debug, Clone)]
pub struct ShardedServiceConfig {
    /// Compiler/architecture options used at registration.
    pub compiler: CompilerConfig,
    /// Number of shards (request queues); registration places each
    /// matrix by the [`placement`](ShardedServiceConfig::placement)
    /// policy. Clamped to ≥ 1.
    pub shards: usize,
    /// Worker threads draining each shard's queue.
    pub workers_per_shard: usize,
    /// Max requests drained per dispatch round of one shard worker.
    pub batch_size: usize,
    /// Numeric backend selection (native by default).
    pub backend: BackendConfig,
    /// When true, every shard constructs its own backend instance (own
    /// worker pools — more threads, shard-parallel numerics). The default
    /// `false` shares one backend, and therefore one persistent MGD pool,
    /// across all shards: a solve already fans out across the pool's
    /// workers, so shards contend on cores either way and sharing keeps
    /// the thread count bounded.
    pub backend_per_shard: bool,
    /// Per-lane queue-depth bound of each shard (two lanes per shard:
    /// latency and bulk). `0` means unbounded — the pre-admission
    /// behavior. With a cap set, no lane's depth ever exceeds it; the
    /// [`AdmissionPolicy`] decides what a full lane does to the
    /// submitter.
    pub queue_cap: usize,
    /// Full-lane behavior (see [`AdmissionPolicy`]); irrelevant while
    /// `queue_cap == 0`.
    pub admission: AdmissionPolicy,
    /// How registration assigns keys to shards: least-loaded by
    /// cost-model weight ([`PlacementPolicy::Cost`], the default) or
    /// registration-order round-robin ([`PlacementPolicy::RoundRobin`]).
    pub placement: PlacementPolicy,
    /// Aging bound of the bulk lane in milliseconds: a queued bulk job
    /// older than this is drained ahead of the latency lane, so a
    /// sustained latency flood cannot starve bulk indefinitely. `0`
    /// (default) disables aging — latency drains strictly first.
    pub bulk_aging_ms: u64,
}

impl Default for ShardedServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            shards: 2,
            workers_per_shard: 2,
            batch_size: 8,
            backend: BackendConfig::default(),
            backend_per_shard: false,
            queue_cap: 0,
            admission: AdmissionPolicy::Block,
            placement: PlacementPolicy::Cost,
            bulk_aging_ms: 0,
        }
    }
}

/// Single-matrix service configuration (the [`SolveService`] facade).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Compiler/architecture options.
    pub compiler: CompilerConfig,
    /// Worker threads serving the numeric path.
    pub workers: usize,
    /// Max requests drained per batch round.
    pub batch_size: usize,
    /// Numeric backend selection (native by default).
    pub backend: BackendConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            compiler: CompilerConfig::default(),
            workers: 2,
            batch_size: 8,
            backend: BackendConfig::default(),
        }
    }
}

/// One solve request of the sharded service: which matrix, which RHS,
/// which scheduling class, and where to send the reply.
pub struct SolveRequest {
    /// Registration key of the matrix to solve against.
    pub matrix_key: String,
    /// Right-hand side (length = the matrix's order).
    pub b: Vec<f32>,
    /// Producer end of the reply's completion cell
    /// ([`completion::channel`]); the matching [`Completion`] usually
    /// lives inside a [`SolveHandle`].
    pub reply: completion::Completer<Result<SolveResponse>>,
    /// Scheduling class; `None` uses the key's default (itself
    /// [`RequestClass::Bulk`] unless the key was registered or swapped
    /// with an explicit class).
    pub class: Option<RequestClass>,
}

/// Receiver side of one admitted request: wraps the reply's completion
/// cell ([`super::completion`]) with blocking waits, waker/poll
/// readiness, `FnOnce` callbacks and a `Future` adapter. Obtained from
/// [`ShardedSolveService::submit`], [`ShardedSolveService::submit_class`]
/// or an [`Admission::Admitted`].
pub struct SolveHandle {
    cell: Completion<Result<SolveResponse>>,
}

impl SolveHandle {
    /// Block until the reply arrives. A dropped reply cell (the service
    /// was torn down around the request — the contract makes this
    /// unreachable, but the API refuses to hang on it) maps to an error.
    pub fn wait(self) -> Result<SolveResponse> {
        self.cell
            .wait()
            .unwrap_or_else(|| Err(anyhow!("reply channel dropped without a reply")))
    }

    /// Wait for the reply with a deadline. `None` means the deadline
    /// passed: the request is **still in flight** (its reply, and its
    /// in-flight accounting toward [`ShardedSolveService::evict`], are
    /// unaffected) and the handle can be waited again — a timeout
    /// observes slowness, it does not cancel work. A timed-out handle
    /// can also re-arm readiness instead: [`SolveHandle::on_ready`] and
    /// [`SolveHandle::poll`] stay valid after any number of expiries.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Result<SolveResponse>> {
        match self.cell.wait_timeout(timeout) {
            PollState::Ready(reply) => Some(reply),
            PollState::Pending => None,
            PollState::Gone => Some(Err(anyhow!("reply channel dropped without a reply"))),
        }
    }

    /// Non-blocking poll that arms `waker` while the solve is still in
    /// flight: the waker fires (once, off the completing thread, no
    /// locks held) when the reply lands, after which the next call
    /// returns it. Re-polling replaces the previous registration.
    /// `Some(Err(..))` covers both error replies and a dropped cell.
    pub fn poll(&self, waker: &completion::Waker) -> Option<Result<SolveResponse>> {
        match self.cell.poll(waker) {
            PollState::Ready(reply) => Some(reply),
            PollState::Pending => None,
            PollState::Gone => Some(Err(anyhow!("reply channel dropped without a reply"))),
        }
    }

    /// Non-blocking look without registering anything: `None` while the
    /// solve is in flight.
    pub fn try_wait(&self) -> Option<Result<SolveResponse>> {
        match self.cell.try_take() {
            PollState::Ready(reply) => Some(reply),
            PollState::Pending => None,
            PollState::Gone => Some(Err(anyhow!("reply channel dropped without a reply"))),
        }
    }

    /// Registers a one-shot readiness callback: `f` runs when the reply
    /// lands (or immediately, on this thread, if it already did). The
    /// callback only signals readiness — collect the reply itself with
    /// [`SolveHandle::try_wait`] or a wait.
    pub fn on_ready<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.cell.on_ready(f)
    }

    /// Adapts the handle to a [`std::future::Future`] resolving to the
    /// reply — no async runtime required or provided; bring any executor
    /// that drives a `std::task::Waker`.
    pub fn into_future(self) -> SolveFuture {
        SolveFuture {
            inner: self.cell.into_future(),
        }
    }
}

/// [`std::future::Future`] adapter over a [`SolveHandle`] (see
/// [`SolveHandle::into_future`]); a dropped reply cell resolves to the
/// same error the blocking wait reports.
pub struct SolveFuture {
    inner: completion::CompletionFuture<Result<SolveResponse>>,
}

impl std::future::Future for SolveFuture {
    type Output = Result<SolveResponse>;

    fn poll(
        mut self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<Self::Output> {
        match std::pin::Pin::new(&mut self.inner).poll(cx) {
            std::task::Poll::Ready(Some(reply)) => std::task::Poll::Ready(reply),
            std::task::Poll::Ready(None) => std::task::Poll::Ready(Err(anyhow!(
                "reply channel dropped without a reply"
            ))),
            std::task::Poll::Pending => std::task::Poll::Pending,
        }
    }
}

/// Outcome of a non-blocking [`ShardedSolveService::try_route`].
pub enum Admission {
    /// The request holds a queue slot (or was answered immediately, e.g.
    /// an unknown key): exactly one reply will arrive on the handle.
    Admitted(SolveHandle),
    /// The admission policy rejected the request at a full queue lane;
    /// the string names the lane, its cap and the policy. Nothing was
    /// enqueued.
    Shed(String),
}

/// One solve response.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Solution vector.
    pub x: Vec<f32>,
    /// Host wall-clock latency of the numeric path (seconds, averaged
    /// over the dispatch batch the request rode in). May be 0.0 for tiny
    /// solves at coarse timer resolution.
    pub host_seconds: f64,
    /// Shared accelerator metrics for this matrix.
    pub metrics: SolveMetrics,
}

/// Owns one in-flight mark on a registry entry; checked out at route
/// time, checked back in when dropped. Dropping *after* the reply send
/// means [`ShardedSolveService::evict`] cannot return while any reply is
/// still owed — and because it is a drop guard, a job that dies on the
/// floor (worker panic, shutdown teardown) still checks in instead of
/// wedging a future evict forever.
struct InflightGuard(Arc<RegisteredMatrix>);

impl InflightGuard {
    /// The resolved registry entry this mark belongs to.
    fn entry(&self) -> &Arc<RegisteredMatrix> {
        &self.0
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.note_done();
    }
}

/// A routed job on a shard queue: the registry entry is resolved at
/// submit time (owned by the in-flight guard) so shard workers never
/// touch the key map.
struct ShardJob {
    b: Vec<f32>,
    reply: completion::Completer<Result<SolveResponse>>,
    /// In-flight mark owning the resolved entry, dropped after the reply
    /// is delivered.
    guard: InflightGuard,
    /// Effective class (request override or key default), fixed at
    /// admission.
    class: RequestClass,
    /// When the job entered admission — what the bulk lane's aging bound
    /// measures against.
    enqueued_at: Instant,
}

/// Internal admission outcome (`admit` already delivered any error
/// reply; this only tells the public wrappers what to report).
enum Admitted {
    /// The request holds a queue slot.
    Enqueued,
    /// The request was answered immediately (unknown key).
    Answered,
    /// The request was shed; the reply channel got the reason too.
    Shed(String),
}

/// Outcome of one [`ShardQueue::push`].
enum Enqueue {
    /// The job holds a queue slot; `depth` is its lane's depth right
    /// after the enqueue (feeds the peak-depth counter).
    Admitted { depth: usize },
    /// Rejected at a full lane under `Shed`/`ByClass`; the job comes
    /// back so the caller can send the error reply on its channel.
    Shed { job: Box<ShardJob>, reason: String },
    /// The queue is closed (service stopping); the job comes back so the
    /// caller can uphold the reply contract.
    Closed { job: Box<ShardJob> },
}

/// One shard's bounded two-lane queue. The latency lane is drained
/// before the bulk lane — except that a bulk job older than the `aging`
/// window is promoted ahead of it (the aging bound: a latency flood
/// cannot starve bulk indefinitely). Each lane's depth is bounded by
/// `cap` (0 = unbounded) **under the mutex**, so the bound is exact —
/// there is no window where a lane overshoots. `Block`-policy submitters
/// park on `space`; workers park on `ready`.
///
/// Aging needs no timed waits: a worker only parks when **both** lanes
/// are empty, in which case there is no bulk job to age — so promotion
/// is purely an ordering decision made at each dequeue against the
/// oldest bulk job's enqueue time.
struct ShardQueue {
    cap: usize,
    policy: AdmissionPolicy,
    /// Bulk-lane aging bound; `None` disables promotion (latency drains
    /// strictly first).
    aging: Option<Duration>,
    /// Bulk jobs promoted ahead of waiting latency jobs by the aging
    /// bound (feeds [`ShardStats::aged_bulk`]).
    aged: AtomicU64,
    state: Mutex<LaneState>,
    /// Signaled on every enqueue and on close (workers wait here).
    ready: Condvar,
    /// Signaled on every dequeue and on close (blocked submitters wait
    /// here).
    space: Condvar,
}

#[derive(Default)]
struct LaneState {
    latency: VecDeque<ShardJob>,
    bulk: VecDeque<ShardJob>,
    closed: bool,
}

impl ShardQueue {
    fn new(cap: usize, policy: AdmissionPolicy, aging: Option<Duration>) -> Self {
        Self {
            cap,
            policy,
            aging,
            aged: AtomicU64::new(0),
            state: Mutex::new(LaneState::default()),
            ready: Condvar::new(),
            space: Condvar::new(),
        }
    }

    /// Bulk jobs the aging bound promoted past waiting latency jobs.
    fn aged_count(&self) -> u64 {
        // relaxed: monotonic stats counter, read for reporting only.
        self.aged.load(Ordering::Relaxed)
    }

    /// Admit `job` into its class's lane, applying the admission policy
    /// at a full lane. Never drops the job: a rejected or raced-shutdown
    /// job is handed back for an error reply.
    fn push(&self, job: ShardJob) -> Enqueue {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Enqueue::Closed { job: Box::new(job) };
            }
            let depth = match job.class {
                RequestClass::Latency => st.latency.len(),
                RequestClass::Bulk => st.bulk.len(),
            };
            if self.cap == 0 || depth < self.cap {
                break;
            }
            let blocks = match self.policy {
                AdmissionPolicy::Block => true,
                AdmissionPolicy::Shed => false,
                AdmissionPolicy::ByClass => job.class == RequestClass::Latency,
            };
            if !blocks {
                return Enqueue::Shed {
                    reason: format!(
                        "{} lane is at its queue cap ({depth} of {} slots, admission policy {})",
                        job.class, self.cap, self.policy
                    ),
                    job: Box::new(job),
                };
            }
            st = self.space.wait(st).unwrap();
        }
        let lane = match job.class {
            RequestClass::Latency => &mut st.latency,
            RequestClass::Bulk => &mut st.bulk,
        };
        lane.push_back(job);
        let depth = lane.len();
        self.ready.notify_one();
        Enqueue::Admitted { depth }
    }

    /// Dequeue the next drain group: latency-lane jobs first, unless the
    /// oldest bulk job has waited past the aging window — then it is
    /// promoted (and counted) ahead of the latency lane. Returns `None`
    /// only when the queue is closed **and** both lanes are empty
    /// (workers drain before exiting).
    ///
    /// The group is extended past the first job only while batching is
    /// actually exploitable: the backend must batch (`multi_rhs`) and the
    /// next job must target the same registry entry (same matrix, same
    /// swap generation — and, living in the same lane, the same class).
    /// Anything else stays queued for a sibling worker, so a burst of
    /// unbatchable jobs spreads across the worker pool instead of
    /// serializing behind one greedy drain.
    fn pop(&self, batch: usize, multi_rhs: bool) -> Option<Vec<ShardJob>> {
        let mut st = self.state.lock().unwrap();
        let (first, from_latency) = loop {
            let aged = match (self.aging, st.bulk.front()) {
                (Some(window), Some(oldest)) => oldest.enqueued_at.elapsed() >= window,
                _ => false,
            };
            let from_latency = !aged && !st.latency.is_empty();
            let job = if from_latency {
                st.latency.pop_front()
            } else {
                st.bulk.pop_front()
            };
            match job {
                Some(j) => {
                    if aged && !st.latency.is_empty() {
                        // An actual promotion: the bulk job jumped ahead
                        // of waiting latency work.
                        // relaxed: monotonic stats counter.
                        self.aged.fetch_add(1, Ordering::Relaxed);
                    }
                    break (j, from_latency);
                }
                None if st.closed => return None,
                None => st = self.ready.wait(st).unwrap(),
            }
        };
        let mut jobs = vec![first];
        if multi_rhs {
            let lane = if from_latency {
                &mut st.latency
            } else {
                &mut st.bulk
            };
            while jobs.len() < batch.max(1) {
                let same_entry = lane
                    .front()
                    .is_some_and(|j| Arc::ptr_eq(j.guard.entry(), jobs[0].guard.entry()));
                if !same_entry {
                    break;
                }
                jobs.push(lane.pop_front().expect("front exists"));
            }
        }
        drop(st);
        // Every dequeue frees at least one slot; wake all blocked
        // submitters (they re-check their own lane's depth).
        self.space.notify_all();
        Some(jobs)
    }

    /// Close the queue: no new jobs are admitted (pushers get
    /// `Enqueue::Closed`, parked pushers wake into it), while already
    /// queued jobs remain drainable by the workers.
    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
        self.space.notify_all();
    }
}

/// One shard: its queue, its workers, its counters, its backend handle.
struct Shard {
    queue: Arc<ShardQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
    counters: Arc<ShardCounters>,
    backend: Arc<dyn SolverBackend>,
}

/// The running sharded multi-matrix service.
pub struct ShardedSolveService {
    registry: Arc<MatrixRegistry>,
    shards: Vec<Shard>,
    backend_name: &'static str,
}

impl ShardedSolveService {
    /// Construct the configured backend(s) ([`create_backend`] — failures
    /// are startup errors) and spawn the shard queues and worker pools.
    /// The service starts with an empty registry; add matrices with
    /// [`ShardedSolveService::register`].
    pub fn start(cfg: ShardedServiceConfig) -> Result<Self> {
        let nshards = cfg.shards.max(1);
        let shared = (!cfg.backend_per_shard)
            .then(|| create_backend(&cfg.backend))
            .transpose()
            .context("construct solver backend")?;
        let mut backends = Vec::with_capacity(nshards);
        for shard in 0..nshards {
            backends.push(match &shared {
                Some(b) => Arc::clone(b),
                None => create_backend(&cfg.backend)
                    .with_context(|| format!("construct solver backend for shard {shard}"))?,
            });
        }
        Ok(Self::start_shards(backends, &cfg))
    }

    /// Like [`ShardedSolveService::start`] but with one caller-provided
    /// backend shared by every shard (dependency injection for tests,
    /// benches and embedders — e.g. reusing one backend, and thereby one
    /// persistent worker pool, across repeated service start/shutdown
    /// cycles). `cfg.backend` and `cfg.backend_per_shard` are ignored.
    pub fn start_with_backend(backend: Arc<dyn SolverBackend>, cfg: ShardedServiceConfig) -> Self {
        let backends = (0..cfg.shards.max(1)).map(|_| Arc::clone(&backend)).collect();
        Self::start_shards(backends, &cfg)
    }

    fn start_shards(backends: Vec<Arc<dyn SolverBackend>>, cfg: &ShardedServiceConfig) -> Self {
        let backend_name = backends[0].name();
        let registry = Arc::new(MatrixRegistry::with_placement(
            backends.len(),
            cfg.compiler.clone(),
            cfg.placement,
        ));
        let batch = cfg.batch_size.max(1);
        let aging = (cfg.bulk_aging_ms > 0).then(|| Duration::from_millis(cfg.bulk_aging_ms));
        let shards = backends
            .into_iter()
            .map(|backend| {
                let queue = Arc::new(ShardQueue::new(cfg.queue_cap, cfg.admission, aging));
                let counters = Arc::new(ShardCounters::default());
                let workers = (0..cfg.workers_per_shard.max(1))
                    .map(|_| {
                        let queue = Arc::clone(&queue);
                        let backend = Arc::clone(&backend);
                        let counters = Arc::clone(&counters);
                        std::thread::spawn(move || {
                            shard_worker(&queue, &*backend, &counters, batch)
                        })
                    })
                    .collect();
                Shard {
                    queue,
                    workers,
                    counters,
                    backend,
                }
            })
            .collect();
        Self {
            registry,
            shards,
            backend_name,
        }
    }

    /// Register `m` under `key`: compile + simulate + plan once (see
    /// [`MatrixRegistry::register`]), then warm the owning shard's
    /// backend ([`SolverBackend::prepare`] — for the native backend this
    /// builds the cached MGD plan and spawns the persistent pool). After
    /// this returns, requests for `key` pay zero setup. The key's
    /// requests default to the `Bulk` class; see
    /// [`ShardedSolveService::register_with_class`].
    pub fn register(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        self.register_with_class(key, m, RequestClass::Bulk)
    }

    /// [`ShardedSolveService::register`] with a per-key default
    /// [`RequestClass`]: requests for `key` that carry no class of their
    /// own ride the given lane (latency-critical keys jump bulk
    /// backlogs and may lease the pool's reserved workers).
    pub fn register_with_class(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: RequestClass,
    ) -> Result<Arc<RegisteredMatrix>> {
        let entry = self.registry.register_with_class(key, m, class)?;
        let backend = &self.shards[entry.shard()].backend;
        if let Err(e) = backend.prepare(entry.solver()) {
            // Roll the registration back: a key must not stay routed to
            // a backend that failed to prepare (retries would otherwise
            // hit "already registered" forever).
            let _ = self.registry.remove(key);
            return Err(e.context(format!("prepare backend for matrix {key:?}")));
        }
        // Debug builds statically audit the plan the backend just cached
        // (`MgdPlan::verify` + the kernel-IR lowering round trip) — the
        // static tier of the verification ladder, run against the plan
        // actually being served rather than a rebuilt default-config copy.
        #[cfg(debug_assertions)]
        if let Err(e) = entry.audit_served_plan() {
            let _ = self.registry.remove(key);
            return Err(e);
        }
        if let Some(kind) = backend.chosen_scheduler(entry.solver()) {
            entry.note_scheduler(kind);
        }
        Ok(entry)
    }

    /// Evict the matrix registered under `key`: the key becomes unknown
    /// immediately (new submits get the error reply), the call blocks
    /// until every request already routed for the key has been replied
    /// to, and the drained entry is returned (its final `served` count is
    /// readable; dropping it releases the plan). The key is then free for
    /// re-registration. Errors if `key` is not registered.
    ///
    /// Call from a control-plane thread, not from inside a shard worker
    /// (a worker cannot drain its own queue while blocked here).
    pub fn evict(&self, key: &str) -> Result<Arc<RegisteredMatrix>> {
        self.registry
            .evict(key)
            .with_context(|| format!("evict: matrix key {key:?} is not registered"))
    }

    /// Replace the matrix registered under `key` **live**: compile,
    /// simulate and plan `m` off the hot path, warm the owning shard's
    /// backend ([`SolverBackend::prepare`]), then atomically publish the
    /// new entry. Requests keep flowing throughout: mid-swap submits are
    /// served by whichever fully-formed entry they resolve, and the key
    /// keeps its shard so routing never migrates. Errors if `key` is not
    /// registered (or was evicted mid-swap); a failed prepare leaves the
    /// old entry serving.
    pub fn swap(&self, key: &str, m: &CsrMatrix) -> Result<Arc<RegisteredMatrix>> {
        self.swap_with_class(key, m, None)
    }

    /// [`ShardedSolveService::swap`] that also sets the key's default
    /// [`RequestClass`]: `Some(class)` re-classes the key as part of the
    /// same atomic publish, `None` keeps the replaced entry's class.
    pub fn swap_with_class(
        &self,
        key: &str,
        m: &CsrMatrix,
        class: Option<RequestClass>,
    ) -> Result<Arc<RegisteredMatrix>> {
        self.registry.swap_with_class(key, m, class, |entry| {
            let backend = &self.shards[entry.shard()].backend;
            backend
                .prepare(entry.solver())
                .with_context(|| format!("prepare backend for swapped matrix {key:?}"))?;
            if let Some(kind) = backend.chosen_scheduler(entry.solver()) {
                entry.note_scheduler(kind);
            }
            Ok(())
        })
    }

    /// Even out the per-shard load after evict churn: plan migrations
    /// from overloaded to underloaded shards
    /// ([`MatrixRegistry::rebalance_plan`]), warm each destination
    /// shard's backend ([`SolverBackend::prepare`] — so a migrated key's
    /// first request pays zero setup), then publish each move
    /// ([`MatrixRegistry::migrate`]). Returns the applied moves.
    ///
    /// Live-safe: the migrated entry **shares** its lineage counters
    /// with the entry it replaces, so served/in-flight accounting stays
    /// exact across the move; requests already queued on the source
    /// shard finish there on the entry `Arc` they hold, while new
    /// submits route to the destination. A key evicted or re-registered
    /// between plan and publish is skipped, not an error; a failed
    /// destination prepare aborts with the moves applied so far.
    pub fn rebalance(&self) -> Result<Vec<Migration>> {
        let moves = self.registry.rebalance_plan();
        let mut applied = Vec::new();
        for mv in moves {
            self.shards[mv.to]
                .backend
                .prepare(mv.entry().solver())
                .with_context(|| {
                    format!(
                        "prepare destination shard {} for migrated matrix {:?}",
                        mv.to, mv.key
                    )
                })?;
            if self.registry.migrate(&mv).is_ok() {
                applied.push(mv);
            }
        }
        Ok(applied)
    }

    /// Route one request to the shard owning its matrix, applying the
    /// admission policy. The reply contract: **every** request either
    /// takes a queue slot or receives an immediate error *reply* on its
    /// channel — an unknown `matrix_key`, a shed request (the reply
    /// names the full lane, its cap and the policy) and the shutdown
    /// race all answer instead of dropping the channel. The call itself
    /// errors only when the service is stopping (after the error reply
    /// has been sent). Under `Block` (or `ByClass` for latency
    /// requests) the call parks while the target lane is full;
    /// [`ShardedSolveService::try_route`] is the never-parking form.
    pub fn route(&self, req: SolveRequest) -> Result<()> {
        self.admit(req).map(|_| ())
    }

    /// Non-blocking submit with an admission verdict: builds the reply
    /// channel, routes, and returns [`Admission::Admitted`] with the
    /// [`SolveHandle`] (exactly one reply will arrive — possibly an
    /// error reply, e.g. for an unknown key) or [`Admission::Shed`] with
    /// the queue-cap reason. Errors only when the service is stopping.
    ///
    /// "Non-blocking" is admission-wide under `Shed`; under
    /// `Block`/`ByClass` a full *blocking-class* lane still parks the
    /// caller, because that is what those policies promise the request.
    pub fn try_route(
        &self,
        key: &str,
        b: Vec<f32>,
        class: Option<RequestClass>,
    ) -> Result<Admission> {
        let (reply, rx) = completion::channel();
        let outcome = self.admit(SolveRequest {
            matrix_key: key.to_string(),
            b,
            reply,
            class,
        })?;
        Ok(match outcome {
            Admitted::Enqueued | Admitted::Answered => {
                Admission::Admitted(SolveHandle { cell: rx })
            }
            Admitted::Shed(reason) => Admission::Shed(reason),
        })
    }

    /// The one admission path behind [`ShardedSolveService::route`] and
    /// [`ShardedSolveService::try_route`]. Sends the error reply itself
    /// in every non-enqueued case, so the reply contract holds no matter
    /// which caller drops which half of the plumbing.
    fn admit(&self, req: SolveRequest) -> Result<Admitted> {
        // `checkout` (not `get`): the in-flight mark is taken under the
        // registry's read lock, so an evict cannot slip between the
        // lookup and the enqueue and miss this request in its drain.
        let Some(entry) = self.registry.checkout(&req.matrix_key) else {
            let _ = req.reply.send(Err(anyhow!(
                "unknown matrix key {:?} (registered: [{}])",
                req.matrix_key,
                self.registry.keys().join(", ")
            )));
            return Ok(Admitted::Answered);
        };
        let class = req.class.unwrap_or(entry.default_class());
        // Guard the mark before anything fallible: every exit below
        // either enqueues the guard or drops it (checking the request
        // back in), so an evict of this key can never wait forever on a
        // request that never ran.
        let guard = InflightGuard(entry);
        let shard = &self.shards[guard.entry().shard()];
        let matrix_key = req.matrix_key;
        let job = ShardJob {
            b: req.b,
            reply: req.reply,
            guard,
            class,
            enqueued_at: Instant::now(),
        };
        match shard.queue.push(job) {
            Enqueue::Admitted { depth } => {
                shard.counters.note_admitted(class, depth as u64);
                Ok(Admitted::Enqueued)
            }
            Enqueue::Shed { job, reason } => {
                shard.counters.note_shed(class);
                let _ = job
                    .reply
                    .send(Err(anyhow!("request for {matrix_key:?} shed: {reason}")));
                Ok(Admitted::Shed(reason))
                // `job` (and its in-flight guard) drops here: a shed
                // request leaves the in-flight set immediately.
            }
            Enqueue::Closed { job } => {
                // The shutdown race: the queue closed between checkout
                // and enqueue. The old front end dropped `reply` here,
                // leaving waiters a bare RecvError; the contract demands
                // a descriptive reply first.
                let _ = job.reply.send(Err(anyhow!(
                    "service stopped: shard {} accepts no new requests \
                     (request for {matrix_key:?} was not enqueued)",
                    job.guard.entry().shard()
                )));
                Err(anyhow!("service stopped"))
            }
        }
    }

    /// Submit a request for `key` under its default class; returns the
    /// handle for the response.
    pub fn submit(&self, key: &str, b: Vec<f32>) -> Result<SolveHandle> {
        self.submit_class(key, b, None)
    }

    /// Submit a request for `key` with an explicit class override
    /// (`None` = the key's default). Shed requests surface as an `Err`
    /// on the returned handle's wait, exactly like other error replies
    /// (`admit` answers the channel before handing the shed back).
    pub fn submit_class(
        &self,
        key: &str,
        b: Vec<f32>,
        class: Option<RequestClass>,
    ) -> Result<SolveHandle> {
        let (reply, rx) = completion::channel();
        self.admit(SolveRequest {
            matrix_key: key.to_string(),
            b,
            reply,
            class,
        })?;
        Ok(SolveHandle { cell: rx })
    }

    /// Solve synchronously against the matrix registered under `key`.
    pub fn solve(&self, key: &str, b: Vec<f32>) -> Result<SolveResponse> {
        self.submit(key, b)?.wait()
    }

    /// The matrix registry (lookups, keys, per-matrix served counts).
    pub fn registry(&self) -> &Arc<MatrixRegistry> {
        &self.registry
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Point-in-time per-shard serving statistics.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let mut stats = s.counters.snapshot(i);
                stats.aged_bulk = s.queue.aged_count();
                stats
            })
            .collect()
    }

    /// Aggregate serving statistics across all shards, including the
    /// worker-pool session concurrency of every **distinct** backend
    /// (shards share one backend — and so one pool — by default;
    /// `peak_concurrency >= 2` there means two solves really overlapped).
    pub fn stats(&self) -> ServingStats {
        let mut agg = ServingStats::aggregate(&self.shard_stats());
        // Dedup backends by data pointer (not `Arc::ptr_eq`, which
        // compares vtable pointers too on `dyn` and lints as ambiguous).
        let mut seen: Vec<*const ()> = Vec::new();
        for shard in &self.shards {
            let ptr = Arc::as_ptr(&shard.backend) as *const ();
            if seen.contains(&ptr) {
                continue;
            }
            seen.push(ptr);
            if let Some(pool) = shard.backend.pool_stats() {
                agg.concurrent_sessions += pool.concurrent_sessions as u64;
                agg.peak_concurrency = agg.peak_concurrency.max(pool.peak_concurrency as u64);
            }
        }
        agg
    }

    /// Replies delivered so far (successful and error replies; unknown-key
    /// replies short-circuit at routing and are not counted here).
    pub fn served(&self) -> u64 {
        let agg = self.stats();
        agg.served + agg.errors
    }

    /// Name of the numeric backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.backend_name
    }

    /// Stop accepting new requests on every shard: from this point each
    /// [`ShardedSolveService::route`]/submit answers with a "service
    /// stopped" error reply (and errors), while requests already queued
    /// keep draining and replying normally. The first step of a graceful
    /// shutdown; [`ShardedSolveService::shutdown`] calls it implicitly.
    pub fn close_intake(&self) {
        for shard in &self.shards {
            shard.queue.close();
        }
    }

    /// Stop all shard workers (each drains its queue first). Dropping the
    /// service does the same; this form merely makes the join explicit.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.close_intake();
        for shard in &mut self.shards {
            for w in shard.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for ShardedSolveService {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One shard worker: drain the next group and dispatch it through the
/// backend. The queue hands back *homogeneous* groups — same registry
/// entry, same class, latency lane first — and extends a group past one
/// job only when the backend can actually batch it ([`ShardQueue::pop`]).
/// The former greedy drain (grab `batch` jobs regardless) serialized
/// unbatchable bursts behind one worker while its siblings idled; now an
/// unbatchable burst spreads one job per worker.
fn shard_worker(
    queue: &ShardQueue,
    backend: &dyn SolverBackend,
    counters: &ShardCounters,
    batch: usize,
) {
    let multi_rhs = backend.supports_multi_rhs();
    while let Some(jobs) = queue.pop(batch, multi_rhs) {
        let entry = Arc::clone(jobs[0].guard.entry());
        let class = jobs[0].class;
        let group = jobs
            .into_iter()
            .map(|job| {
                debug_assert!(Arc::ptr_eq(job.guard.entry(), &entry));
                debug_assert_eq!(job.class, class);
                (job.b, job.reply, job.guard)
            })
            .collect();
        solve_group(backend, &entry, group, class, counters);
    }
}

type Reply = completion::Completer<Result<SolveResponse>>;

/// Solve one same-matrix, same-class group and reply to every requester.
/// Errors are propagated to each caller in the group — a worker must
/// never drop requests on the floor.
fn solve_group(
    backend: &dyn SolverBackend,
    entry: &RegisteredMatrix,
    group: Vec<(Vec<f32>, Reply, InflightGuard)>,
    class: RequestClass,
    counters: &ShardCounters,
) {
    let count = group.len();
    let t0 = Instant::now();
    if count > 1 && backend.supports_multi_rhs() {
        // Batched rounds go through the backend's multi-RHS path,
        // amortizing dispatch and gather staging. The RHS vectors move
        // out of the jobs (no clone); replies only need the channels.
        // The in-flight guards stay alive until every reply in the group
        // has been sent, so an evict observes all-or-nothing per round.
        let mut bs = Vec::with_capacity(count);
        let mut replies = Vec::with_capacity(count);
        let mut guards = Vec::with_capacity(count);
        for (b, reply, guard) in group {
            bs.push(b);
            replies.push(reply);
            guards.push(guard);
        }
        match backend.solve_multi_class(entry.solver(), &bs, class) {
            Ok(xs) => {
                let elapsed = t0.elapsed();
                let per = elapsed.as_secs_f64() / count as f64;
                entry.note_served(count as u64);
                counters.record_round(count as u64, 0, elapsed);
                for (reply, x) in replies.into_iter().zip(xs) {
                    let _ = reply.send(Ok(SolveResponse {
                        x,
                        host_seconds: per,
                        metrics: entry.metrics().clone(),
                    }));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                counters.record_round(0, count as u64, t0.elapsed());
                for reply in replies {
                    let _ = reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
        drop(guards); // replies delivered: requests leave the in-flight set
    } else {
        // Scalar path: reply immediately after each solve (no head-of-
        // group latency), recording counters just before each send so a
        // caller holding its response never reads stale stats.
        for (b, reply, guard) in group {
            let t1 = Instant::now();
            let out = backend
                .solve_class(entry.solver(), &b, class)
                .map(|x| SolveResponse {
                    x,
                    host_seconds: t1.elapsed().as_secs_f64(),
                    metrics: entry.metrics().clone(),
                });
            match &out {
                Ok(_) => {
                    entry.note_served(1);
                    counters.record_round(1, 0, t1.elapsed());
                }
                Err(_) => counters.record_round(0, 1, t1.elapsed()),
            }
            let _ = reply.send(out);
            drop(guard); // reply delivered: request leaves the in-flight set
        }
    }
}

/// Key the [`SolveService`] facade registers its single matrix under
/// (shared with [`super::session`] so the facade can open sessions).
pub(super) const SINGLE_KEY: &str = "default";

/// The single-matrix solve service: a 1-shard [`ShardedSolveService`]
/// with one matrix registered at startup. This is the compile-once,
/// serve-many facade used by `mgd solve`, tests and benches.
pub struct SolveService {
    /// The wrapped 1-shard service (visible to [`super::session`] so the
    /// facade can open streaming sessions against [`SINGLE_KEY`]).
    pub(super) inner: ShardedSolveService,
    /// The compiled accelerator program (public for inspection/benches).
    pub program: Arc<Program>,
    /// Shared per-matrix metrics.
    pub metrics: SolveMetrics,
}

impl SolveService {
    /// Construct the configured backend ([`create_backend`]), start a
    /// 1-shard service, and register `m`. Backend construction failures —
    /// e.g. an explicit `pjrt` request without the toolchain — are
    /// startup errors, not hung requests; so are compile/verify failures.
    pub fn start(m: &CsrMatrix, cfg: ServiceConfig) -> Result<Self> {
        let backend = create_backend(&cfg.backend).context("construct solver backend")?;
        Self::start_with_backend(m, backend, cfg)
    }

    /// Like [`SolveService::start`] but with a caller-provided backend
    /// (dependency injection for tests, benches and embedders).
    pub fn start_with_backend(
        m: &CsrMatrix,
        backend: Arc<dyn SolverBackend>,
        cfg: ServiceConfig,
    ) -> Result<Self> {
        let inner = ShardedSolveService::start_with_backend(
            backend,
            ShardedServiceConfig {
                compiler: cfg.compiler,
                shards: 1,
                workers_per_shard: cfg.workers,
                batch_size: cfg.batch_size,
                backend: cfg.backend,
                ..ShardedServiceConfig::default()
            },
        );
        let entry = inner.register(SINGLE_KEY, m)?;
        let program = Arc::clone(entry.program());
        let metrics = entry.metrics().clone();
        Ok(Self {
            inner,
            program,
            metrics,
        })
    }

    /// Submit a request; returns the handle for the response.
    pub fn submit(&self, b: Vec<f32>) -> Result<SolveHandle> {
        self.inner.submit(SINGLE_KEY, b)
    }

    /// Solve synchronously (submit + wait).
    pub fn solve(&self, b: Vec<f32>) -> Result<SolveResponse> {
        self.inner.solve(SINGLE_KEY, b)
    }

    /// Replies delivered so far (successful and error replies).
    pub fn served(&self) -> u64 {
        self.inner.served()
    }

    /// Name of the numeric backend serving requests.
    pub fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }

    /// Stop the workers (drains the queue first).
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchConfig;
    use crate::matrix::gen::{self, GenSeed};
    use crate::matrix::triangular::assert_close_to_reference;
    use crate::runtime::sync::mpsc;
    use crate::runtime::BackendKind;

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            workers: 2,
            batch_size: 4,
            backend: BackendConfig::default(),
        }
    }

    fn small_sharded_cfg(shards: usize) -> ShardedServiceConfig {
        ShardedServiceConfig {
            compiler: CompilerConfig {
                arch: ArchConfig {
                    log2_cus: 4,
                    ..ArchConfig::default()
                },
                ..CompilerConfig::default()
            },
            shards,
            workers_per_shard: 2,
            batch_size: 4,
            ..ShardedServiceConfig::default()
        }
    }

    #[test]
    fn serves_concurrent_requests_correctly() {
        let m = gen::circuit(400, 5, 0.8, GenSeed(1));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..12 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.wait().unwrap();
            assert_close_to_reference(&m, &b, &resp.x, 1e-3);
            assert!(resp.metrics.gops > 0.0);
            // >= 0.0, not > 0.0: tiny solves can land under the host
            // timer's resolution.
            assert!(resp.host_seconds >= 0.0);
        }
        assert_eq!(svc.served(), 12);
        svc.shutdown();
    }

    #[test]
    fn serves_through_the_mgd_scheduler() {
        use crate::runtime::{NativeConfig, SchedulerKind};
        // A deep matrix served with the barrier-free scheduler pinned:
        // requests flow through `MgdPlan`/`mgd_exec` end to end.
        let m = gen::banded(600, 3, 0.9, GenSeed(6));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Native,
                native: NativeConfig {
                    threads: 4,
                    scheduler: SchedulerKind::Mgd,
                    ..NativeConfig::default()
                },
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let svc = SolveService::start(&m, cfg).unwrap();
        assert_eq!(svc.backend_name(), "native");
        let mut rxs = Vec::new();
        let mut bs = Vec::new();
        for k in 0..6 {
            let b: Vec<f32> = (0..m.n).map(|i| ((i + 2 * k) % 5) as f32 - 2.0).collect();
            rxs.push(svc.submit(b.clone()).unwrap());
            bs.push(b);
        }
        for (rx, b) in rxs.into_iter().zip(bs) {
            let resp = rx.wait().unwrap();
            // The MGD scheduler's contract is bitwise-serial numerics.
            let want = crate::matrix::triangular::solve_serial(&m, &b);
            for i in 0..m.n {
                assert_eq!(resp.x[i].to_bits(), want[i].to_bits(), "row {i}");
            }
        }
        assert_eq!(svc.served(), 6);
        svc.shutdown();
    }

    #[test]
    fn default_backend_is_native_without_pjrt_artifacts() {
        let m = gen::banded(200, 4, 0.6, GenSeed(3));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        // Auto selection: PJRT artifacts are absent in a clean checkout,
        // so the service must come up on the native executor.
        assert_eq!(svc.backend_name(), "native");
        let resp = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &resp.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn explicit_pjrt_without_toolchain_fails_at_start_not_at_solve() {
        // The seed bug: a worker whose runtime failed to load returned
        // silently, so submitted requests hung forever. Backend
        // construction now happens before any worker spawns.
        let m = gen::banded(150, 4, 0.6, GenSeed(4));
        let cfg = ServiceConfig {
            backend: BackendConfig {
                kind: BackendKind::Pjrt,
                artifacts: std::path::PathBuf::from("/nonexistent/artifacts"),
                ..BackendConfig::default()
            },
            ..small_cfg()
        };
        let err = SolveService::start(&m, cfg).err().expect("must not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt") || msg.contains("PJRT"), "{msg}");
    }

    #[test]
    fn worker_replies_with_error_on_bad_request() {
        // A malformed RHS must produce an error reply, not a hang or a
        // worker exit.
        let m = gen::banded(100, 4, 0.6, GenSeed(5));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        let err = svc.solve(vec![1.0f32; m.n + 7]).unwrap_err();
        assert!(format!("{err:#}").contains("rhs length"));
        // The service keeps serving after an error round.
        let ok = svc.solve(vec![1.0f32; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0f32; m.n], &ok.x, 1e-3);
        svc.shutdown();
    }

    #[test]
    fn metrics_match_program_prediction() {
        let m = gen::banded(300, 5, 0.6, GenSeed(2));
        let svc = SolveService::start(&m, small_cfg()).unwrap();
        assert_eq!(svc.metrics.cycles, svc.program.predicted.cycles);
        svc.shutdown();
    }

    #[test]
    fn sharded_service_routes_multiple_matrices() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let ma = gen::circuit(300, 4, 0.8, GenSeed(71));
        let mb = gen::banded(220, 4, 0.6, GenSeed(72));
        let ea = svc.register("alpha", &ma).unwrap();
        let eb = svc.register("beta", &mb).unwrap();
        // Two matrices on two shards: least-loaded placement puts the
        // second key on the still-empty shard.
        assert_eq!((ea.shard(), eb.shard()), (0, 1));
        let mut expect = Vec::new();
        let mut rxs = Vec::new();
        for k in 0..10 {
            let (key, m) = if k % 2 == 0 { ("alpha", &ma) } else { ("beta", &mb) };
            let b: Vec<f32> = (0..m.n).map(|i| ((i + k) % 7) as f32 - 3.0).collect();
            rxs.push(svc.submit(key, b.clone()).unwrap());
            expect.push((m, b));
        }
        for (rx, (m, b)) in rxs.into_iter().zip(expect) {
            let resp = rx.wait().unwrap();
            assert_close_to_reference(m, &b, &resp.x, 1e-3);
        }
        // Both shards served, and the aggregate adds up.
        let stats = svc.shard_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].served, 5, "{stats:?}");
        assert_eq!(stats[1].served, 5, "{stats:?}");
        let agg = svc.stats();
        assert_eq!(agg.served, 10);
        assert_eq!(agg.errors, 0);
        assert!(agg.batched_rounds >= 2);
        assert_eq!(ea.served() + eb.served(), 10);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_key_is_an_error_reply_not_a_hang() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let m = gen::chain(80, GenSeed(73));
        svc.register("only", &m).unwrap();
        // Reply arrives immediately with a diagnostic, listing what is
        // actually registered.
        let err = svc.solve("missing", vec![0.0; m.n]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown matrix key"), "{msg}");
        assert!(msg.contains("only"), "{msg}");
        // The error does not count against any shard's request stream.
        assert_eq!(svc.stats().errors, 0);
        svc.shutdown();
    }

    #[test]
    fn failed_prepare_rolls_back_the_registration() {
        use crate::runtime::LevelSolver;
        struct FailingPrepare;
        impl SolverBackend for FailingPrepare {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn prepare(&self, _plan: &LevelSolver) -> Result<()> {
                anyhow::bail!("artifacts unavailable")
            }
            fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
                Ok(crate::matrix::triangular::solve_serial(plan.matrix(), b))
            }
        }
        let svc =
            ShardedSolveService::start_with_backend(Arc::new(FailingPrepare), small_sharded_cfg(1));
        let m = gen::chain(50, GenSeed(75));
        let err = svc.register("m", &m).unwrap_err();
        assert!(format!("{err:#}").contains("prepare backend"));
        // The key is not poisoned: it is unknown again and can be
        // registered against a working backend later.
        assert!(svc.registry().get("m").is_none());
        svc.shutdown();
    }

    #[test]
    fn duplicate_registration_errors() {
        let svc = ShardedSolveService::start(small_sharded_cfg(1)).unwrap();
        let m = gen::chain(60, GenSeed(74));
        svc.register("m", &m).unwrap();
        assert!(svc.register("m", &m).is_err());
        svc.shutdown();
    }

    #[test]
    fn evict_retires_the_key_and_frees_it_for_reregistration() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let m = gen::banded(200, 4, 0.6, GenSeed(76));
        svc.register("cold", &m).unwrap();
        let resp = svc.solve("cold", vec![1.0; m.n]).unwrap();
        assert_close_to_reference(&m, &vec![1.0; m.n], &resp.x, 1e-3);
        let entry = svc.evict("cold").unwrap();
        assert_eq!(entry.served(), 1);
        assert_eq!(entry.inflight(), 0, "evict returned before draining");
        // The key is unknown now (error reply, not a hang)...
        let err = svc.solve("cold", vec![1.0; m.n]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown matrix key"));
        // ...an evict of an unknown key is an error...
        assert!(svc.evict("cold").is_err());
        // ...and the key can be registered again.
        svc.register("cold", &m).unwrap();
        assert!(svc.solve("cold", vec![1.0; m.n]).is_ok());
        svc.shutdown();
    }

    #[test]
    fn swap_replaces_the_matrix_between_requests() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let ma = gen::banded(180, 4, 0.6, GenSeed(77));
        let mb = gen::banded(240, 5, 0.7, GenSeed(78));
        let old = svc.register("hot", &ma).unwrap();
        let ra = svc.solve("hot", vec![1.0; ma.n]).unwrap();
        assert_close_to_reference(&ma, &vec![1.0; ma.n], &ra.x, 1e-3);
        // Swap to a different matrix (different order, even): the key
        // stays routable throughout and keeps its shard.
        let new = svc.swap("hot", &mb).unwrap();
        assert_eq!(new.shard(), old.shard());
        assert_eq!(new.served(), 1, "served carries across the swap");
        let rb = svc.solve("hot", vec![1.0; mb.n]).unwrap();
        assert_eq!(rb.x.len(), mb.n);
        assert_close_to_reference(&mb, &vec![1.0; mb.n], &rb.x, 1e-3);
        assert_eq!(new.served(), 2);
        // Swapping an unknown key errors without disturbing the rest.
        assert!(svc.swap("ghost", &ma).is_err());
        svc.shutdown();
    }

    use crate::matrix::triangular::solve_serial;
    use crate::runtime::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};
    use crate::runtime::LevelSolver;

    /// Scalar-only backend whose **first** solve blocks until released,
    /// recording the order in which solves run (identified by `b[0]`).
    /// The deterministic way to hold a shard worker busy while the test
    /// shapes the queue behind it.
    struct GatedOrderBackend {
        started: mpsc::Sender<()>,
        release: Mutex<mpsc::Receiver<()>>,
        gated: AtomicBool,
        order: Mutex<Vec<i32>>,
    }

    impl GatedOrderBackend {
        fn new() -> (Arc<Self>, mpsc::Receiver<()>, mpsc::Sender<()>) {
            let (started_tx, started_rx) = mpsc::channel();
            let (release_tx, release_rx) = mpsc::channel();
            (
                Arc::new(Self {
                    started: started_tx,
                    release: Mutex::new(release_rx),
                    gated: AtomicBool::new(true),
                    order: Mutex::new(Vec::new()),
                }),
                started_rx,
                release_tx,
            )
        }

        fn order(&self) -> Vec<i32> {
            self.order.lock().unwrap().clone()
        }
    }

    impl SolverBackend for GatedOrderBackend {
        fn name(&self) -> &'static str {
            "gated-order"
        }

        fn solve(&self, plan: &LevelSolver, b: &[f32]) -> Result<Vec<f32>> {
            if self.gated.swap(false, AtomicOrdering::SeqCst) {
                let _ = self.started.send(());
                let _ = self
                    .release
                    .lock()
                    .unwrap()
                    .recv_timeout(std::time::Duration::from_secs(30));
            }
            self.order.lock().unwrap().push(b[0] as i32);
            Ok(solve_serial(plan.matrix(), b))
        }
    }

    /// Start a 1-shard, 1-worker service over a gated backend with the
    /// first (marker 0) request already inside the backend, so the test
    /// can shape the queue deterministically behind it.
    fn gated_service(
        queue_cap: usize,
        admission: AdmissionPolicy,
    ) -> (
        ShardedSolveService,
        Arc<GatedOrderBackend>,
        mpsc::Sender<()>,
        crate::matrix::CsrMatrix,
        SolveHandle,
    ) {
        let (backend, started, release) = GatedOrderBackend::new();
        let svc = ShardedSolveService::start_with_backend(
            Arc::clone(&backend) as Arc<dyn SolverBackend>,
            ShardedServiceConfig {
                workers_per_shard: 1,
                queue_cap,
                admission,
                ..small_sharded_cfg(1)
            },
        );
        let m = gen::chain(40, GenSeed(140));
        svc.register("m", &m).unwrap();
        let mut b0 = vec![1.0f32; m.n];
        b0[0] = 0.0;
        let gate_handle = svc.submit("m", b0).unwrap();
        started
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("gate request never reached the backend");
        (svc, backend, release, m, gate_handle)
    }

    fn marker_rhs(n: usize, marker: f32) -> Vec<f32> {
        let mut b = vec![1.0f32; n];
        b[0] = marker;
        b
    }

    #[test]
    fn latency_lane_is_drained_before_the_bulk_backlog() {
        let (svc, backend, release, m, gate) = gated_service(0, AdmissionPolicy::Block);
        // Queue two bulk requests, then one latency request, while the
        // single worker is held inside the gate request.
        let h1 = svc.submit("m", marker_rhs(m.n, 1.0)).unwrap();
        let h2 = svc.submit("m", marker_rhs(m.n, 2.0)).unwrap();
        let h9 = svc
            .submit_class("m", marker_rhs(m.n, 9.0), Some(RequestClass::Latency))
            .unwrap();
        release.send(()).unwrap();
        for h in [gate, h1, h2, h9] {
            h.wait().unwrap();
        }
        // The latency request jumped the bulk backlog it arrived behind.
        assert_eq!(backend.order(), vec![0, 9, 1, 2]);
        let stats = svc.stats();
        assert_eq!(stats.admitted_latency, 1);
        assert_eq!(stats.admitted_bulk, 3);
        assert_eq!(stats.shed_latency + stats.shed_bulk, 0);
        svc.shutdown();
    }

    #[test]
    fn shed_policy_bounds_the_queue_and_names_the_cap() {
        let (svc, _backend, release, m, gate) = gated_service(1, AdmissionPolicy::Shed);
        // One queued request fills the single-slot bulk lane...
        let h1 = svc.submit("m", marker_rhs(m.n, 1.0)).unwrap();
        // ...so the next is shed, with the cap in the verdict...
        match svc.try_route("m", marker_rhs(m.n, 2.0), None).unwrap() {
            Admission::Shed(reason) => {
                assert!(reason.contains("queue cap"), "{reason}");
                assert!(reason.contains("bulk"), "{reason}");
            }
            Admission::Admitted(_) => panic!("request must be shed at the cap"),
        }
        // ...and a submit over the same full lane yields the error as a
        // reply on the handle, never a dropped request.
        let err = svc.submit("m", marker_rhs(m.n, 3.0)).unwrap().wait().unwrap_err();
        assert!(format!("{err:#}").contains("shed"), "{err:#}");
        release.send(()).unwrap();
        gate.wait().unwrap();
        h1.wait().unwrap();
        let stats = svc.stats();
        assert_eq!(stats.shed_bulk, 2, "{stats:?}");
        assert_eq!(stats.admitted_bulk, 2, "{stats:?}");
        assert_eq!(stats.peak_queue_depth, 1, "cap bounds the lane: {stats:?}");
        assert_eq!(stats.served, 2, "{stats:?}");
        svc.shutdown();
    }

    #[test]
    fn by_class_sheds_bulk_but_admits_latency_at_the_cap() {
        let (svc, backend, release, m, gate) = gated_service(1, AdmissionPolicy::ByClass);
        let h1 = svc.submit("m", marker_rhs(m.n, 1.0)).unwrap(); // fills bulk lane
        match svc.try_route("m", marker_rhs(m.n, 2.0), None).unwrap() {
            Admission::Shed(reason) => assert!(reason.contains("by-class"), "{reason}"),
            Admission::Admitted(_) => panic!("bulk must be shed at the cap under by-class"),
        }
        // The latency lane is empty, so latency traffic is untouched by
        // the bulk lane being full.
        let h9 = match svc
            .try_route("m", marker_rhs(m.n, 9.0), Some(RequestClass::Latency))
            .unwrap()
        {
            Admission::Admitted(h) => h,
            Admission::Shed(r) => panic!("latency shed while its lane was empty: {r}"),
        };
        release.send(()).unwrap();
        for h in [gate, h9, h1] {
            h.wait().unwrap();
        }
        assert_eq!(backend.order(), vec![0, 9, 1]);
        let stats = svc.stats();
        assert_eq!(stats.shed_bulk, 1);
        assert_eq!(stats.shed_latency, 0);
        assert_eq!(stats.admitted_latency, 1);
        svc.shutdown();
    }

    #[test]
    fn block_policy_parks_the_submitter_until_space_frees() {
        let (svc, _backend, release, m, gate) = gated_service(1, AdmissionPolicy::Block);
        let svc = Arc::new(svc);
        let h1 = svc.submit("m", marker_rhs(m.n, 1.0)).unwrap(); // lane full
        let (parked_tx, parked_rx) = mpsc::channel();
        let submitter = {
            let svc = Arc::clone(&svc);
            let b = marker_rhs(m.n, 2.0);
            std::thread::spawn(move || {
                let h = svc.submit("m", b).unwrap(); // parks at the cap
                parked_tx.send(()).unwrap();
                h.wait().unwrap()
            })
        };
        // The submitter stays parked while the lane is full...
        assert!(
            parked_rx
                .recv_timeout(std::time::Duration::from_millis(200))
                .is_err(),
            "blocked submitter returned with the lane still full"
        );
        // ...and admission completes once the worker frees a slot.
        release.send(()).unwrap();
        parked_rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("blocked submitter never admitted after space freed");
        gate.wait().unwrap();
        h1.wait().unwrap();
        submitter.join().unwrap();
        let stats = svc.stats();
        assert_eq!(stats.served, 3);
        assert_eq!(stats.shed_bulk + stats.shed_latency, 0, "block never sheds");
        assert!(stats.peak_queue_depth <= 1, "{stats:?}");
        Arc::try_unwrap(svc).ok().expect("sole owner").shutdown();
    }

    #[test]
    fn close_intake_answers_new_requests_instead_of_dropping_them() {
        let svc = ShardedSolveService::start(small_sharded_cfg(1)).unwrap();
        let m = gen::chain(30, GenSeed(141));
        svc.register("m", &m).unwrap();
        svc.close_intake();
        // The route call errors *and* the reply cell carries a
        // descriptive error — the shutdown race can no longer surface as
        // a bare dropped-cell error on the waiter's side.
        let (reply, rx) = completion::channel();
        let err = svc
            .route(SolveRequest {
                matrix_key: "m".to_string(),
                b: vec![1.0; m.n],
                reply,
                class: None,
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("service stopped"), "{err:#}");
        let replied = match rx.wait_timeout(std::time::Duration::from_secs(5)) {
            PollState::Ready(reply) => reply.unwrap_err(),
            other => panic!("reply contract broken: {other:?} instead of an error reply"),
        };
        assert!(
            format!("{replied:#}").contains("accepts no new requests"),
            "{replied:#}"
        );
        // The refused request left the in-flight set, so evict drains
        // instantly instead of waiting on a request that never ran.
        let entry = svc.evict("m").unwrap();
        assert_eq!(entry.inflight(), 0);
        svc.shutdown();
    }

    use crate::runtime::sync::atomic::AtomicUsize;
    use crate::runtime::sync::{model, thread};

    /// One tagged queue job against registry key `key`, its in-flight
    /// mark checked out for real so the drop guard's check-in stays
    /// balanced. The reply consumer is dropped up front: queue-protocol
    /// tests never reply, and [`ShardQueue`] never touches the cell.
    fn queue_job(reg: &MatrixRegistry, key: &str, tag: f32, class: RequestClass) -> ShardJob {
        let (reply, _rx) = completion::channel();
        ShardJob {
            b: vec![tag],
            reply,
            guard: InflightGuard(reg.checkout(key).expect("key registered")),
            class,
            enqueued_at: Instant::now(),
        }
    }

    /// Model-checked: the admission bound is exact. No interleaving of
    /// two `Block`-policy submitters with a draining worker ever observes
    /// a lane deeper than `cap` — the depth check, the enqueue and the
    /// park on `space` all happen under the lane mutex.
    #[test]
    fn model_queue_depth_never_exceeds_cap() {
        let reg = Arc::new(MatrixRegistry::new(1, CompilerConfig::default()));
        reg.register("q", &gen::banded(4, 1, 1.0, GenSeed(1))).unwrap();
        let out = model::explore(model::ModelConfig::fast(), move || {
            let q = Arc::new(ShardQueue::new(1, AdmissionPolicy::Block, None));
            let pushers: Vec<_> = (0..2u32)
                .map(|i| {
                    let q = Arc::clone(&q);
                    let reg = Arc::clone(&reg);
                    thread::spawn(move || {
                        let job = queue_job(&reg, "q", i as f32, RequestClass::Bulk);
                        match q.push(job) {
                            Enqueue::Admitted { depth } => {
                                if depth > 1 {
                                    model::flag("queue cap exceeded");
                                }
                            }
                            _ => model::flag("Block-policy push must admit"),
                        }
                    })
                })
                .collect();
            for _ in 0..2 {
                let jobs = q.pop(1, false).expect("open queue yields jobs");
                if jobs.len() != 1 {
                    model::flag("pop(1) returned a drain group");
                }
            }
            for h in pushers {
                h.join().unwrap();
            }
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// Model-checked: racing [`ShardQueue::close`] against concurrent
    /// submitters never strands a job. Every job whose push reported
    /// `Admitted` is still drainable afterwards, and every other job
    /// comes back as `Closed` for the error-reply contract.
    #[test]
    fn model_close_push_race_never_strands_admitted_jobs() {
        let reg = Arc::new(MatrixRegistry::new(1, CompilerConfig::default()));
        reg.register("q", &gen::banded(4, 1, 1.0, GenSeed(2))).unwrap();
        let out = model::explore(model::ModelConfig::fast(), move || {
            let q = Arc::new(ShardQueue::new(0, AdmissionPolicy::Block, None));
            let admitted = Arc::new(AtomicUsize::new(0));
            let pushers: Vec<_> = (0..2u32)
                .map(|i| {
                    let q = Arc::clone(&q);
                    let reg = Arc::clone(&reg);
                    let admitted = Arc::clone(&admitted);
                    thread::spawn(move || {
                        let job = queue_job(&reg, "q", i as f32, RequestClass::Latency);
                        match q.push(job) {
                            Enqueue::Admitted { .. } => {
                                admitted.fetch_add(1, AtomicOrdering::SeqCst);
                            }
                            Enqueue::Closed { .. } => {}
                            Enqueue::Shed { .. } => model::flag("unbounded lane shed a job"),
                        }
                    })
                })
                .collect();
            q.close();
            for h in pushers {
                h.join().unwrap();
            }
            let mut drained = 0;
            while q.pop(1, false).is_some() {
                drained += 1;
            }
            if drained != admitted.load(AtomicOrdering::SeqCst) {
                model::flag("admitted job stranded by close");
            }
        });
        out.assert_ok();
        assert!(out.schedules > 1, "expected multiple interleavings");
    }

    /// The latency lane drains strictly before bulk, and a multi-rhs
    /// drain group extends only over same-entry queue neighbors.
    #[test]
    fn queue_pop_orders_latency_first_and_batches_same_entry() {
        let reg = MatrixRegistry::new(1, CompilerConfig::default());
        reg.register("q", &gen::banded(4, 1, 1.0, GenSeed(3))).unwrap();
        let q = ShardQueue::new(0, AdmissionPolicy::Block, None);
        for tag in [1.0, 2.0] {
            let r = q.push(queue_job(&reg, "q", tag, RequestClass::Bulk));
            assert!(matches!(r, Enqueue::Admitted { .. }));
        }
        for tag in [3.0, 4.0] {
            let r = q.push(queue_job(&reg, "q", tag, RequestClass::Latency));
            assert!(matches!(r, Enqueue::Admitted { .. }));
        }
        let order: Vec<f32> = (0..4).map(|_| q.pop(1, false).unwrap()[0].b[0]).collect();
        assert_eq!(order, vec![3.0, 4.0, 1.0, 2.0]);
        for tag in [5.0, 6.0, 7.0] {
            let r = q.push(queue_job(&reg, "q", tag, RequestClass::Bulk));
            assert!(matches!(r, Enqueue::Admitted { .. }));
        }
        let group = q.pop(4, true).unwrap();
        assert_eq!(group.len(), 3, "same-entry jobs fold into one group");
    }

    /// The aging bound: a bulk job past its window is drained ahead of
    /// the latency lane. A zero window makes every queued bulk job
    /// instantly aged — deterministic, no sleeps.
    #[test]
    fn aging_window_promotes_the_oldest_bulk_job() {
        let reg = MatrixRegistry::new(1, CompilerConfig::default());
        reg.register("q", &gen::banded(4, 1, 1.0, GenSeed(4))).unwrap();
        let q = ShardQueue::new(0, AdmissionPolicy::ByClass, Some(Duration::ZERO));
        let r = q.push(queue_job(&reg, "q", 1.0, RequestClass::Bulk));
        assert!(matches!(r, Enqueue::Admitted { .. }));
        for tag in [3.0, 4.0] {
            let r = q.push(queue_job(&reg, "q", tag, RequestClass::Latency));
            assert!(matches!(r, Enqueue::Admitted { .. }));
        }
        let order: Vec<f32> = (0..3).map(|_| q.pop(1, false).unwrap()[0].b[0]).collect();
        assert_eq!(order, vec![1.0, 3.0, 4.0], "aged bulk jumps the latency lane");
        assert_eq!(q.aged_count(), 1, "only jumps past waiting latency work count");
    }

    /// `bulk_aging_ms` plumbs from the config into every shard queue and
    /// promotions surface as `aged_bulk` in the serving stats.
    #[test]
    fn aging_bound_surfaces_in_the_service_stats() {
        let (backend, started, release) = GatedOrderBackend::new();
        let svc = ShardedSolveService::start_with_backend(
            Arc::clone(&backend) as Arc<dyn SolverBackend>,
            ShardedServiceConfig {
                workers_per_shard: 1,
                admission: AdmissionPolicy::ByClass,
                bulk_aging_ms: 1,
                ..small_sharded_cfg(1)
            },
        );
        let m = gen::chain(40, GenSeed(150));
        svc.register("m", &m).unwrap();
        let gate = svc.submit("m", marker_rhs(m.n, 0.0)).unwrap();
        started
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("gate request never reached the backend");
        // A bulk job queues first, a latency job behind it; by the time
        // the worker frees up, the bulk job is far past the 1 ms window
        // and drains first despite the waiting latency job.
        let hb = svc.submit("m", marker_rhs(m.n, 1.0)).unwrap();
        let hl = svc
            .submit_class("m", marker_rhs(m.n, 9.0), Some(RequestClass::Latency))
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        release.send(()).unwrap();
        for h in [gate, hb, hl] {
            h.wait().unwrap();
        }
        assert_eq!(backend.order(), vec![0, 1, 9]);
        let stats = svc.stats();
        assert_eq!(stats.aged_bulk, 1, "{stats:?}");
        svc.shutdown();
    }

    /// [`ShardedSolveService::rebalance`] migrates a key off the loaded
    /// shard and requests keep landing on it (now via the new shard).
    #[test]
    fn rebalance_migrates_and_requests_follow() {
        let svc = ShardedSolveService::start(small_sharded_cfg(2)).unwrap();
        let heavy = gen::banded(400, 8, 0.8, GenSeed(160));
        let light = gen::chain(40, GenSeed(161));
        svc.register("heavy", &heavy).unwrap();
        for k in 0..3 {
            svc.register(&format!("l{k}"), &light).unwrap();
        }
        // All light keys stacked opposite the heavy one; the evict
        // leaves shard 0 empty while shard 1 carries all three.
        svc.evict("heavy").unwrap();
        let moved = svc.rebalance().unwrap();
        assert_eq!(moved.len(), 1, "one light key evens 3-vs-0");
        assert_eq!((moved[0].from, moved[0].to), (1, 0));
        let entry = svc.registry().get(&moved[0].key).unwrap();
        assert_eq!(entry.shard(), 0);
        let resp = svc.solve(&moved[0].key, vec![1.0; light.n]).unwrap();
        assert_close_to_reference(&light, &vec![1.0; light.n], &resp.x, 1e-3);
        assert_eq!(entry.served(), 1, "the migrated lineage keeps counting");
        svc.shutdown();
    }

    /// Registration records the backend's per-matrix scheduler pick so
    /// `mgd serve` can report it.
    #[test]
    fn registration_records_the_backends_scheduler_choice() {
        use crate::runtime::SchedulerKind;
        let svc = ShardedSolveService::start(small_sharded_cfg(1)).unwrap();
        // A pure chain recommends Mgd at any thread count (its level
        // path pays one barrier per row).
        let deep = gen::chain(200, GenSeed(170));
        let entry = svc.register("deep", &deep).unwrap();
        assert_eq!(entry.scheduler_choice(), Some(SchedulerKind::Mgd));
        // And the swap re-records for the replacement entry.
        let swapped = svc.swap("deep", &gen::chain(220, GenSeed(171))).unwrap();
        assert_eq!(swapped.scheduler_choice(), Some(SchedulerKind::Mgd));
        svc.shutdown();
    }
}
